"""Paper Figs. 7/8: attention-desert rate across layers.

Per layer we take the cached (roped) keys of a live smoke model run over the
synthetic corpus, score every prior position against the last query position
(attention-mass proxy), and measure the fraction of chunks containing no
top-10% token — the paper's desert rate (60-80% at chunk 16 on trained
models; random-init models are flatter, which the row labels note)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.desert import desert_rate
from repro.data.synthetic import DataCfg, SyntheticCorpus
from repro.models import lm


def _iter_layer_caches(cache):
    for c in cache["prologue"]:
        if c and "k" in c:
            yield c["k"]
    for pi, stacked in enumerate(cache["body"]):
        if "k" not in stacked:
            continue
        for r in range(stacked["k"].shape[0]):
            yield stacked["k"][r]


def run() -> None:
    cfg = get_config("longchat-7b-32k", smoke=True)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(DataCfg(vocab_size=cfg.vocab_size, seq_len=256,
                                     global_batch=1))
    doc = corpus.document(7)[:256][None]
    _, cache = lm.prefill(params, cfg,
                          {"tokens": jnp.asarray(doc, jnp.int32)},
                          max_len=256)
    rates = []
    for li, k in enumerate(_iter_layer_caches(cache)):
        k = np.asarray(k, np.float32)                 # (B, S, Hkv, hd)
        q = k[:, -1]                                  # last-position proxy
        s = np.abs(np.einsum("bkd,bskd->bks", q, k).sum(1))
        r = float(np.mean([desert_rate(s[b] + 1e-9 * np.arange(s.shape[1]),
                                       chunk=16, rate=0.10)
                           for b in range(s.shape[0])]))
        rates.append(r)
        emit(f"fig8/desert_rate/layer{li}", 0.0, f"rate={r:.2f}")
    emit("fig7/desert_rate/mean", 0.0,
         f"rate={np.mean(rates):.2f}(paper:0.6-0.8@trained;random-init is flatter)")
