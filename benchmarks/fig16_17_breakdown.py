"""Paper Figs. 16/17: individual technique breakdown — latency and
throughput for H2O-like baseline, +LKA, +IAKM, ALL (batch 2, rate 0.1)."""

from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.serving.simulator import ServeCfg, compare_policies

STACK = [("baseline_h2o", "h2o"), ("+LKA", "leoam_lka"),
         ("+IAKM", "leoam_iakm"), ("ALL", "leoam_all")]


def run() -> None:
    for model in ("longchat-7b-32k", "phi4-mini-3.8b"):
        cfg = get_config(model)
        scfg = ServeCfg(batch=2, prompt=8192, output=128, importance_rate=0.1)
        res = compare_policies(cfg, scfg)
        base = res["h2o"]
        for label, pol in STACK:
            r = res[pol]
            red = (1 - r["total_s"] / base["total_s"]) * 100
            tput_x = r["tokens_per_s"] / base["tokens_per_s"]
            emit(f"fig16/{model}/{label}", r["total_s"] * 1e6,
                 f"latency_reduction={red:.1f}%")
            emit(f"fig17/{model}/{label}",
                 1e6 / max(r["tokens_per_s"], 1e-9),
                 f"throughput={tput_x:.2f}x")
