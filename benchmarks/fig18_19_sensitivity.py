"""Paper Fig. 18 (chunk-size sensitivity) and Fig. 19 (batch-size
latency/throughput), plus the PQ-abstract sensitivity sweep (ISSUE-10):
selection overlap and bytes/chunk across subvector count ``m`` and
codebook size ``K`` — the two knobs `EngineCfg(pq_m, pq_centroids)`
exposes."""

from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import emit
from benchmarks.fig14_quality import selection_overlap
from repro.configs import get_config
from repro.serving.simulator import ServeCfg, simulate_request, HWCfg


def run_pq_sensitivity() -> None:
    """Overlap@k and abstract bytes vs (m, K) on the clustered-key panel.

    Bytes per chunk token per kv head: ``m`` uint8 codes vs 4 fp16
    bound coordinates per min/max box (2*hd*2 bytes per chunk per head,
    amortized 4*hd/chunk per token) — more subvectors buy overlap
    linearly in bytes, more centroids buy it for free per chunk (the
    codebook is shared per-layer state)."""
    chunk, hd = 16, 16
    seeds = range(6) if common.SMOKE else range(16)
    grid = ((1, 16), (2, 16), (2, 64), (2, 256), (4, 16)) \
        if common.SMOKE else \
        ((1, 16), (1, 64), (2, 16), (2, 64), (2, 256), (4, 16), (4, 64))
    for m, K in grid:
        mm, pq = zip(*[selection_overlap(s, m=m, K=K, chunk=chunk, hd=hd)
                       for s in seeds])
        ratio = (chunk * m) / (4.0 * hd)    # code bytes / box bytes
        emit(f"fig18/pq_m{m}_K{K}", float(np.mean(pq)),
             f"minmax={np.mean(mm):.3f} bytes_ratio={ratio:.3f}")


def run() -> None:
    cfg = get_config("phi4-mini-3.8b")   # OPT-6.7B-class stand-in
    hw = HWCfg()
    # Fig. 18: latency falls with chunk size, diminishing past 64
    prev = None
    for chunk in (8, 16, 32, 64, 128):
        r = simulate_request(cfg, ServeCfg(batch=1, prompt=8192, output=128,
                                           chunk=chunk,
                                           importance_rate=0.2), hw,
                             "leoam_all")
        d = "" if prev is None else f"delta={100 * (prev - r['total_s']) / prev:.1f}%"
        emit(f"fig18/chunk{chunk}", r["total_s"] * 1e6, d or "-")
        prev = r["total_s"]
    # Fig. 19: batch scaling
    for batch in (1, 2, 4, 8, 16):
        r = simulate_request(cfg, ServeCfg(batch=batch, prompt=8192,
                                           output=128), hw, "leoam_all")
        emit(f"fig19/batch{batch}", r["total_s"] * 1e6,
             f"tput={r['tokens_per_s']:.2f}tok_s")
    run_pq_sensitivity()
