"""Paper Fig. 18 (chunk-size sensitivity) and Fig. 19 (batch-size
latency/throughput)."""

from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.serving.simulator import ServeCfg, simulate_request, HWCfg


def run() -> None:
    cfg = get_config("phi4-mini-3.8b")   # OPT-6.7B-class stand-in
    hw = HWCfg()
    # Fig. 18: latency falls with chunk size, diminishing past 64
    prev = None
    for chunk in (8, 16, 32, 64, 128):
        r = simulate_request(cfg, ServeCfg(batch=1, prompt=8192, output=128,
                                           chunk=chunk,
                                           importance_rate=0.2), hw,
                             "leoam_all")
        d = "" if prev is None else f"delta={100 * (prev - r['total_s']) / prev:.1f}%"
        emit(f"fig18/chunk{chunk}", r["total_s"] * 1e6, d or "-")
        prev = r["total_s"]
    # Fig. 19: batch scaling
    for batch in (1, 2, 4, 8, 16):
        r = simulate_request(cfg, ServeCfg(batch=batch, prompt=8192,
                                           output=128), hw, "leoam_all")
        emit(f"fig19/batch{batch}", r["total_s"] * 1e6,
             f"tput={r['tokens_per_s']:.2f}tok_s")
