"""Paper Fig. 4 (token-level evaluation overhead vs compute) and Fig. 5
(fixed-chunk precision: redundant KV inside "important" chunks)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.adaptive import flat_chunk_select, tree_select
from repro.serving.simulator import HWCfg, ServeCfg, decode_step_costs


def _clustered(rng, n, n_clusters=6, width=24):
    s = np.abs(rng.randn(n)) * 0.01
    for _ in range(n_clusters):
        c = rng.randint(0, n - width)
        s[c:c + width] += np.abs(rng.randn(width)) * 3 + 1
    return s + rng.rand(n) * 1e-9


def run() -> None:
    cfg = get_config("phi4-mini-3.8b")
    hw = HWCfg()
    # Fig. 4: H2O-like token-level evaluation time vs GPU compute time
    for prompt in (2048, 8192, 32768):
        costs = decode_step_costs(cfg, ServeCfg(batch=4, prompt=prompt),
                                  hw, "h2o")
        ev = sum(c.eval_cpu + c.abstract_bytes / hw.disk_bw for c in costs)
        cp = sum(c.compute for c in costs)
        emit(f"fig4/eval_overhead/S{prompt}", ev * 1e6,
             f"eval_over_compute={ev / cp:.2f}x")
    # Fig. 5: top-20% chunk selection precision (fixed chunks vs tree)
    rng = np.random.RandomState(0)
    precisions_flat, precisions_tree = [], []
    for seed in range(20):
        s = _clustered(np.random.RandomState(seed), 2048)
        budget = int(0.2 * 2048 * 0.25)
        flat = flat_chunk_select(s, budget, 64)
        tree = tree_select(s, budget, 64)
        precisions_flat.append(flat.transfer_ratio)
        precisions_tree.append(tree.transfer_ratio)
    emit("fig5/chunk_precision/fixed64", 0.0,
         f"useful_transfer={np.mean(precisions_flat):.2f}(paper:~0.625)")
    emit("fig5/chunk_precision/leoam_tree", 0.0,
         f"useful_transfer={np.mean(precisions_tree):.2f}(paper:1.0)")
