"""Bench-smoke regression gate.

Compares a ``benchmarks/run.py --smoke`` CSV against the checked-in
``benchmarks/BENCH_baseline.json`` and fails (exit 1) when any gated
latency metric regresses past the baseline × tolerance — so CI catches a
serving-path slowdown instead of only checking the benches still run.

The tolerance is deliberately generous (CI runners differ wildly from the
box that produced the baseline); the gate exists to catch order-of-
magnitude regressions — a serialized pipeline, a lost overlap, a per-round
recompile — not single-digit-percent noise.  A gated metric DISAPPEARING
from the CSV also fails: benches must keep emitting what the gate watches.

Usage:
    python benchmarks/run.py --smoke | tee bench.csv
    python benchmarks/check_baseline.py bench.csv            # gate
    python benchmarks/check_baseline.py bench.csv --update   # refresh json
    python benchmarks/check_baseline.py bench.csv --prefix=fig15/overload
        # gate only metrics under a name prefix (for CI jobs that run a
        # single bench module and produce a partial CSV)
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict

_DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "BENCH_baseline.json")

# the serving-path latencies this PR series optimizes: decode round time
# (pooled sync + pipelined) and TTFT (admission serial/overlapped, queued
# arrivals) — all in us as emitted by benchmarks.common.emit
GATED = [
    "fig13/engine/round/serial",
    "fig13/engine/round/pipelined",
    "fig13/admit/engine/serial",
    "fig13/admit/engine/overlapped",
    "fig15/queued/serial/mean_ttft",
    "fig15/queued/overlap/mean_ttft",
    "fig15/prefix/ttft_warm",
]

# absolute count ceilings (NOT latency-scaled): the bucketed prefill path
# must keep its compiled-program count O(log max_len) for the smoke length
# mix — ceil(log2(max_len)) + 2 — instead of one XLA program per distinct
# prompt length.  A count regression here means the bucket schedule broke.
# Gated for BOTH the GQA mix (max_len=512 -> 11) and the absorbed-MLA mix
# (max_len=256 -> 10): MLA traffic rides the same bucket schedule.
COUNT_LIMITS = {
    "fig13/mixed/prefill_programs": 11.0,
    "fig13/mixed_mla/prefill_programs": 10.0,
}

# raw-value bounds (NOT latencies, no tolerance multiplier): rows whose
# us column carries the quantity itself.  The shared-prefix cache must
# keep a majority chunk hit rate on the zipfian mix AND deliver warm
# TTFT at most half of cold — the ISSUE-7 acceptance bar.
BOUNDS = {
    "fig15/prefix/hit_rate": (">=", 0.5),
    "fig15/prefix/warm_over_cold": ("<=", 0.5),
    # the per-chunk CRC32 integrity layer must stay in the decode noise
    # floor (ISSUE-8 acceptance bar): checksums-on over checksums-off
    # per-round wall-clock, best-of-2 each side (fig13_pipeline.py)
    "fig13/checksum/overhead": ("<=", 1.10),
    # overload robustness (ISSUE-9 acceptance bar): the preempting
    # scheduler's goodput on the all-at-once burst replay must stay
    # >= 0.8x its steady-paced goodput, and every request across all
    # three harness runs must land in exactly one terminal bucket
    # (completed + shed + failed == submitted)
    "fig15/overload/burst_over_steady": (">=", 0.8),
    "fig15/overload/unaccounted": ("<=", 0.0),
    # PQ abstract plane (ISSUE-10 acceptance bar): ADC selection
    # overlap@k against the exact attention ranking must match or beat
    # the min/max upper-bound ranking on the paired seed panel
    # (fig14_quality.run_pq_overlap, deterministic seeds), at no more
    # than half the min/max abstract bytes per chunk
    "fig14/pq/overlap_gain": (">=", 0.0),
    "fig14/pq/bytes_ratio": ("<=", 0.5),
}


def parse_csv(path: str) -> Dict[str, float]:
    out: Dict[str, float] = {}
    with open(path) as fh:
        for line in fh:
            parts = line.strip().split(",")
            if len(parts) < 2 or parts[0] in ("name", ""):
                continue
            try:
                out[parts[0]] = float(parts[1])
            except ValueError:
                continue
    return out


def main() -> int:
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    if not args:
        print(__doc__, file=sys.stderr)
        return 2
    rows = parse_csv(args[0])
    baseline_path = _DEFAULT_BASELINE
    for a in sys.argv[1:]:
        if a.startswith("--baseline="):
            baseline_path = a.split("=", 1)[1]

    if "--update" in sys.argv:
        missing = [n for n in GATED + list(COUNT_LIMITS) + list(BOUNDS)
                   if n not in rows]
        if missing:
            print(f"refusing to update: CSV lacks {missing}",
                  file=sys.stderr)
            return 1
        data = {"tolerance": 4.0,
                "metrics_us": {n: round(rows[n], 1) for n in GATED},
                "counts_max": dict(COUNT_LIMITS),
                "bounds": {n: list(v) for n, v in BOUNDS.items()}}
        with open(baseline_path, "w") as fh:
            json.dump(data, fh, indent=2)
            fh.write("\n")
        print(f"baseline written: {baseline_path}")
        return 0

    with open(baseline_path) as fh:
        base = json.load(fh)
    # --prefix= narrows the gate to one name subtree so a CI job running
    # a single bench module (partial CSV) doesn't fail on MISSING rows
    # that other modules emit
    prefix = None
    for a in sys.argv[1:]:
        if a.startswith("--prefix="):
            prefix = a.split("=", 1)[1]
    if prefix is not None:
        for key in ("metrics_us", "counts_max", "bounds"):
            if key in base:
                base[key] = {n: v for n, v in base[key].items()
                             if n.startswith(prefix)}
    tol = float(base.get("tolerance", 4.0))
    failures = []
    for name, want_us in base["metrics_us"].items():
        got = rows.get(name)
        if got is None:
            failures.append(f"{name}: MISSING from CSV (baseline "
                            f"{want_us:.0f}us)")
            continue
        limit = want_us * tol
        verdict = "ok" if got <= limit else "REGRESSION"
        print(f"{name}: {got:.0f}us vs baseline {want_us:.0f}us "
              f"(limit {limit:.0f}us, x{tol:.1f}) -> {verdict}")
        if got > limit:
            failures.append(f"{name}: {got:.0f}us > {limit:.0f}us "
                            f"({got / want_us:.1f}x baseline)")
    # hard count ceilings: jit compile counts, not latencies — no
    # tolerance multiplier (a recompile-per-length bug blows straight past)
    for name, limit in base.get("counts_max", {}).items():
        got = rows.get(name)
        if got is None:
            failures.append(f"{name}: MISSING from CSV (count gate "
                            f"<= {limit:.0f})")
            continue
        verdict = "ok" if got <= limit else "REGRESSION"
        print(f"{name}: {got:.0f} vs ceiling {limit:.0f} -> {verdict}")
        if got > limit:
            failures.append(f"{name}: count {got:.0f} > ceiling "
                            f"{limit:.0f}")
    # raw-value bounds: the row's us column IS the quantity (a rate or a
    # ratio), compared directly against the checked-in bound
    for name, (op, bound) in base.get("bounds", {}).items():
        got = rows.get(name)
        if got is None:
            failures.append(f"{name}: MISSING from CSV (bound "
                            f"{op} {bound})")
            continue
        ok = got >= bound if op == ">=" else got <= bound
        verdict = "ok" if ok else "REGRESSION"
        print(f"{name}: {got:.3f} vs bound {op} {bound} -> {verdict}")
        if not ok:
            failures.append(f"{name}: {got:.3f} violates {op} {bound}")
    if failures:
        print("\nbench smoke regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    n_gates = (len(base["metrics_us"]) + len(base.get("counts_max", {}))
               + len(base.get("bounds", {})))
    print("bench smoke regression gate passed "
          f"({n_gates} metrics, x{tol:.1f} tolerance)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
