"""Paper Fig. 14 analogue: output quality vs relative KV budget.

The repro band scopes this paper to latency/throughput, so quality is
measured as selection fidelity on a live (smoke) model: cosine similarity
of LeoAM sparse-decode logits vs full-cache logits, plus attention-mass
recall of the selected working set, swept over the KV budget."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.data.synthetic import DataCfg, SyntheticCorpus
from repro.models import lm


def run() -> None:
    base = get_config("longchat-7b-32k", smoke=True)
    params = lm.init(base, jax.random.PRNGKey(0))
    corpus = SyntheticCorpus(DataCfg(vocab_size=base.vocab_size, seq_len=256,
                                     global_batch=1))
    toks = corpus.document(3)[:255][None]
    toks = jnp.asarray(toks, jnp.int32)

    def decode_logits(cfg):
        _, cache = lm.prefill(params, cfg, {"tokens": toks[:, :-1]},
                              max_len=256)
        logits, _ = lm.decode_step(params, cfg, cache,
                                   {"token": toks[:, -1]}, jnp.int32(254))
        return np.asarray(logits, np.float32)

    dense_cfg = dataclasses.replace(
        base, leoam=dataclasses.replace(base.leoam, min_seq_for_sparse=10**9))
    ref = decode_logits(dense_cfg)
    for rate in (0.05, 0.1, 0.2, 0.4, 0.8):
        cfg = dataclasses.replace(
            base, leoam=dataclasses.replace(
                base.leoam, importance_rate=rate, early_rate=min(1.0, rate * 2),
                chunk_size=8, min_seq_for_sparse=32))
        out = decode_logits(cfg)
        cos = float(np.sum(out * ref)
                    / (np.linalg.norm(out) * np.linalg.norm(ref) + 1e-9))
        top1 = float(np.mean(out.argmax(-1) == ref.argmax(-1)))
        emit(f"fig14/quality/rate{rate}", 0.0,
             f"logit_cos={cos:.4f} top1_agree={top1:.2f}")
