"""Paper Fig. 14 analogue: output quality vs relative KV budget, plus the
ISSUE-10 abstract-plane A/B (min/max boxes vs PQ codes).

The repro band scopes this paper to latency/throughput, so quality is
measured as selection fidelity.  Two parts, both on the live smoke model
**through the batched engine API** (the seed-era `lm.prefill`/
`decode_step` sweep predated the engine rewrites — every ranked chunk now
really flows store -> selection -> pooled attention):

* ``run_budget_quality`` — token-stream agreement of the sparse tiered
  engine vs the dense full-cache engine, swept over the importance-rate
  (KV budget) axis.
* ``run_pq_overlap`` — the abstract-plane A/B: selection-overlap@k of the
  min/max upper-bound ranking and the PQ asymmetric-distance ranking
  against the exact attention ranking (same keys, same queries, the
  engine's score convention), end-task token agreement of a pq-enabled
  vs pq-disabled engine, and abstract bytes/chunk for both planes.  The
  ``fig14/pq/overlap_gain`` and ``fig14/pq/bytes_ratio`` rows are gated
  in CI (``check_baseline.py`` bounds): PQ must rank at least as well as
  min/max at <= 0.5x the abstract bytes.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.configs import get_config
from repro.kernels.pq import adc_chunk_scores, pq_encode, pq_train
from repro.models import lm
from repro.serving.engine import BatchedLeoAMEngine, EngineCfg

MAX_LEN = 160
PROMPT_LEN = 96

_SETUP = {}


def _setup():
    if not _SETUP:
        cfg = get_config("longchat-7b-32k", smoke=True)
        cfg = dataclasses.replace(
            cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                           importance_rate=0.3,
                                           early_rate=0.5,
                                           min_seq_for_sparse=32))
        _SETUP["cfg"] = cfg
        _SETUP["params"] = lm.init(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(14)
        _SETUP["prompts"] = [rng.randint(2, cfg.vocab_size, PROMPT_LEN)
                             for _ in range(2)]
    return _SETUP["cfg"], _SETUP["params"], _SETUP["prompts"]


def _engine_streams(cfg, params, prompts, n_new, **ecfg_kw):
    """Decode ``n_new`` rounds through one batched engine; returns the
    per-request token streams plus the (shared) traffic log totals."""
    eng = BatchedLeoAMEngine(
        cfg, params, EngineCfg(max_len=MAX_LEN, selection="tree", **ecfg_kw),
        max_seqs=len(prompts))
    cur = {}
    for p in prompts:
        sid, tok = eng.add_sequence(p)
        cur[sid] = tok
    out = {sid: [t] for sid, t in cur.items()}
    for _ in range(n_new - 1):
        cur = eng.decode_round(cur)
        for sid, t in cur.items():
            out[sid].append(t)
    log = {kind: eng.store.log.total(kind=kind)
           for kind in ("abstract", "pq_codes_read", "pq_codes_write")}
    abs_bytes = (float(eng.store.abstract_bytes),
                 float(eng.store.pq_bytes) if eng.store.pq else 0.0)
    eng.store.close()
    return out, log, abs_bytes


def _agreement(a, b):
    toks_a = [t for sid in sorted(a) for t in a[sid]]
    toks_b = [t for sid in sorted(b) for t in b[sid]]
    return float(np.mean(np.asarray(toks_a) == np.asarray(toks_b)))


def _rate_cfg(cfg, rate):
    return dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, importance_rate=rate,
                                       early_rate=min(1.0, rate * 2)))


def run_budget_quality() -> None:
    """Fig. 14 axis: output fidelity vs KV budget, live engine end to end.

    The reference is the SAME tiered engine at importance rate 1.0 — the
    budget covers every chunk, so selection is score-independent and the
    attend path is identical (a dense full-cache engine would compare a
    different compiled program, not the selection policy)."""
    cfg, params, prompts = _setup()
    n_new = 6 if common.SMOKE else 12
    ref, _, _ = _engine_streams(_rate_cfg(cfg, 1.0), params, prompts, n_new)
    rates = (0.2, 0.4) if common.SMOKE else (0.05, 0.1, 0.2, 0.4, 0.8)
    for rate in rates:
        out, _, _ = _engine_streams(_rate_cfg(cfg, rate), params, prompts,
                                    n_new)
        emit(f"fig14/quality/rate{rate}", 0.0,
             f"tok_agree={_agreement(out, ref):.3f}")


# ---------------------------------------------------------------------------
# abstract-plane A/B: min/max boxes vs PQ codes
# ---------------------------------------------------------------------------

def _clustered(rng, S, Hkv, hd, n_clusters=8, span=8, noise=0.25):
    """Keys with cluster runs shorter than a chunk — the regime where a
    chunk's min/max box mixes clusters and goes loose (the PQ plane's
    motivating workload; same generator as tests/test_pq_abstracts.py)."""
    centers = rng.randn(n_clusters, hd).astype(np.float32) * 2.0
    assign = rng.randint(0, n_clusters, (S // span, Hkv))
    assign = np.repeat(assign[:, None, :], span, 1).reshape(S, Hkv)
    return centers[assign] + rng.randn(S, Hkv, hd).astype(np.float32) * noise


def selection_overlap(seed, *, S=256, chunk=16, Hkv=2, hd=16, k=4, m=2,
                      K=16, n_queries=8):
    """(minmax, pq) mean overlap@k against the exact chunk ranking over
    ``n_queries`` paired query draws, mirroring the engine's score
    convention (max over a chunk's tokens, then over kv heads)."""
    rng = np.random.RandomState(seed)
    nc = S // chunk
    keys = _clustered(rng, S, Hkv, hd)
    kc = keys.reshape(nc, chunk, Hkv, hd)
    cb0 = np.zeros((m, K, hd // m), np.float32)
    cb, _ = pq_train(keys.reshape(-1, hd), cb0, np.zeros((m, K), np.float64),
                     iters=4)
    codes = pq_encode(keys.reshape(-1, hd), cb).reshape(1, nc, chunk, Hkv, m)
    ov_mm = ov_pq = 0.0
    for _ in range(n_queries):
        q = rng.randn(Hkv, hd).astype(np.float32)
        tok = np.einsum("hd,shd->hs", q, keys)
        exact = tok.reshape(Hkv, nc, chunk).max(-1).max(0)
        ub = np.maximum(q[None] * kc.max(1), q[None] * kc.min(1)) \
            .sum(-1).max(-1)
        adc = adc_chunk_scores(q[None], cb, codes, np.asarray([S]))[0].max(0)
        top_exact = set(np.argsort(-exact)[:k])
        ov_mm += len(set(np.argsort(-ub)[:k]) & top_exact) / k
        ov_pq += len(set(np.argsort(-adc)[:k]) & top_exact) / k
    return ov_mm / n_queries, ov_pq / n_queries


def run_pq_overlap() -> None:
    cfg, params, prompts = _setup()
    # 1) selection overlap@k, paired seeds (deterministic: fixed seeds, no
    #    RNG in the k-means) — the CI-gated quality A/B
    seeds = range(12) if common.SMOKE else range(32)
    mm, pq = zip(*[selection_overlap(s) for s in seeds])
    mm_mean, pq_mean = float(np.mean(mm)), float(np.mean(pq))
    emit("fig14/pq/overlap_minmax", mm_mean, f"n_seeds={len(mm)}")
    emit("fig14/pq/overlap_pq", pq_mean, f"n_seeds={len(pq)}")
    emit("fig14/pq/overlap_gain", pq_mean - mm_mean,
         f"pq={pq_mean:.3f} minmax={mm_mean:.3f}")
    # 2) end-task quality + abstract bytes/chunk through the live engine:
    #    pq-enabled vs pq-disabled streams against the full-working-set
    #    reference (rate 1.0: selection is score-independent, so BOTH
    #    planes produce the identical reference stream — checked)
    n_new = 6 if common.SMOKE else 12
    ref, _, _ = _engine_streams(_rate_cfg(cfg, 1.0), params, prompts, n_new)
    ref_pq, _, _ = _engine_streams(_rate_cfg(cfg, 1.0), params, prompts,
                                   n_new, pq_abstracts=True)
    assert ref == ref_pq, "full-budget selection must be plane-independent"
    out_mm, log_mm, (mm_bytes, _) = _engine_streams(
        cfg, params, prompts, n_new)
    out_pq, log_pq, (_, pq_bytes) = _engine_streams(
        cfg, params, prompts, n_new, pq_abstracts=True)
    emit("fig14/pq/tok_agree_minmax", _agreement(out_mm, ref),
         "vs full working set")
    emit("fig14/pq/tok_agree_pq", _agreement(out_pq, ref),
         f"vs full working set; pq_read_bytes={log_pq['pq_codes_read']:.0f} "
         f"mm_abstract_bytes={log_mm['abstract']:.0f}")
    # 3) abstract bytes per chunk, both planes (the disk-bandwidth claim:
    #    a per-round importance read moves pq_bytes instead of the
    #    min/max box) — gated <= 0.5x
    emit("fig14/pq/abstract_bytes_minmax", mm_bytes, "per chunk")
    emit("fig14/pq/abstract_bytes_pq", pq_bytes, "per chunk")
    emit("fig14/pq/bytes_ratio", pq_bytes / mm_bytes,
         f"pq={pq_bytes:.0f}B minmax={mm_bytes:.0f}B")


def run() -> None:
    run_budget_quality()
    run_pq_overlap()
