"""Paper Fig. 13: decode-step timelines — serial vs prefetch-pipelined vs
DTP with dynamic compression (GPU idle time is the paper's target metric).

Three parts: the analytic event-timeline model (the original figure), a
MEASURED decode-round breakdown on the live engine — eval / disk gather /
upload / attend wall-clock for the synchronous pooled engine next to the
pipelined engine's round time — and a TTFT (admission) breakdown: prefill
compute vs the tier-write stall, serial ingest vs the write-behind
layer-streamed path, analytic (``prefill_schedule``) and measured
(``engine.admit_profiles``) side by side.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.configs import get_config
from repro.core.pipeline import (PrefillLayerCost, TierBW, prefill_schedule,
                                 schedule)
from repro.serving.simulator import HWCfg, ServeCfg, decode_step_costs


def run_simulated() -> None:
    cfg = get_config("longchat-7b-32k")
    hw = HWCfg()
    scfg = ServeCfg(batch=4, prompt=8192)
    layers = decode_step_costs(cfg, scfg, hw, "leoam_iakm")
    bw = TierBW(pcie=hw.pcie_bw, disk=hw.disk_bw, kappa=hw.decompress_kappa,
                delta=hw.int4_ratio)
    serial = schedule(layers, bw, pipelined=False)
    pipe = schedule(layers, bw, pipelined=True, dynamic_compression=False)
    dyn = schedule(layers, bw, pipelined=True, dynamic_compression=True)
    for label, tl in (("a_serial", serial), ("b_prefetch", pipe),
                      ("c_dtp_dyncomp", dyn)):
        emit(f"fig13/{label}", tl.makespan * 1e6,
             f"gpu_idle={tl.gpu_idle * 1e3:.1f}ms")
    emit("fig13/theta_mean", 0.0,
         f"theta={sum(dyn.thetas) / max(len(dyn.thetas), 1):.2f}")


def run_engine_overlap() -> None:
    """Measured counterpart: wall-clock decode-round breakdown of the live
    pooled engine, synchronous vs async-DTP-pipelined."""
    import jax
    from repro.models import lm
    from repro.serving.engine import BatchedLeoAMEngine, EngineCfg

    cfg = get_config("longchat-7b-32k", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.3, early_rate=0.5,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(0)
    batch, n_new = (2, 4) if common.SMOKE else (4, 8)
    prompts = [rng.randint(2, cfg.vocab_size, 96) for _ in range(batch)]

    def decode(ecfg):
        eng = BatchedLeoAMEngine(cfg, params, ecfg, max_seqs=batch)
        toks = {}
        for p in prompts:
            sid, tok = eng.add_sequence(p)
            toks[sid] = tok
        for _ in range(n_new):
            toks = eng.decode_round(toks)
        profs = eng.round_profiles[1:]          # drop the jit-warmup round
        eng.store.close()
        return profs

    prof = decode(EngineCfg(max_len=160, pooled=True, pipeline=False,
                            profile=True))          # blocked: breakdown only
    sync = decode(EngineCfg(max_len=160, pooled=True, pipeline=False))
    piped = decode(EngineCfg(max_len=160, pooled=True, pipeline=True))
    stages = ("eval_s", "gather_s", "upload_s", "attend_s")
    mean = {s: float(np.mean([p[s] for p in prof])) for s in stages}
    total_prof = float(np.mean([p["total_s"] for p in prof]))
    total_sync = float(np.mean([p["total_s"] for p in sync]))
    total_pipe = float(np.mean([p["total_s"] for p in piped]))
    for s in stages:
        emit(f"fig13/engine/serial_breakdown/{s}", mean[s] * 1e6,
             f"frac={mean[s] / max(total_prof, 1e-12):.2f}")
    emit("fig13/engine/round/serial", total_sync * 1e6, f"b{batch}")
    emit("fig13/engine/round/pipelined", total_pipe * 1e6,
         f"overlap_gain={total_sync / max(total_pipe, 1e-12):.2f}x")


def run_debug_sync_overhead() -> None:
    """Cost of the runtime sync-sanitizer (EngineCfg(debug_sync=True)):
    per-round decode wall-clock with the owning-thread / epoch / lock-order
    checks live vs off, same smoke engine.  Measured here — and ONLY here —
    because benchmarks/run.py refuses to emit any other measured row while
    the sanitizer is active (docs/INVARIANTS.md, measurement hygiene)."""
    import jax
    from repro.models import lm
    from repro.serving.engine import BatchedLeoAMEngine, EngineCfg

    cfg = get_config("longchat-7b-32k", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.3, early_rate=0.5,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(0)
    batch, n_new = (2, 4) if common.SMOKE else (2, 8)
    prompts = [rng.randint(2, cfg.vocab_size, 96) for _ in range(batch)]

    def round_time(debug_sync: bool) -> float:
        eng = BatchedLeoAMEngine(
            cfg, params,
            EngineCfg(max_len=160, pooled=True, pipeline=True,
                      debug_sync=debug_sync),
            max_seqs=batch)
        toks = {}
        for p in prompts:
            sid, tok = eng.add_sequence(p)
            toks[sid] = tok
        toks = eng.decode_round(toks)           # jit warmup round
        t0 = time.perf_counter()
        for _ in range(n_new):
            toks = eng.decode_round(toks)
        dt = (time.perf_counter() - t0) / n_new
        eng.store.close()
        return dt

    t_off = round_time(False)
    t_on = round_time(True)
    emit("fig13/debug_sync/off", t_off * 1e6, f"b{batch}")
    emit("fig13/debug_sync/on", t_on * 1e6,
         f"overhead={t_on / max(t_off, 1e-12):.2f}x")


def run_checksum_overhead() -> None:
    """Cost of the per-chunk CRC32 integrity layer (EngineCfg(checksums)):
    per-round decode wall-clock with replica/sidecar verification live vs
    off, same smoke engine.  The overhead ratio is a gated row
    (check_baseline.py BOUNDS: <= 1.10x) — the integrity tax must stay
    in the noise, or the checksum layer is doing work on the wrong path."""
    import jax
    from repro.models import lm
    from repro.serving.engine import BatchedLeoAMEngine, EngineCfg

    cfg = get_config("longchat-7b-32k", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.3, early_rate=0.5,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(0)
    batch, n_new = (2, 4) if common.SMOKE else (2, 8)
    prompts = [rng.randint(2, cfg.vocab_size, 96) for _ in range(batch)]

    def round_time(checksums: bool) -> float:
        eng = BatchedLeoAMEngine(
            cfg, params,
            EngineCfg(max_len=160, pooled=True, pipeline=True,
                      disk_sidecar=True, checksums=checksums),
            max_seqs=batch)
        toks = {}
        for p in prompts:
            sid, tok = eng.add_sequence(p)
            toks[sid] = tok
        toks = eng.decode_round(toks)           # jit warmup round
        t0 = time.perf_counter()
        for _ in range(n_new):
            toks = eng.decode_round(toks)
        dt = (time.perf_counter() - t0) / n_new
        eng.store.close()
        return dt

    # best-of-2 per config: the gate compares a RATIO of two short smoke
    # timings, so shave scheduler noise off both sides before dividing
    t_off = min(round_time(False) for _ in range(2))
    t_on = min(round_time(True) for _ in range(2))
    emit("fig13/checksum/off", t_off * 1e6, f"b{batch}")
    emit("fig13/checksum/on", t_on * 1e6, f"b{batch}")
    emit("fig13/checksum/overhead", t_on / max(t_off, 1e-12),
         "ratio_on_over_off,gated<=1.10")


def run_admission_ttft() -> None:
    """TTFT breakdown: prefill compute vs tier-write stall, serial vs
    write-behind overlapped ingest — the analytic ``prefill_schedule``
    model next to measured ``add_sequence`` wall-clock."""
    # analytic: 7B-class geometry, 8k prompt, per-layer replica+abstract
    # bytes against the sustained disk link
    cfg = get_config("longchat-7b-32k")
    hw = HWCfg()
    prompt = 8192
    d = cfg.n_kv_heads * cfg.hd
    replica = prompt * d * 2 * 2 + (prompt // cfg.leoam.chunk_size) * d * 2 * 2
    flops = 2 * prompt * cfg.d_model * (4 * cfg.d_model + 2 * 4 * cfg.d_model)
    layers = [PrefillLayerCost(compute=flops / hw.gpu_flops,
                               replica_bytes=float(replica))
              for _ in range(cfg.n_layers)]
    serial = prefill_schedule(layers, hw.disk_bw, write_behind=False)
    wb = prefill_schedule(layers, hw.disk_bw, write_behind=True)
    stall = serial.compute[-1][1] - wb.compute[-1][1]
    emit("fig13/admit/model/serial_ttft", serial.compute[-1][1] * 1e6,
         f"tier_write_stall={stall * 1e3:.1f}ms")
    emit("fig13/admit/model/write_behind_ttft", wb.compute[-1][1] * 1e6,
         f"gain={serial.compute[-1][1] / max(wb.compute[-1][1], 1e-12):.2f}x,"
         f"write_tail={(wb.makespan - wb.compute[-1][1]) * 1e3:.1f}ms")

    # measured: smoke engine, serial vs overlapped admission wall-clock
    import jax
    from repro.models import lm
    from repro.serving.engine import BatchedLeoAMEngine, EngineCfg

    mcfg = get_config("longchat-7b-32k", smoke=True)
    mcfg = dataclasses.replace(
        mcfg, leoam=dataclasses.replace(mcfg.leoam, chunk_size=16,
                                        importance_rate=0.3, early_rate=0.5,
                                        min_seq_for_sparse=32))
    params = lm.init(mcfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(0)
    n_adds = 3 if common.SMOKE else 5
    prompts = [rng.randint(2, mcfg.vocab_size, 96) for _ in range(n_adds)]

    def admits(overlap: bool):
        eng = BatchedLeoAMEngine(
            mcfg, params, EngineCfg(max_len=160, overlap_ingest=overlap),
            max_seqs=n_adds)
        for p in prompts:
            eng.add_sequence(p)
        profs = eng.admit_profiles[1:]        # drop the jit-warmup admit
        eng.store.close()                     # fences any write-behind tail
        return profs

    ser = admits(False)
    ovl = admits(True)
    t_ser = float(np.mean([p["total_s"] for p in ser]))
    t_ovl = float(np.mean([p["total_s"] for p in ovl]))
    emit("fig13/admit/engine/serial", t_ser * 1e6,
         f"prefill={np.mean([p['prefill_s'] for p in ser]) * 1e3:.1f}ms,"
         f"tier_write={np.mean([p['ingest_s'] for p in ser]) * 1e3:.1f}ms")
    emit("fig13/admit/engine/overlapped", t_ovl * 1e6,
         f"gain={t_ser / max(t_ovl, 1e-12):.2f}x")


def run_mixed_length() -> None:
    """Mixed-length arrival scenario (PR 4): a public-traffic-style length
    mix (>= 16 distinct prompt lengths, one long straggler) through the
    continuous batcher — reporting the COMPILED PREFILL PROGRAM count
    (bucketed: O(log max_len); per-length: one per distinct length), TTFT
    p50/p95, and the max step stall the running batch sees while the long
    prompt admits: whole-prompt admission pays its entire prefill in one
    gap, chunked admission is bounded by the per-round token budget."""
    _mixed_length_scenario(
        arch="longchat-7b-32k", tag="mixed", max_len=512,
        lengths=[64, 72, 460] + list(range(20, 98, 6)),
        straggler_rounds=16, min_distinct=16)


def run_mixed_length_mla() -> None:
    """The same mixed-length scenario on a DeepSeek-class absorbed-MLA
    model (PR 5): MLA traffic rides the bucketed + chunked admission path
    through the latent single-plane store, so the compiled-program gate
    and the bounded-stall comparison cover it too."""
    _mixed_length_scenario(
        arch="deepseek-v2-lite-16b", tag="mixed_mla", max_len=256,
        lengths=[64, 72, 230] + list(range(20, 92, 8)),
        straggler_rounds=12, min_distinct=12)


def _mixed_length_scenario(arch: str, tag: str, max_len: int,
                           lengths: list, straggler_rounds: int,
                           min_distinct: int) -> None:
    import jax
    from repro.models import lm
    from repro.serving.engine import BatchedLeoAMEngine, EngineCfg
    from repro.serving.scheduler import (ContinuousBatcher, Request,
                                         SchedulerCfg)

    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.3, early_rate=0.5,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(5)
    # the two mediums arrive first (and decode long enough that the long
    # straggler admits UNDER their rounds)
    assert len(set(lengths)) >= min_distinct
    prompts = [rng.randint(2, cfg.vocab_size, n) for n in lengths]
    max_news = [straggler_rounds, straggler_rounds, 4] \
        + [2] * (len(lengths) - 3)

    def drive(eng, chunked: bool, measure: bool):
        b = ContinuousBatcher(
            cfg=SchedulerCfg(max_active=2, chunk=16,
                             chunked_admission=chunked,
                             prefill_round_tokens=32),
            engine=eng)
        for rid, (p, mn) in enumerate(zip(prompts, max_news)):
            b.submit(Request(rid, p, max_new=mn))
        stalls = []
        while b.pending_work:
            had_active = bool(b.active)
            t0 = time.perf_counter()
            b.step()
            if had_active and measure:
                # the stall the RUNNING batch sees: decode round + any
                # admission work the scheduler ran in the same step
                stalls.append(time.perf_counter() - t0)
        stt = b.stats()
        return stalls, stt

    results = {}
    for mode, chunked in (("whole", False), ("chunked", True)):
        eng = BatchedLeoAMEngine(
            cfg, params, EngineCfg(max_len=max_len, prefill_chunk_tokens=32),
            max_seqs=3)
        drive(eng, chunked, measure=False)        # jit warmup, all buckets
        stalls, stt = drive(eng, chunked, measure=True)
        results[mode] = (stalls, stt, eng.prefill_programs)
        eng.store.close()
    for mode, (stalls, stt, programs) in results.items():
        emit(f"fig13/{tag}/{mode}/max_round_stall",
             max(stalls) * 1e6 if stalls else 0.0,
             f"p50_ttft={stt['p50_ttft_s'] * 1e3:.0f}ms,"
             f"p95_ttft={stt['p95_ttft_s'] * 1e3:.0f}ms,"
             f"programs={programs}")
    w, c = max(results["whole"][0]), max(results["chunked"][0])
    emit(f"fig13/{tag}/stall_reduction", 0.0,
         f"{w / max(c, 1e-12):.2f}x,budget=32tok")
    # the CI gate: compiled prefill programs for the whole mix must stay
    # O(log max_len) (ceil(log2(max_len)) + 2), not one per length — gate
    # on the WHOLE-prompt engine, whose admissions all went through the
    # bucket schedule (the chunked engine compiles exactly one chunk-step
    # program regardless of length)
    emit(f"fig13/{tag}/prefill_programs", float(results["whole"][2]),
         f"distinct_lengths={len(set(lengths))},"
         f"chunked_programs={results['chunked'][2]},"
         f"limit=ceil(log2({max_len}))+2")


def run() -> None:
    run_simulated()
    run_engine_overlap()
    run_debug_sync_overhead()
    run_checksum_overhead()
    run_admission_ttft()
    run_mixed_length()
    run_mixed_length_mla()
