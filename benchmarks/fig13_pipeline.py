"""Paper Fig. 13: decode-step timelines — serial vs prefetch-pipelined vs
DTP with dynamic compression (GPU idle time is the paper's target metric).

Two parts: the analytic event-timeline model (the original figure), and a
MEASURED decode-round breakdown on the live engine — eval / disk gather /
upload / attend wall-clock for the synchronous pooled engine next to the
pipelined engine's round time, so the simulated overlap can be checked
against what the engine actually achieves.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.configs import get_config
from repro.core.pipeline import TierBW, schedule
from repro.serving.simulator import HWCfg, ServeCfg, decode_step_costs


def run_simulated() -> None:
    cfg = get_config("longchat-7b-32k")
    hw = HWCfg()
    scfg = ServeCfg(batch=4, prompt=8192)
    layers = decode_step_costs(cfg, scfg, hw, "leoam_iakm")
    bw = TierBW(pcie=hw.pcie_bw, disk=hw.disk_bw, kappa=hw.decompress_kappa,
                delta=hw.int4_ratio)
    serial = schedule(layers, bw, pipelined=False)
    pipe = schedule(layers, bw, pipelined=True, dynamic_compression=False)
    dyn = schedule(layers, bw, pipelined=True, dynamic_compression=True)
    for label, tl in (("a_serial", serial), ("b_prefetch", pipe),
                      ("c_dtp_dyncomp", dyn)):
        emit(f"fig13/{label}", tl.makespan * 1e6,
             f"gpu_idle={tl.gpu_idle * 1e3:.1f}ms")
    emit("fig13/theta_mean", 0.0,
         f"theta={sum(dyn.thetas) / max(len(dyn.thetas), 1):.2f}")


def run_engine_overlap() -> None:
    """Measured counterpart: wall-clock decode-round breakdown of the live
    pooled engine, synchronous vs async-DTP-pipelined."""
    import jax
    from repro.models import lm
    from repro.serving.engine import BatchedLeoAMEngine, EngineCfg

    cfg = get_config("longchat-7b-32k", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.3, early_rate=0.5,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    rng = np.random.RandomState(0)
    batch, n_new = (2, 4) if common.SMOKE else (4, 8)
    prompts = [rng.randint(2, cfg.vocab_size, 96) for _ in range(batch)]

    def decode(ecfg):
        eng = BatchedLeoAMEngine(cfg, params, ecfg, max_seqs=batch)
        toks = {}
        for p in prompts:
            sid, tok = eng.add_sequence(p)
            toks[sid] = tok
        for _ in range(n_new):
            toks = eng.decode_round(toks)
        profs = eng.round_profiles[1:]          # drop the jit-warmup round
        eng.store.close()
        return profs

    prof = decode(EngineCfg(max_len=160, pooled=True, pipeline=False,
                            profile=True))          # blocked: breakdown only
    sync = decode(EngineCfg(max_len=160, pooled=True, pipeline=False))
    piped = decode(EngineCfg(max_len=160, pooled=True, pipeline=True))
    stages = ("eval_s", "gather_s", "upload_s", "attend_s")
    mean = {s: float(np.mean([p[s] for p in prof])) for s in stages}
    total_prof = float(np.mean([p["total_s"] for p in prof]))
    total_sync = float(np.mean([p["total_s"] for p in sync]))
    total_pipe = float(np.mean([p["total_s"] for p in piped]))
    for s in stages:
        emit(f"fig13/engine/serial_breakdown/{s}", mean[s] * 1e6,
             f"frac={mean[s] / max(total_prof, 1e-12):.2f}")
    emit("fig13/engine/round/serial", total_sync * 1e6, f"b{batch}")
    emit("fig13/engine/round/pipelined", total_pipe * 1e6,
         f"overlap_gain={total_sync / max(total_pipe, 1e-12):.2f}x")


def run() -> None:
    run_simulated()
    run_engine_overlap()
