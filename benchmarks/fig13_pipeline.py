"""Paper Fig. 13: decode-step timelines — serial vs prefetch-pipelined vs
DTP with dynamic compression (GPU idle time is the paper's target metric)."""

from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.pipeline import TierBW, schedule
from repro.serving.simulator import HWCfg, ServeCfg, decode_step_costs


def run() -> None:
    cfg = get_config("longchat-7b-32k")
    hw = HWCfg()
    scfg = ServeCfg(batch=4, prompt=8192)
    layers = decode_step_costs(cfg, scfg, hw, "leoam_iakm")
    bw = TierBW(pcie=hw.pcie_bw, disk=hw.disk_bw, kappa=hw.decompress_kappa,
                delta=hw.int4_ratio)
    serial = schedule(layers, bw, pipelined=False)
    pipe = schedule(layers, bw, pipelined=True, dynamic_compression=False)
    dyn = schedule(layers, bw, pipelined=True, dynamic_compression=True)
    for label, tl in (("a_serial", serial), ("b_prefetch", pipe),
                      ("c_dtp_dyncomp", dyn)):
        emit(f"fig13/{label}", tl.makespan * 1e6,
             f"gpu_idle={tl.gpu_idle * 1e3:.1f}ms")
    emit("fig13/theta_mean", 0.0,
         f"theta={sum(dyn.thetas) / max(len(dyn.thetas), 1):.2f}")
