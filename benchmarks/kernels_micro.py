"""Kernel microbenchmarks: jnp reference vs interpret-mode Pallas (CPU
timing is NOT TPU-representative — the derived column carries the analytic
VMEM working set and arithmetic intensity instead)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels.chunk_bounds.ops import chunk_bounds
from repro.kernels.sparse_decode.ops import sparse_decode


def run() -> None:
    rng = np.random.RandomState(0)
    # chunk_bounds at decode_32k geometry (per shard)
    B, Hkv, G, hd, nc = 8, 8, 12, 128, 128
    q = jnp.asarray(rng.randn(B, Hkv, G, hd).astype(np.float32))
    km = jnp.asarray(rng.randn(B, Hkv, nc, hd).astype(np.float32))
    kn = km - 1.0
    t_ref = time_fn(jax.jit(lambda *a: chunk_bounds(*a, impl="ref")), q, km, kn)
    flops = 4 * B * Hkv * G * nc * hd * 2
    emit("kernel/chunk_bounds/ref_jit", t_ref,
         f"flops={flops:.2e} vmem_tile={(G * hd + 2 * 128 * hd) * 4 / 2**10:.0f}KiB")
    # sparse_decode at long_500k per-shard geometry
    B, Hkv, G, hd, S, chunk, nsel = 1, 8, 12, 128, 1024, 64, 8
    q = jnp.asarray(rng.randn(B, Hkv, G, hd).astype(np.float32))
    k = jnp.asarray(rng.randn(B, S, Hkv, hd).astype(np.float32))
    v = jnp.asarray(rng.randn(B, S, Hkv, hd).astype(np.float32))
    ids = jnp.asarray(rng.randint(0, S // chunk, (B, Hkv, nsel)), jnp.int32)
    t_ref = time_fn(jax.jit(
        lambda *a: sparse_decode(*a, chunk=chunk, impl="ref")),
        q, k, v, ids, jnp.int32(S))
    moved = nsel * chunk * hd * 2 * 2
    emit("kernel/sparse_decode/ref_jit", t_ref,
         f"hbm_bytes_per_bh={moved / 2**10:.0f}KiB "
         f"vmem_tile={(chunk * hd * 2 * 4) / 2**10:.0f}KiB")
