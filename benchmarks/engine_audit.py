"""Tier-store traffic audit on the live engine: measured LKA savings vs the
r = α + 2/n' model (paper Fig. 11 / §6.5 time overhead)."""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.tiers import lka_transfer_ratio
from repro.models import lm
from repro.serving.engine import BatchedLeoAMEngine, EngineCfg, LeoAMEngine
from repro.serving.faults import FaultPlan
from repro.serving.offload import DISK, HOST


def run() -> None:
    cfg = get_config("longchat-7b-32k", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.2, early_rate=0.4,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    eng = LeoAMEngine(cfg, params, EngineCfg(max_len=256, gpu_chunk_frac=0.1,
                                             cpu_chunk_frac=0.3,
                                             selection="tree"))
    rng = np.random.RandomState(0)
    eng.generate(rng.randint(2, cfg.vocab_size, 200), 8)
    log = eng.store.log
    disk_kv = log.total(src=DISK, kind="kv")
    disk_abs = log.total(src=DISK, kind="abstract")
    full_disk = (eng.store.n_chunks * 0.6) * eng.store.chunk_bytes * \
        len(eng.attn_layers) * 8
    measured_r = (disk_kv + disk_abs) / max(full_disk, 1)
    model_r = lka_transfer_ratio(cfg.leoam.importance_rate,
                                 cfg.leoam.chunk_size)
    emit("engine/lka_disk_traffic_ratio", 0.0,
         f"measured={measured_r:.3f} model_r={model_r:.3f}")
    ev = np.mean([s.evaluations for s in eng.stats])
    emit("engine/evals_per_step", 0.0,
         f"n={ev:.0f} token_level_would_be={eng.length * len(eng.attn_layers)}")
    # device-pool residency: once warm, H2D per round is the promoted delta
    ps = eng.store.pool_stats()
    emit("engine/pool_hit_rate", 0.0,
         f"hit_rate={ps['hit_rate']:.3f} hits={ps['hits']:.0f} "
         f"uploads={ps['uploads']:.0f}")
    h2d = log.bytes.get(("host", "device", "kv"), 0.0)
    full = sum(s.fetched_chunks for s in eng.stats) * eng.store._transit_bytes()
    emit("engine/h2d_delta_vs_full_reupload", 0.0,
         f"delta={h2d:.0f}B full_would_be={full:.0f}B "
         f"saved={100 * (1 - h2d / max(full, 1)):.1f}%")
    eng.store.close()

    # shared-prefix audit: the same prompt admitted twice through the
    # content-addressable store — the second admission adopts the
    # resident chunks by reference and skips their prefill + tier bytes
    peng = BatchedLeoAMEngine(
        cfg, params, EngineCfg(max_len=256, gpu_chunk_frac=0.1,
                               cpu_chunk_frac=0.3, selection="tree",
                               prefix_cache=True,
                               prefill_chunk_tokens=64), max_seqs=2)
    prompt = rng.randint(2, cfg.vocab_size, 200)
    for _ in range(2):
        sid, tok = peng.add_sequence(prompt)
        cur = {sid: tok}
        for _ in range(4):
            cur = peng.decode_round(cur)
        peng.release(sid)
    ps = peng.store.prefix_stats()
    emit("engine/prefix/hit_rate", 0.0,
         f"hit_rate={ps['prefix_hit_rate']:.3f} "
         f"hits={ps['prefix_hit_chunks']:.0f} "
         f"misses={ps['prefix_miss_chunks']:.0f}")
    emit("engine/prefix/shared_chunks", 0.0,
         f"shared={ps['shared_chunks']:.0f} refs={ps['shared_refs']:.0f} "
         f"warm_admissions={ps['warm_admissions']:.0f}")
    emit("engine/prefix/bytes_deduped", 0.0,
         f"deduped={ps['bytes_deduped']:.0f}B "
         f"cow_copies={ps['cow_copies']:.0f} "
         f"prefix_ref_ops={peng.store.log.ops.get(('host', 'disk', 'prefix_ref'), 0):.0f}")
    peng.store.close()

    # fault-containment audit: a deterministic FaultPlan (one transient
    # disk error + one sidecar bitflip) against the same smoke engine —
    # the counters and the recovery billing kinds are the observable
    # residue of the degrade paths (docs/INVARIANTS.md I6)
    plan = FaultPlan(schedule={"disk_read": {0: "io_error"},
                               "sidecar_read": {1: "bitflip"}})
    feng = BatchedLeoAMEngine(
        cfg, params, EngineCfg(max_len=256, gpu_chunk_frac=0.1,
                               cpu_chunk_frac=0.3, selection="tree",
                               disk_sidecar=True, fault_plan=plan),
        max_seqs=1)
    sid, tok = feng.add_sequence(rng.randint(2, cfg.vocab_size, 200))
    cur = {sid: tok}
    for _ in range(6):
        cur = feng.decode_round(cur)
    fs = feng.fault_stats()
    flog = feng.store.log
    emit("engine/faults/io_retries", fs.get("io_retries", 0.0),
         f"injected=1io_error,plan_calls={plan.calls()}")
    emit("engine/faults/checksum_failures",
         fs.get("checksum_failures", 0.0),
         f"injected=1bitflip,degraded_seqs={fs.get('degraded_seqs', 0):.0f}")
    emit("engine/faults/chunks_recomputed",
         fs.get("chunks_recomputed", 0.0),
         f"recompute_bytes={flog.total(src=HOST, kind='kv_recompute'):.0f}B")
    emit("engine/faults/seqs_failed", fs.get("seqs_failed", 0.0),
         f"fallback_bytes={flog.total(src=DISK, kind='kv_fallback'):.0f}B")
    feng.store.close()
