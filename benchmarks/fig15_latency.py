"""Paper Fig. 15: end-to-end inference latency vs baselines at batch 1/4/8
(LongChat-7B and OPT-6.7B-class geometry; LongBench/PG-19-scale prompts).

Two parts:

* the paper-testbed latency **simulator** sweep (policy comparison at the
  full 7B geometry), and
* a **live-engine batch sweep** on the smoke model: B = 1, 4, 8 requests
  decoded by ONE BatchedLeoAMEngine round vs B sequential single-sequence
  engines, AND the pooled+pipelined engine (device-resident chunk pool,
  async DTP) vs the PR-1 synchronous full-re-upload engine on the same
  config — reporting tokens/s and bytes moved per tier, with the
  shared-log == Σ per-seq-log invariant checked on every run.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import emit
from repro.configs import get_config
from repro.models import lm
from repro.serving.engine import BatchedLeoAMEngine, EngineCfg, LeoAMEngine
from repro.serving.overload import LoadHarness, PressureMonitor, WatermarkCfg
from repro.serving.scheduler import ContinuousBatcher, Request, SchedulerCfg
from repro.serving.simulator import (HWCfg, POLICIES, ServeCfg,
                                     compare_policies, prefill_time,
                                     prefill_time_prefix,
                                     simulate_trace_goodput)
from repro.serving.trace import TraceCfg, gen_trace

PROMPT_LEN = 96
N_NEW = 8
MAX_LEN = 160


def _smoke_setup():
    cfg = get_config("longchat-7b-32k", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.3, early_rate=0.5,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _ecfg(**kw):
    return EngineCfg(max_len=MAX_LEN, selection="tree", **kw)


def _prompts(rng, cfg, batch):
    return [rng.randint(2, cfg.vocab_size, PROMPT_LEN) for _ in range(batch)]


def _run_sequential(cfg, params, prompts):
    """B independent single-sequence engines, one after another."""
    tiers = {}
    toks = 0
    decode_s = 0.0
    t0 = time.perf_counter()
    for p in prompts:
        eng = LeoAMEngine(cfg, params, _ecfg())
        tok = eng.prefill(p)
        toks += 1
        td = time.perf_counter()
        for _ in range(N_NEW - 1):
            tok = eng.decode_step(tok)
            toks += 1
        decode_s += time.perf_counter() - td
        for pair, b in eng.store.tier_bytes().items():
            tiers[pair] = tiers.get(pair, 0.0) + b
        eng.store.close()
    return time.perf_counter() - t0, decode_s, toks, tiers


def _run_batched(cfg, params, prompts, **ecfg_kw):
    """One batched engine, one shared store, one decode round per token."""
    t0 = time.perf_counter()
    eng = BatchedLeoAMEngine(cfg, params, _ecfg(**ecfg_kw),
                             max_seqs=len(prompts))
    toks = len(prompts)
    cur = {}
    for p in prompts:
        sid, tok = eng.add_sequence(p)
        cur[sid] = tok
    td = time.perf_counter()
    for _ in range(N_NEW - 1):
        cur = eng.decode_round(cur)
        toks += len(cur)
    decode_s = time.perf_counter() - td
    tiers = eng.store.tier_bytes()
    # accounting invariant: shared log == sum of per-sequence logs
    for key, v in eng.store.log.bytes.items():
        per_seq = sum(lg.bytes.get(key, 0.0)
                      for lg in eng.store.seq_logs.values())
        assert abs(v - per_seq) < 1e-6, (key, v, per_seq)
    eng.store.close()
    return time.perf_counter() - t0, decode_s, toks, tiers


def run_engine_batch_sweep() -> None:
    cfg, params = _smoke_setup()
    rng = np.random.RandomState(0)

    batches = (1, 2) if common.SMOKE else (1, 4, 8)
    reps = 2 if common.SMOKE else 3
    for batch in batches:
        prompts = _prompts(rng, cfg, batch)
        # first rep at each batch size doubles as warmup (jit caches are
        # shared between modes); best-of-reps damps scheduler noise
        runs_s = [_run_sequential(cfg, params, prompts) for _ in range(reps)]
        # PR-1 synchronous engine: full working-set re-upload per layer
        runs_p1 = [_run_batched(cfg, params, prompts, pooled=False,
                                pipeline=False) for _ in range(reps)]
        # tentpole engine: device-resident pool + async DTP overlap
        runs_b = [_run_batched(cfg, params, prompts) for _ in range(reps)]
        dt_s, dec_s, toks_s, tiers_s = min(runs_s[1:], key=lambda r: r[1])
        dt_1, dec_1, toks_1, tiers_1 = min(runs_p1[1:], key=lambda r: r[1])
        dt_b, dec_b, toks_b, tiers_b = min(runs_b[1:], key=lambda r: r[1])
        assert toks_s == toks_b == toks_1 == batch * N_NEW
        n_dec = batch * (N_NEW - 1)
        emit(f"fig15/engine/sequential/b{batch}", dt_s * 1e6,
             f"tput={toks_s / dt_s:.2f}tok_s,decode={n_dec / dec_s:.2f}tok_s")
        emit(f"fig15/engine/pr1_batched/b{batch}", dt_1 * 1e6,
             f"tput={toks_1 / dt_1:.2f}tok_s,decode={n_dec / dec_1:.2f}tok_s")
        emit(f"fig15/engine/batched/b{batch}", dt_b * 1e6,
             f"tput={toks_b / dt_b:.2f}tok_s,decode={n_dec / dec_b:.2f}tok_s")
        emit(f"fig15/engine/batched_speedup/b{batch}", 0.0,
             f"e2e={dt_s / dt_b:.2f}x,decode={dec_s / dec_b:.2f}x")
        emit(f"fig15/engine/pooled_vs_pr1/b{batch}", 0.0,
             f"e2e={dt_1 / dt_b:.2f}x,decode={dec_1 / dec_b:.2f}x")
        for pair in sorted(set(tiers_s) | set(tiers_b) | set(tiers_1)):
            emit(f"fig15/engine/bytes/{pair}/b{batch}", 0.0,
                 f"seq={tiers_s.get(pair, 0.0):.0f}B,"
                 f"pr1={tiers_1.get(pair, 0.0):.0f}B,"
                 f"bat={tiers_b.get(pair, 0.0):.0f}B")


def run_queued_admission() -> None:
    """Queued-arrival scenario: a request backlog drains through the
    continuous batcher with admission UNDER decode (prefill on the
    admission worker while rounds run) vs serial admission — TTFT for
    queued requests drops by roughly the decode time they no longer wait
    out, at equal token streams (tested)."""
    cfg, params = _smoke_setup()
    rng = np.random.RandomState(3)
    # decode-heavy backlog: generations long enough that admissions have
    # standing decode work to hide under (prompt 48 so prefill < decode)
    n_req, max_new = (4, 24) if common.SMOKE else (8, 32)
    prompts = [rng.randint(2, cfg.vocab_size, 48) for _ in range(n_req)]

    def drive(overlap: bool):
        # same slots + same per-layer pool budget in both modes: the
        # overlap win comes from scheduling, not extra device memory
        eng = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=3,
                                 device_chunk_budget=2 * MAX_LEN // 16)
        b = ContinuousBatcher(
            cfg=SchedulerCfg(max_active=2, chunk=cfg.leoam.chunk_size,
                             overlap_admission=overlap, prefill_ahead=1),
            engine=eng)
        for rid, p in enumerate(prompts):
            b.submit(Request(rid, p, max_new=max_new))
        b.run()
        stt = b.stats()
        eng.store.close()
        return stt

    drive(False)                       # jit warmup (both modes' shapes),
    drive(True)                        # discarded
    reps = 2 if common.SMOKE else 3
    s0 = min([drive(False) for _ in range(reps)],
             key=lambda s: s["mean_ttft_s"])
    s1 = min([drive(True) for _ in range(reps)],
             key=lambda s: s["mean_ttft_s"])
    emit("fig15/queued/serial/mean_ttft", s0["mean_ttft_s"] * 1e6,
         f"p50={s0['p50_ttft_s'] * 1e3:.0f}ms,"
         f"p95={s0['p95_ttft_s'] * 1e3:.0f}ms,"
         f"tput={s0['throughput_tok_s']:.2f}tok_s")
    emit("fig15/queued/overlap/mean_ttft", s1["mean_ttft_s"] * 1e6,
         f"p50={s1['p50_ttft_s'] * 1e3:.0f}ms,"
         f"p95={s1['p95_ttft_s'] * 1e3:.0f}ms,"
         f"tput={s1['throughput_tok_s']:.2f}tok_s")
    emit("fig15/queued/admission_under_decode_gain", 0.0,
         f"ttft={s0['mean_ttft_s'] / max(s1['mean_ttft_s'], 1e-12):.2f}x,"
         f"tput={s1['throughput_tok_s'] / max(s0['throughput_tok_s'], 1e-12):.2f}x")


def run_prefix_reuse() -> None:
    """Zipfian shared-prefix traffic through the content-addressable
    store: a small pool of "system prompts" drawn with skewed popularity,
    each followed by a unique suffix.  Warm requests adopt the resident
    prefix by reference — TTFT collapses to the cold-suffix cost and no
    tier holds duplicate bytes for the shared span (proved by replaying
    the identical trace with the cache off and comparing tier bytes)."""
    cfg, params = _smoke_setup()
    rng = np.random.RandomState(7)
    C = cfg.leoam.chunk_size                       # 16
    # 64 shared + 12 unique: the unique suffix ends mid-chunk, so warm
    # requests share a partial tail chunk and their first decode append
    # exercises copy-on-write
    prefix_tok, suffix_tok = 4 * C, C - 4
    n_prefix = 3
    n_req = 12 if common.SMOKE else 20
    n_dec = 3                                      # decode rounds per req
    prefixes = [rng.randint(2, cfg.vocab_size, prefix_tok)
                for _ in range(n_prefix)]
    # zipf-ish popularity: p(rank) ∝ 1/rank^1.2
    w = 1.0 / np.arange(1, n_prefix + 1) ** 1.2
    picks = rng.choice(n_prefix, size=n_req, p=w / w.sum())
    trace = [np.concatenate([prefixes[i],
                             rng.randint(2, cfg.vocab_size, suffix_tok)])
             for i in picks]

    def drive(prefix_cache: bool):
        eng = BatchedLeoAMEngine(
            cfg, params, _ecfg(prefill_chunk_tokens=2 * C,
                               prefix_cache=prefix_cache), max_seqs=2)
        warm_s, cold_s = [], []
        for prompt in trace:
            warm = (prefix_cache and eng.store.prefix_probe(prompt)
                    ["hit_tokens"] >= prefix_tok)
            t0 = time.perf_counter()
            sid, tok = eng.add_sequence(prompt)
            (warm_s if warm else cold_s).append(time.perf_counter() - t0)
            cur = {sid: tok}
            for _ in range(n_dec):
                cur = eng.decode_round(cur)
            eng.release(sid)
        stats = eng.store.prefix_stats()
        tiers = eng.store.tier_bytes()
        eng.store.close()
        return warm_s, cold_s, stats, tiers

    drive(True)                        # jit warmup (chunked prefill,
    drive(False)                       # warm resume + cold shapes)
    reps = 2 if common.SMOKE else 3
    warm_s, cold_s = [], []
    for _ in range(reps):
        w_s, c_s, stats, tiers_on = drive(True)
        warm_s += w_s
        cold_s += c_s
    _, _, _, tiers_off = drive(False)
    assert warm_s and cold_s, (len(warm_s), len(cold_s))
    ttft_warm = float(np.median(warm_s))
    ttft_cold = float(np.median(cold_s))
    ratio = ttft_warm / max(ttft_cold, 1e-12)
    # raw-value rows: the us column carries the quantity itself so the
    # baseline gate (check_baseline.py "bounds") can bound it directly
    emit("fig15/prefix/hit_rate", stats["prefix_hit_rate"],
         f"chunk_hits={stats['prefix_hit_chunks']:.0f}/"
         f"{stats['prefix_hit_chunks'] + stats['prefix_miss_chunks']:.0f},"
         f"warm_req={len(warm_s) // reps},cold_req={len(cold_s) // reps}")
    emit("fig15/prefix/ttft_warm", ttft_warm * 1e6,
         f"n={len(warm_s)},resume_chunks={prefix_tok // (2 * C)}")
    emit("fig15/prefix/ttft_cold", ttft_cold * 1e6, f"n={len(cold_s)}")
    emit("fig15/prefix/warm_over_cold", ratio,
         f"warm={ttft_warm * 1e3:.1f}ms,cold={ttft_cold * 1e3:.1f}ms")
    emit("fig15/prefix/disk_bytes_saved", stats["bytes_deduped"],
         f"cow_copies={stats['cow_copies']:.0f},"
         f"shared_chunks={stats['shared_chunks']:.0f}")
    # dedup proof: identical trace, cache on vs off, bytes per tier pair
    for pair in sorted(set(tiers_on) | set(tiers_off)):
        on, off = tiers_on.get(pair, 0.0), tiers_off.get(pair, 0.0)
        emit(f"fig15/prefix/bytes/{pair}", 0.0,
             f"cache_on={on:.0f}B,cache_off={off:.0f}B,"
             f"saved={max(off - on, 0.0):.0f}B")
    # model-vs-measured honesty check: the simulator's prefix-aware TTFT
    # at the trace's hit fraction, same geometry knobs as the live engine
    hit_frac = prefix_tok / (prefix_tok + suffix_tok)
    scfg = ServeCfg(batch=1, prompt=prefix_tok + suffix_tok, output=n_dec,
                    chunk=C, importance_rate=cfg.leoam.importance_rate)
    model = prefill_time_prefix(cfg, scfg, HWCfg(), hit_frac) \
        / max(prefill_time(cfg, scfg, HWCfg()), 1e-12)
    emit("fig15/prefix/model_warm_over_cold", model,
         f"measured={ratio:.2f},model={model:.2f},hit_frac={hit_frac:.2f}")


def run_overload() -> None:
    """Overload robustness: the same seeded arrival trace replayed three
    ways through the live batcher.  *Steady* paces arrivals in wall-clock
    time (the queue never builds); *burst* submits the whole trace up
    front — the preempting scheduler (PressureMonitor + priority
    preemption, shedding disabled via a high red watermark) must sustain
    >= 0.8x the steady goodput (gated); a *no-preemption baseline* with
    the legacy bounded queue replays the same burst and degrades by
    rejecting the overflow (ungated, reported for contrast).  A fourth
    row compares measured burst goodput with the analytic
    simulate_trace_goodput on the identical arrivals."""
    cfg, params = _smoke_setup()
    C = cfg.leoam.chunk_size
    n_req = 10 if common.SMOKE else 16
    max_new = 6
    tcfg = TraceCfg(n_requests=n_req, base_rate=8.0, burst_rate=8.0,
                    min_prompt=24, max_prompt=96, max_new=max_new,
                    scenario="chat", deadline_s=120.0,
                    priorities=(0, 0, 0, 1))
    trace = gen_trace(tcfg, seed=5)

    def drive(arrivals, *, preempt, time_scale):
        eng = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=3)
        # disk probe pinned huge: a nearly-full CI filesystem must not
        # trip the disk watermark and turn this into a shedding test
        mon = PressureMonitor(
            eng, WatermarkCfg(queue_yellow=2, queue_red=99),
            disk_free_fn=lambda: float(1 << 40)) if preempt else None
        scfg = SchedulerCfg(max_active=2, chunk=C,
                            **({} if preempt else {"max_queue": 4}))
        b = ContinuousBatcher(cfg=scfg, engine=eng, monitor=mon)
        res = LoadHarness(b, arrivals, time_scale=time_scale, seed=3,
                          vocab=cfg.vocab_size).run()
        eng.store.close()
        return res

    drive(trace[:2], preempt=True, time_scale=0.0)      # jit warmup
    s = drive(trace, preempt=True, time_scale=1.0)      # paced
    u = drive(trace, preempt=True, time_scale=0.0)      # all-at-once
    base = drive(trace, preempt=False, time_scale=0.0)  # bounded queue
    ratio = u["goodput"] / max(s["goodput"], 1e-12)
    unacc = max(r["requests_unaccounted"] for r in (s, u, base))
    # raw-value rows (quantity in the us column) so check_baseline
    # "bounds" can gate the ratio and the accounting invariant directly
    emit("fig15/overload/goodput_steady", s["goodput"],
         f"completed={s['requests_completed']:.0f}/"
         f"{s['requests_submitted']:.0f},"
         f"p99_ttft={s['p99_ttft_s'] * 1e3:.0f}ms")
    emit("fig15/overload/goodput_burst", u["goodput"],
         f"completed={u['requests_completed']:.0f}/"
         f"{u['requests_submitted']:.0f},"
         f"suspensions={u['suspensions']:.0f},"
         f"shed={u['requests_shed']:.0f},"
         f"p99_ttft={u['p99_ttft_s'] * 1e3:.0f}ms")
    emit("fig15/overload/burst_over_steady", ratio,
         f"burst={u['goodput']:.2f},steady={s['goodput']:.2f}")
    emit("fig15/overload/unaccounted", unacc,
         "completed+shed+failed==submitted_across_all_runs")
    emit("fig15/overload/baseline_burst_goodput", base["goodput"],
         f"max_queue=4,rejected={base['requests_shed']:.0f},"
         f"preempting={u['goodput']:.2f}")
    # analytic cross-check on the same all-at-once arrivals
    sim = simulate_trace_goodput(
        cfg, ServeCfg(batch=1, prompt=tcfg.max_prompt, output=max_new,
                      chunk=C),
        HWCfg(), [dataclasses.replace(a, t=0.0) for a in trace])
    emit("fig15/overload/sim_vs_measured_goodput", sim["goodput"],
         f"measured={u['goodput']:.2f},sim={sim['goodput']:.2f},"
         f"sim_mean_lat={sim['mean_latency_s'] * 1e3:.2f}ms")


def run() -> None:
    cfg = get_config("longchat-7b-32k")
    speedups = []
    for batch in ((1, 4) if common.SMOKE else (1, 4, 8)):
        scfg = ServeCfg(batch=batch, prompt=8192, output=128)
        res = compare_policies(cfg, scfg)
        base = min(res[p]["total_s"] for p in ("h2o", "h2o_chunked",
                                               "prefetch"))
        for p in POLICIES:
            emit(f"fig15/latency/{p}/b{batch}", res[p]["total_s"] * 1e6,
                 f"tput={res[p]['tokens_per_s']:.2f}tok_s")
        sp = base / res["leoam_all"]["total_s"]
        speedups.append(sp)
        emit(f"fig15/speedup_vs_best_baseline/b{batch}", 0.0, f"{sp:.2f}x")
    emit("fig15/speedup_avg", 0.0,
         f"{np.mean(speedups):.2f}x(paper:3.46x)")
    emit("fig15/speedup_max", 0.0,
         f"{np.max(speedups):.2f}x(paper:5.47x)")
    run_engine_batch_sweep()
    run_queued_admission()
    run_prefix_reuse()
    run_overload()
