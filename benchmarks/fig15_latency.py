"""Paper Fig. 15: end-to-end inference latency vs baselines at batch 1/4/8
(LongChat-7B and OPT-6.7B-class geometry; LongBench/PG-19-scale prompts)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.serving.simulator import POLICIES, ServeCfg, compare_policies


def run() -> None:
    cfg = get_config("longchat-7b-32k")
    speedups = []
    for batch in (1, 4, 8):
        scfg = ServeCfg(batch=batch, prompt=8192, output=128)
        res = compare_policies(cfg, scfg)
        base = min(res[p]["total_s"] for p in ("h2o", "h2o_chunked",
                                               "prefetch"))
        for p in POLICIES:
            emit(f"fig15/latency/{p}/b{batch}", res[p]["total_s"] * 1e6,
                 f"tput={res[p]['tokens_per_s']:.2f}tok_s")
        sp = base / res["leoam_all"]["total_s"]
        speedups.append(sp)
        emit(f"fig15/speedup_vs_best_baseline/b{batch}", 0.0, f"{sp:.2f}x")
    emit("fig15/speedup_avg", 0.0,
         f"{np.mean(speedups):.2f}x(paper:3.46x)")
    emit("fig15/speedup_max", 0.0,
         f"{np.max(speedups):.2f}x(paper:5.47x)")
