"""Paper Fig. 10 (tree-structured evaluation counts) and Fig. 11 / §6.5
(LKA transfer ratio r = α + 2/n' and abstract storage overhead)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.adaptive import (flat_chunk_select, pyramid_eval_count,
                                 tree_select)
from repro.core.tiers import abstract_overhead, lka_transfer_ratio


def _clustered(rng, n, n_clusters, width):
    s = np.abs(rng.randn(n)) * 0.01
    for _ in range(n_clusters):
        c = rng.randint(0, n - width)
        s[c:c + width] += np.abs(rng.randn(width)) * 3 + 1
    return s + rng.rand(n) * 1e-9


def run() -> None:
    # Fig. 10: evaluations at token / fixed-chunk / tree level
    for n, label in ((2048, "2k"), (32768, "32k")):
        evs_tree, evs_flat = [], []
        for seed in range(10):
            s = _clustered(np.random.RandomState(seed), n,
                           n_clusters=max(4, n // 400), width=32)
            budget = int(0.05 * n)
            evs_tree.append(tree_select(s, budget, 64).evaluations)
            evs_flat.append(flat_chunk_select(s, budget, 64).evaluations)
        emit(f"fig10/evals_token/{label}", 0.0, f"n={n}")
        emit(f"fig10/evals_chunk64/{label}", 0.0,
             f"n={int(np.mean(evs_flat))}")
        emit(f"fig10/evals_leoam_tree/{label}", 0.0,
             f"n={int(np.mean(evs_tree))} ({n / np.mean(evs_tree):.1f}x fewer than token)")
        dev = pyramid_eval_count(4, n // 64, int(0.1 * n // 64))
        emit(f"fig10/evals_pyramid_device/{label}", 0.0, f"n={dev}")
    # Fig. 11: LKA transfer ratio
    for alpha in (0.05, 0.1, 0.2):
        for chunk in (16, 32, 64, 128):
            emit(f"fig11/lka_ratio/a{alpha}/c{chunk}", 0.0,
                 f"r={lka_transfer_ratio(alpha, chunk):.4f}")
    emit("sec6.5/abstract_storage_overhead/c64", 0.0,
         f"{abstract_overhead(64) * 100:.2f}%(paper:<1.6%)")
