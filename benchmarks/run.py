"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
Roofline terms come from the dry-run artifacts — see
``python -m repro.launch.roofline`` (EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import os
import sys
import traceback

# make `python benchmarks/run.py` work from anywhere: the repo root (for
# the benchmarks package) and src/ (for repro) join sys.path
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def main() -> None:
    # measurement hygiene: never produce measured rows with the sync-
    # sanitizer live — its owning-thread/epoch/lock-order checks would be
    # folded into every checked-in baseline number.  The sanitizer's own
    # overhead is measured explicitly by fig13/debug_sync/{on,off}.
    from repro.serving import sanitizer
    if sanitizer.active():
        raise SystemExit(
            "benchmarks/run.py: the sync-sanitizer is active (debug_sync "
            "engine live or REPRO_DEBUG_SYNC=1) — refusing to emit measured "
            "numbers; unset REPRO_DEBUG_SYNC / close debug engines first")
    from benchmarks import (common, engine_audit, fig4_5_overheads,
                            fig7_8_desert, fig10_11_evals, fig13_pipeline,
                            fig14_quality, fig15_latency, fig16_17_breakdown,
                            fig18_19_sensitivity, kernels_micro)
    args = sys.argv[1:]
    if "--smoke" in args:            # cheapest config per fig (CI tier)
        args.remove("--smoke")
        common.set_smoke(True)
    sys.argv = [sys.argv[0]] + args
    print("name,us_per_call,derived")
    modules = [
        ("fig4_5", fig4_5_overheads), ("fig7_8", fig7_8_desert),
        ("fig10_11", fig10_11_evals), ("fig13", fig13_pipeline),
        ("fig14", fig14_quality), ("fig15", fig15_latency),
        ("fig16_17", fig16_17_breakdown), ("fig18_19", fig18_19_sensitivity),
        ("kernels", kernels_micro), ("engine", engine_audit),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failed = []
    for name, mod in modules:
        if only and only not in name:
            continue
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
