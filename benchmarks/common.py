"""Shared benchmark utilities: timing + CSV row emission.

Every benchmark prints ``name,us_per_call,derived`` rows (the harness
contract); ``derived`` carries the figure-specific quantity (speedup,
ratio, rate, ...).
"""

from __future__ import annotations

import os
import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []

# --smoke (benchmarks/run.py) flips this: every fig module runs only its
# cheapest configuration — the CI sanity tier, not a measurement.
SMOKE = bool(int(os.environ.get("REPRO_BENCH_SMOKE", "0")))


def set_smoke(on: bool = True) -> None:
    global SMOKE
    SMOKE = on


def emit(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (device-synced)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
