"""CLI: ``python -m repro.analysis [--strict] [--passes a,b] [paths...]``.

Exit status: 0 when every finding is waived (or there are none); 1 when
unwaived findings remain.  ``--strict`` additionally fails on malformed
waiver pragmas (they are reported either way) and is what CI runs.
"""

from __future__ import annotations

import argparse
import sys

from repro.analysis.core import PASS_IDS, run_passes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="leolint: concurrency/billing contract checker for "
                    "the tiered serving engine")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to check (default: src)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on malformed waiver pragmas")
    ap.add_argument("--passes", default=",".join(PASS_IDS),
                    help=f"comma-separated subset of {PASS_IDS}")
    ap.add_argument("--show-waived", action="store_true",
                    help="also print waived findings (audit view)")
    args = ap.parse_args(argv)

    passes = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = [p for p in passes if p not in PASS_IDS]
    if unknown:
        ap.error(f"unknown pass(es): {unknown}; choose from {PASS_IDS}")

    findings, _index = run_passes(args.paths, passes)
    live = [f for f in findings if not f.waived and f.pass_id != "waiver"]
    malformed = [f for f in findings if f.pass_id == "waiver"]
    waived = [f for f in findings if f.waived]

    for f in live + malformed:
        print(f.render())
    if args.show_waived:
        for f in waived:
            print(f.render())
    print(f"leolint: {len(live)} finding(s), {len(waived)} waived, "
          f"{len(malformed)} malformed waiver(s)", file=sys.stderr)

    if live:
        return 1
    if malformed and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
