"""locklint: nothing slow, reentrant, or blocking under the store lock.

The tiered store's ``_lock`` serializes tier-table metadata; the
write-behind design only works because everything held under it is cheap
host work.  Three rule families:

1. **No JAX dispatch / device sync / memmap flush under a lock** — a
   ``jnp.*`` / ``jax.*`` call, ``.block_until_ready()``, or ``.flush()``
   holds the lock across device work or disk I/O, stalling every worker
   that needs to land a write.
2. **No fence (or future wait) reachable under the store lock** —
   ``ingest_fence*`` waits on executor futures whose work items need the
   store lock to land writes: fence-under-lock is a deadlock, not a
   slowdown.  ``.result()`` on a future is flagged for the same reason.
3. **Lock-order acyclicity** — every nested ``with <lock>`` acquisition
   (including locks a callee acquires while the caller holds one) records
   an edge; a cycle anywhere in the graph (e.g. ``_lock`` →
   ``_futs_lock`` at one site and the reverse at another) is an ABBA
   deadlock, reported at the edge that closes the cycle.

Findings anchor where the lock is held: a direct violation at its own
line, and a call under a lock into a *lock-sensitive* callee (one that
transitively dispatches JAX / syncs / fences / waits) at the **call
site** — the function that owns the lock context carries the waiver, not
the innocent leaf (``compression.quantize`` is fine on the prefetch
executor; it is ``fetch_chunks_pooled`` that chooses to call it under
``_lock``)."""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import (Finding, FuncInfo, Index, jit_reachable,
                                 jit_roots, scoped_lock_name, walk_in_func)

PASS_ID = "locklint"

#: attribute calls that synchronize with device or disk
_SYNC_ATTRS = {"block_until_ready", "flush"}
#: attribute calls that wait on executor futures
_WAIT_ATTRS = {"result"}
#: receivers whose attribute calls dispatch JAX work
_JAX_RECEIVERS = {"jax", "jnp", "lax"}


def _call_label(call: ast.Call) -> str:
    try:
        return ast.unparse(call.func)
    except Exception:  # pragma: no cover - unparse is total on py>=3.9
        return "<call>"


def _is_fence_name(expr: ast.AST) -> bool:
    if isinstance(expr, ast.Attribute):
        return expr.attr.startswith("ingest_fence")
    if isinstance(expr, ast.Name):
        return expr.id.startswith("ingest_fence")
    return False


def _jax_receiver(expr: ast.AST) -> Optional[str]:
    """'jnp' for ``jnp.stack(...)`` style calls, walking nested attributes
    (``jax.random.split`` → 'jax')."""
    while isinstance(expr, ast.Attribute):
        expr = expr.value
    if isinstance(expr, ast.Name) and expr.id in _JAX_RECEIVERS:
        return expr.id
    return None


def _walk_expr(node: ast.AST) -> Iterable[ast.AST]:
    """Walk an expression tree without entering lambda bodies (those are
    separate functions and execute at call time, not here)."""
    stack = [node]
    while stack:
        n = stack.pop()
        if isinstance(n, ast.Lambda):
            continue
        yield n
        stack.extend(ast.iter_child_nodes(n))


def _local_op(call: ast.Call, jitted: Dict[FuncInfo, str],
              tgts: List[FuncInfo]) -> Optional[str]:
    """Short description if this call is itself slow/blocking, else None."""
    label = _call_label(call)
    if _jax_receiver(call.func) is not None:
        return f"dispatches JAX (`{label}`)"
    if isinstance(call.func, ast.Attribute):
        if call.func.attr in _SYNC_ATTRS:
            return f"blocks on device/disk (`.{call.func.attr}()`)"
        if call.func.attr in _WAIT_ATTRS and not call.args:
            return "waits on a future (`.result()`)"
    if _is_fence_name(call.func):
        return f"waits on ingest workers (`{label}()`)"
    for t in tgts:
        if t in jitted:
            return f"calls jitted `{t.qualname}`"
    return None


class _Analysis:
    """Per-index lock analysis state (sensitivity + acquired-locks
    fixpoints are memoized across the whole run)."""

    def __init__(self, index: Index):
        self.index = index
        self.jitted = jit_reachable(index, jit_roots(index))
        self._sens: Dict[FuncInfo, Optional[str]] = {}
        self._acq: Dict[FuncInfo, Set[str]] = {}

    # -- transitive "dangerous to call under a lock" ---------------------
    def sensitivity(self, fi: FuncInfo) -> Optional[str]:
        """Description of the first slow/blocking op reachable from
        ``fi`` (ignoring lock context — the caller supplies that), or
        None if the whole call tree is cheap host work."""
        if fi in self._sens:
            return self._sens[fi]
        self._sens[fi] = None          # cycle guard: assume clean
        if fi in self.jitted:
            self._sens[fi] = f"is jitted ({self.jitted[fi]})"
            return self._sens[fi]
        for call, tgts in self.index.calls_in(fi):
            op = _local_op(call, self.jitted, tgts)
            if op is not None:
                self._sens[fi] = (f"{op} at "
                                  f"{fi.module.name}:{call.lineno}")
                return self._sens[fi]
        for call, tgts in self.index.calls_in(fi):
            for t in tgts:
                sub = self.sensitivity(t)
                if sub is not None:
                    self._sens[fi] = f"via {t.qualname}: {sub}"
                    return self._sens[fi]
        return self._sens[fi]

    # -- transitive acquired-lock set ------------------------------------
    def acquired(self, fi: FuncInfo) -> Set[str]:
        if fi in self._acq:
            return self._acq[fi]
        self._acq[fi] = set()          # cycle guard
        out: Set[str] = set()
        for node in walk_in_func(fi.node):
            if isinstance(node, ast.With):
                for item in node.items:
                    ln = scoped_lock_name(item.context_expr, fi)
                    if ln is not None:
                        out.add(ln)
        for _call, tgts in self.index.calls_in(fi):
            for t in tgts:
                out |= self.acquired(t)
        self._acq[fi] = out
        return out


def _scan_function(ana: _Analysis, fi: FuncInfo,
                   findings: List[Finding],
                   edge_sites: List[Tuple[str, str, str, int]]) -> None:
    index, jitted = ana.index, ana.jitted

    def check_call(call: ast.Call, locks: Tuple[str, ...]) -> None:
        if not locks:
            return
        held = locks[-1]
        tgts = index.resolve(call.func, fi)
        op = _local_op(call, jitted, tgts)
        if op is not None:
            findings.append(Finding(
                fi.module.path, call.lineno, PASS_ID,
                f"{op} under lock '{held}' — "
                f"{'deadlock: the waited-on work needs this lock' if 'wait' in op else 'stalls every worker that needs the lock'}"))
            return
        for t in tgts:
            sub = ana.sensitivity(t)
            if sub is not None:
                findings.append(Finding(
                    fi.module.path, call.lineno, PASS_ID,
                    f"call to `{t.qualname}` under lock '{held}' — "
                    f"callee {sub}"))
                break
        for t in tgts:
            for ln in ana.acquired(t):
                if ln != held:
                    edge_sites.append((held, ln, fi.module.path,
                                       call.lineno))

    def scan_exprs_of(st: ast.stmt, locks: Tuple[str, ...]) -> None:
        for child in ast.iter_child_nodes(st):
            if isinstance(child, (ast.stmt, ast.excepthandler)):
                continue
            for node in _walk_expr(child):
                if isinstance(node, ast.Call):
                    check_call(node, locks)

    def scan_stmts(stmts, locks: Tuple[str, ...]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue  # nested defs scanned as their own functions
            if isinstance(st, ast.With):
                inner = locks
                for item in st.items:
                    for node in _walk_expr(item.context_expr):
                        if isinstance(node, ast.Call):
                            check_call(node, locks)
                    ln = scoped_lock_name(item.context_expr, fi)
                    if ln is not None:
                        if inner:
                            edge_sites.append((inner[-1], ln,
                                               fi.module.path,
                                               item.context_expr.lineno))
                        inner = inner + (ln,)
                scan_stmts(st.body, inner)
                continue
            scan_exprs_of(st, locks)
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(st, field, None)
                if isinstance(sub, list):
                    scan_stmts([s for s in sub if isinstance(s, ast.stmt)],
                               locks)
            for h in getattr(st, "handlers", None) or []:
                scan_stmts(h.body, locks)

    body = fi.node.body if not isinstance(fi.node, ast.Lambda) \
        else [ast.Expr(fi.node.body)]
    scan_stmts(body, ())


def run(index: Index) -> List[Finding]:
    ana = _Analysis(index)
    findings: List[Finding] = []
    edge_sites: List[Tuple[str, str, str, int]] = []
    for fi in index.functions:
        _scan_function(ana, fi, findings, edge_sites)

    edges: Dict[str, Set[str]] = {}
    for a, b, _p, _line in edge_sites:
        edges.setdefault(a, set()).add(b)

    def path(src: str, dst: str) -> bool:
        stk, vis = [src], set()
        while stk:
            n = stk.pop()
            if n == dst:
                return True
            if n in vis:
                continue
            vis.add(n)
            stk.extend(edges.get(n, ()))
        return False

    reported: Set[Tuple[str, str]] = set()
    for a, b, p, line in edge_sites:
        if a == b:
            continue
        if path(b, a) and (a, b) not in reported and (b, a) not in reported:
            reported.add((a, b))
            findings.append(Finding(
                p, line, PASS_ID,
                f"lock-order cycle: '{a}' -> '{b}' here, but a "
                f"'{b}' -> … -> '{a}' acquisition exists elsewhere — "
                f"ABBA deadlock"))
    return findings
