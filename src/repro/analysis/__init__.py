"""leolint — repo-specific static checker for the tiered serving engine.

Four passes over the AST + call graph (stdlib ``ast`` only):

========== ==============================================================
locklint   no JAX dispatch / device sync / memmap flush / fence / future
           wait while the store lock may be held; lock acquisition order
           acyclic
threadlint executor-submitted work never reaches ``@decode_thread_only``
           code
billlint   replica/sidecar writes and disk→host promotions pair with a
           billing call from the transfer↔bill table, in-function
jitlint    no clocks, Python RNG, locks, or Python-state mutation inside
           (or reachable from) ``jax.jit``-traced functions
========== ==============================================================

Run as ``python -m repro.analysis [--strict] [paths...]``; findings are
suppressible only via ``# leolint: waive[pass] reason=...`` pragmas (see
``docs/INVARIANTS.md``).
"""

from repro.analysis.core import (Finding, Index, PASS_IDS,  # noqa: F401
                                 run_passes)
