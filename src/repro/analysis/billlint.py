"""billlint: every byte that crosses a tier boundary is billed where it
crosses.

PRs 2–3 proved "billed == crossed exactly" dynamically (byte-parity
tests); this pass enforces the property structurally so a new write path
cannot merge without its billing call.  The contract is a pairing table:

* a **write** to a disk-replica / sidecar memmap (``self._disk[...] =``,
  ``self._disk_q[...] =``, ``self._disk_scale[...] =``) must pair, in the
  same function, with a HOST→DISK billing call;
* a **read** (subscript load) of those memmaps is a disk→host promotion
  and must pair with a DISK→HOST billing call;
* every billing call's *kind* must be one the table knows for its
  direction — an unknown (src, dst, kind) triple is itself a finding, so
  the table stays the single source of truth.

A billing call is a ``self._record(seq, SRC, DST, kind, nbytes)`` or
``<log>.record(SRC, DST, kind, nbytes)`` whose tier arguments are the
module-level ``DEVICE`` / ``HOST`` / ``DISK`` constants and whose kind is
a string literal.  Coalesced helpers that intentionally delegate billing
to their callers (e.g. ``_read_sidecar``) carry an explanatory
``# leolint: waive[billlint] reason=...``.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from repro.analysis.core import Finding, FuncInfo, Index, walk_in_func

PASS_ID = "billlint"

#: memmap attributes whose subscript writes/reads are tier crossings
TRACKED_ATTRS = ("_disk", "_disk_q", "_disk_scale", "_pq_codes",
                 "_pq_codebook")

_TIERS = {"DEVICE", "HOST", "DISK"}

#: direction -> transfer kinds the billing schema knows.  Extending the
#: schema means extending this table (and docs/INVARIANTS.md) in the same
#: change — that is the point.
ALLOWED_KINDS = {
    # prefix_ref: by-reference warm-prefix adoption — bills ZERO bytes
    # (one op per adopted chunk, for audit); cow_copy/cow_read: the two
    # halves of a copy-on-write privatization (read the shared replica,
    # write the private one — exactly one chunk each way per layer);
    # kv_shared: a refcounted promotion of a shared chunk (same bytes as
    # "kv", attributed to the reading sequence, phys row ≠ seq row).
    # kv_recompute: a replica rebuilt from a prompt replay after checksum
    # rejection (same landing as "kv_replica", distinct kind so audits
    # can separate recovery traffic from first-write traffic);
    # kv_fallback: an fp16-replica promotion serving in place of a
    # quarantined packed sidecar (lossless degrade — full replica bytes
    # where the sidecar read would have been cheaper).
    # kv_swapout/kv_swapin: whole-sequence preemption — suspend drops a
    # victim's host copies (the write-through replica is already current,
    # so kv_swapout is a ZERO-byte audit op per released chunk, like
    # prefix_ref), and resume re-stages exactly those chunks disk→host
    # (CRC-verified read; kv_swapin bills the bytes that really cross).
    # pq_codes_write/pq_codes_read: the PQ abstract plane — uint8
    # nearest-centroid codes landing next to (not instead of) the min/max
    # boxes at cold ingest / requant re-encode, and the per-round code
    # gather that replaces an "abstract" read for code-valid disk chunks
    # (a degraded chunk bills "abstract" instead, so fallbacks are
    # visible in the ledger).
    ("HOST", "DISK"): {"kv_replica", "kv_append", "sidecar_repack",
                       "abstract", "prefix_ref", "cow_copy",
                       "kv_recompute", "kv_swapout", "pq_codes_write"},
    ("DISK", "HOST"): {"kv", "abstract", "sidecar_repack_read",
                       "kv_shared", "cow_read", "kv_fallback",
                       "kv_swapin", "pq_codes_read"},
    ("HOST", "DEVICE"): {"kv", "kv_append", "abstract", "kv_shared"},
    ("DEVICE", "HOST"): {"kv", "kv_append"},
}


def _tier_name(expr: ast.AST) -> Optional[str]:
    if isinstance(expr, ast.Name) and expr.id in _TIERS:
        return expr.id
    if isinstance(expr, ast.Attribute) and expr.attr in _TIERS:
        return expr.attr
    return None


def _tracked_attr(expr: ast.AST) -> Optional[str]:
    """'_disk' for a ``<anything>._disk[...]`` subscript base."""
    if isinstance(expr, ast.Subscript) \
            and isinstance(expr.value, ast.Attribute) \
            and expr.value.attr in TRACKED_ATTRS:
        return expr.value.attr
    return None


def _billing_calls(fi: FuncInfo) -> List[Tuple[int, str, str, Optional[str]]]:
    """(line, src, dst, kind-or-None) for every record/_record call whose
    consecutive-arg pair is two tier constants."""
    out = []
    for node in walk_in_func(fi.node):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("record", "_record")):
            continue
        args = node.args
        for i in range(len(args) - 1):
            src, dst = _tier_name(args[i]), _tier_name(args[i + 1])
            if src and dst:
                kind = None
                if i + 2 < len(args) \
                        and isinstance(args[i + 2], ast.Constant) \
                        and isinstance(args[i + 2].value, str):
                    kind = args[i + 2].value
                out.append((node.lineno, src, dst, kind))
                break
    return out


def run(index: Index) -> List[Finding]:
    findings: List[Finding] = []
    for fi in index.functions:
        if isinstance(fi.node, ast.Lambda):
            continue
        writes: List[Tuple[int, str]] = []
        reads: List[Tuple[int, str]] = []
        for node in walk_in_func(fi.node):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    attr = _tracked_attr(t)
                    if attr:
                        writes.append((node.lineno, attr))
            elif isinstance(node, ast.Subscript) \
                    and isinstance(node.ctx, ast.Load):
                attr = _tracked_attr(node)
                if attr:
                    reads.append((node.lineno, attr))
        bills = _billing_calls(fi)
        if writes or reads or bills:
            dirs: Set[Tuple[str, str]] = {(s, d) for _, s, d, _ in bills}
            for line, attr in writes:
                if ("HOST", "DISK") not in dirs:
                    findings.append(Finding(
                        fi.module.path, line, PASS_ID,
                        f"write to `{attr}` (host→disk replica/sidecar "
                        f"bytes) in {fi.qualname} with no HOST→DISK "
                        f"billing call in the same function"))
            for line, attr in reads:
                if ("DISK", "HOST") not in dirs:
                    findings.append(Finding(
                        fi.module.path, line, PASS_ID,
                        f"read of `{attr}` (disk→host promotion) in "
                        f"{fi.qualname} with no DISK→HOST billing call "
                        f"in the same function"))
            for line, src, dst, kind in bills:
                allowed = ALLOWED_KINDS.get((src, dst))
                if allowed is None:
                    findings.append(Finding(
                        fi.module.path, line, PASS_ID,
                        f"billing direction {src}→{dst} is not in the "
                        f"transfer↔bill pairing table"))
                elif kind is not None and kind not in allowed:
                    findings.append(Finding(
                        fi.module.path, line, PASS_ID,
                        f"billing kind '{kind}' is not a known "
                        f"{src}→{dst} transfer (table: "
                        f"{sorted(allowed)}) — extend "
                        f"billlint.ALLOWED_KINDS with the new transfer "
                        f"class"))
    return findings
