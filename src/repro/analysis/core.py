"""leolint core: module index, call graph, waiver pragmas, findings.

``leolint`` is a repo-specific static checker (stdlib ``ast`` only — no
third-party deps, so it runs anywhere CI does) for the tiered serving
engine's concurrency and billing contracts.  This module holds the shared
machinery; the four passes (:mod:`locklint`, :mod:`threadlint`,
:mod:`billlint`, :mod:`jitlint`) are thin rule sets over it:

* **Module index** — every analyzed file parsed once; every function
  (methods, nested defs, lambdas) registered as a :class:`FuncInfo` with
  its ownership decoration, enclosing class, and per-module import map.
* **Call resolution** — name-based, deliberately over-approximate where
  types are unknown: ``self.x(...)`` resolves within the enclosing class,
  ``alias.f(...)`` through the import map, bare names lexically then at
  module scope, and ``anything.m(...)`` to every analyzed class method
  named ``m`` (capped — a miss is an under-approximation, which a linter
  with waivers prefers over false certainty).
* **Waivers** — findings are suppressible ONLY via an inline pragma::

      # leolint: waive[pass1,pass2] reason=why this is safe

  attached to the flagged line, the comment line directly above it, or
  the enclosing ``def`` line (function-scoped waiver).  A waive without a
  ``reason=`` is itself reported: every exception stays auditable.
"""

from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

PASS_IDS = ("locklint", "threadlint", "billlint", "jitlint")

OWNERSHIP_DECORATORS = ("decode_thread_only", "worker_thread", "any_thread")
DECODE_ONLY_NAME = "decode_thread_only"

#: attribute (or bare-name) identifiers treated as locks by lock rules
LOCK_NAME_RE = re.compile(r"^_(?:[a-z0-9_]*_)?lock$")

WAIVE_RE = re.compile(
    r"#\s*leolint:\s*waive\[([a-zA-Z0-9_,\s*]+)\]\s*(?:reason\s*=\s*(.*\S))?")


@dataclass
class Finding:
    path: str
    line: int
    pass_id: str
    message: str
    waived: bool = False
    reason: str = ""

    def render(self) -> str:
        tag = f" (waived: {self.reason})" if self.waived else ""
        return f"{self.path}:{self.line}: [{self.pass_id}] {self.message}{tag}"


@dataclass
class FuncInfo:
    module: "Module"
    node: ast.AST                    # FunctionDef / AsyncFunctionDef / Lambda
    name: str
    qualname: str
    cls: Optional[str]
    ownership: Optional[str]
    line: int
    parent: Optional["FuncInfo"] = None
    locals_: Dict[str, "FuncInfo"] = field(default_factory=dict)

    def __hash__(self):
        return id(self.node)

    def __eq__(self, other):
        return isinstance(other, FuncInfo) and other.node is self.node

    def __repr__(self):
        return f"<{self.module.name}:{self.qualname}>"


class Module:
    """One parsed source file plus its waiver table and import map."""

    def __init__(self, path: str, source: str, name: Optional[str] = None):
        self.path = path
        self.name = name or _module_name(path)
        self.tree = ast.parse(source, filename=path)
        self.lines = source.splitlines()
        # line -> {pass_id -> reason}; pass id "*" waives every pass
        self.waivers: Dict[int, Dict[str, str]] = {}
        self.malformed: List[Tuple[int, str]] = []
        # alias -> dotted module name (import x as y / from pkg import mod)
        self.mod_aliases: Dict[str, str] = {}
        # name -> (module dotted name, attr) for `from pkg import fn`
        self.from_imports: Dict[str, Tuple[str, str]] = {}
        self._parse_waivers()
        self._parse_imports()

    def _parse_waivers(self) -> None:
        # only genuine COMMENT tokens count — pragma-looking text inside
        # docstrings / string literals (e.g. this checker's own docs) is
        # neither a waiver nor malformed
        src = "\n".join(self.lines) + "\n"
        try:
            tokens = tokenize.generate_tokens(io.StringIO(src).readline)
            comments = [(t.start[0], t.string) for t in tokens
                        if t.type == tokenize.COMMENT]
        except tokenize.TokenError:  # pragma: no cover - ast.parse ran
            comments = []
        for i, text in comments:
            m = WAIVE_RE.search(text)
            if not m:
                if "leolint" in text and "waive" in text:
                    self.malformed.append((i, text.strip()))
                continue
            passes = [p.strip() for p in m.group(1).split(",") if p.strip()]
            reason = (m.group(2) or "").strip()
            if not reason or not passes:
                self.malformed.append((i, text.strip()))
                continue
            slot = self.waivers.setdefault(i, {})
            for p in passes:
                slot[p] = reason

    def _parse_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.mod_aliases[a.asname or a.name.split(".")[0]] = \
                        a.name
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    self.from_imports[a.asname or a.name] = (node.module,
                                                             a.name)

    def waiver_for(self, line: int, pass_id: str,
                   def_line: Optional[int] = None) -> Optional[str]:
        """Reason string if ``line`` (or its pragma-carrying neighbors /
        enclosing def) waives ``pass_id``; None otherwise."""
        for cand in self._waiver_lines(line, def_line):
            slot = self.waivers.get(cand)
            if slot:
                r = slot.get(pass_id) or slot.get("*")
                if r:
                    return r
        return None

    def _waiver_lines(self, line: int, def_line: Optional[int]
                      ) -> Iterable[int]:
        yield line
        # a standalone comment line directly above the statement
        j = line - 1
        while j >= 1 and j > line - 4 \
                and self.lines[j - 1].lstrip().startswith("#"):
            yield j
            j -= 1
        if def_line is not None and def_line != line:
            yield def_line


def _module_name(path: str) -> str:
    """Dotted module name from a path (rooted at a ``src`` dir when one is
    on the path, else the bare stem — fixtures)."""
    norm = os.path.normpath(os.path.abspath(path))
    parts = norm.split(os.sep)
    stem = [p for p in parts if p]
    if "src" in stem:
        stem = stem[stem.index("src") + 1:]
    else:
        stem = stem[-1:]
    if stem and stem[-1].endswith(".py"):
        stem[-1] = stem[-1][:-3]
    if stem and stem[-1] == "__init__":
        stem = stem[:-1]
    return ".".join(stem)


def _decorator_name(dec: ast.AST) -> Optional[str]:
    if isinstance(dec, ast.Name):
        return dec.id
    if isinstance(dec, ast.Attribute):
        return dec.attr
    if isinstance(dec, ast.Call):
        return _decorator_name(dec.func)
    return None


class Index:
    """Cross-module function index + call graph resolution."""

    #: cap for untyped ``obj.m(...)`` fan-out — beyond it the name is too
    #: generic to mean anything and edges would be noise
    METHOD_MATCH_CAP = 4

    def __init__(self, modules: Sequence[Module]):
        self.modules = list(modules)
        self.by_module: Dict[str, Module] = {m.name: m for m in modules}
        self.functions: List[FuncInfo] = []
        # simple name -> FuncInfos (methods and module-level separately)
        self.methods: Dict[str, List[FuncInfo]] = {}
        self.mod_level: Dict[Tuple[str, str], FuncInfo] = {}
        self.cls_methods: Dict[Tuple[str, str, str], FuncInfo] = {}
        for m in modules:
            self._index_module(m)

    # -- construction ---------------------------------------------------
    def _index_module(self, mod: Module) -> None:
        def visit(node, cls: Optional[str], parent: Optional[FuncInfo],
                  prefix: str):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    own = None
                    for dec in child.decorator_list:
                        d = _decorator_name(dec)
                        if d in OWNERSHIP_DECORATORS:
                            own = d
                    qn = f"{prefix}{child.name}"
                    fi = FuncInfo(mod, child, child.name, qn, cls, own,
                                  child.lineno, parent)
                    self._register(fi)
                    if parent is not None:
                        parent.locals_[child.name] = fi
                    visit(child, cls, fi, qn + ".<locals>.")
                elif isinstance(child, ast.ClassDef):
                    visit(child, child.name, None, child.name + ".")
                elif isinstance(child, ast.Lambda):
                    self._index_lambda(child, mod, cls, parent, prefix)
                else:
                    # lambdas nested in arbitrary statements (jit roots)
                    for sub in ast.walk(child):
                        if isinstance(sub, ast.Lambda):
                            self._index_lambda(sub, mod, cls, parent, prefix)

        visit(mod.tree, None, None, "")

    def _index_lambda(self, node: ast.Lambda, mod: Module,
                      cls: Optional[str], parent: Optional[FuncInfo],
                      prefix: str) -> None:
        fi = FuncInfo(mod, node, "<lambda>",
                      f"{prefix}<lambda@{node.lineno}>", cls, None,
                      node.lineno, parent)
        self._register(fi)

    def _register(self, fi: FuncInfo) -> None:
        self.functions.append(fi)
        if fi.cls is not None:
            self.methods.setdefault(fi.name, []).append(fi)
            self.cls_methods[(fi.module.name, fi.cls, fi.name)] = fi
        elif fi.parent is None and fi.name != "<lambda>":
            self.mod_level[(fi.module.name, fi.name)] = fi

    def func_of(self, node: ast.AST) -> Optional[FuncInfo]:
        for fi in self.functions:
            if fi.node is node:
                return fi
        return None

    # -- resolution -----------------------------------------------------
    def resolve(self, expr: ast.AST, ctx: FuncInfo) -> List[FuncInfo]:
        """Possible targets of calling ``expr`` from inside ``ctx``."""
        if isinstance(expr, ast.Name):
            return self._resolve_name(expr.id, ctx)
        if isinstance(expr, ast.Attribute):
            return self._resolve_attr(expr, ctx)
        if isinstance(expr, ast.Lambda):
            fi = self.func_of(expr)
            return [fi] if fi else []
        return []

    def _resolve_name(self, name: str, ctx: FuncInfo) -> List[FuncInfo]:
        scope = ctx
        while scope is not None:               # lexical nested defs
            if name in scope.locals_:
                return [scope.locals_[name]]
            scope = scope.parent
        fi = self.mod_level.get((ctx.module.name, name))
        if fi is not None:
            return [fi]
        imp = ctx.module.from_imports.get(name)
        if imp is not None:
            tgt = self.mod_level.get(imp)
            if tgt is not None:
                return [tgt]
        return []

    def _resolve_attr(self, expr: ast.Attribute, ctx: FuncInfo
                      ) -> List[FuncInfo]:
        attr, value = expr.attr, expr.value
        if isinstance(value, ast.Name):
            if value.id in ("self", "cls") and ctx.cls is not None:
                fi = self.cls_methods.get((ctx.module.name, ctx.cls, attr))
                if fi is not None:
                    return [fi]
            # module alias: exact resolution through the import map
            dotted = ctx.module.mod_aliases.get(value.id)
            if dotted is None:
                imp = ctx.module.from_imports.get(value.id)
                if imp is not None:
                    dotted = f"{imp[0]}.{imp[1]}"
            if dotted is not None:
                fi = self.mod_level.get((dotted, attr))
                return [fi] if fi is not None else []
        # untyped receiver: every analyzed class method with this name
        cands = self.methods.get(attr, [])
        if 0 < len(cands) <= self.METHOD_MATCH_CAP:
            return list(cands)
        return []

    # -- traversal helpers ----------------------------------------------
    def calls_in(self, fi: FuncInfo) -> List[Tuple[ast.Call,
                                                   List[FuncInfo]]]:
        """All Call nodes lexically inside ``fi`` (excluding nested defs),
        with their resolved targets (possibly empty)."""
        out = []
        for node in walk_in_func(fi.node):
            if isinstance(node, ast.Call):
                out.append((node, self.resolve(node.func, fi)))
        return out


def walk_in_func(fn_node: ast.AST) -> Iterable[ast.AST]:
    """ast.walk over a function body, NOT descending into nested function
    definitions or lambdas (they are separate FuncInfos)."""
    body = fn_node.body if not isinstance(fn_node, ast.Lambda) \
        else [fn_node.body]
    stack = list(body) if isinstance(body, list) else [body]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            stack.append(child)


def lock_name_of(expr: ast.AST) -> Optional[str]:
    """Lock identifier of a ``with`` context expr (or ``None``): matches
    ``self._lock`` / ``obj._futs_lock`` attribute locks and bare
    ``_x_lock`` module-level names.  Attribute locks are scoped by the
    receiver when it is a plain name so distinct classes' ``_lock``\\ s do
    not alias in the order graph."""
    if isinstance(expr, ast.Attribute) and LOCK_NAME_RE.match(expr.attr):
        return expr.attr
    if isinstance(expr, ast.Name) and LOCK_NAME_RE.match(expr.id):
        return expr.id
    return None


def scoped_lock_name(expr: ast.AST, ctx: FuncInfo) -> Optional[str]:
    base = lock_name_of(expr)
    if base is None:
        return None
    if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name) \
            and expr.value.id == "self" and ctx.cls is not None:
        return f"{ctx.cls}.{base}"
    if isinstance(expr, ast.Name):
        return f"{ctx.module.name}.{base}"
    return base


# ----------------------------------------------------------------------
# Jit root detection (shared by jitlint and locklint's dispatch rule)
# ----------------------------------------------------------------------
def _is_jax_jit(expr: ast.AST) -> bool:
    """True for ``jax.jit`` / ``jit`` (imported from jax) references and
    ``functools.partial(jax.jit, ...)`` wrappers."""
    if isinstance(expr, ast.Attribute) and expr.attr == "jit":
        return True
    if isinstance(expr, ast.Name) and expr.id == "jit":
        return True
    if isinstance(expr, ast.Call):
        fn = expr.func
        if isinstance(fn, ast.Attribute) and fn.attr == "partial" \
                or isinstance(fn, ast.Name) and fn.id == "partial":
            return any(_is_jax_jit(a) for a in expr.args)
    return False


def jit_roots(index: Index) -> Dict[FuncInfo, str]:
    """Every function that is jit-compiled: decorated with ``jax.jit`` (or
    a ``functools.partial(jax.jit, ...)``), passed to a ``jax.jit(...)``
    call (names, attributes, inline lambdas), or — for the factory pattern
    ``jax.jit(make_step(...))`` — every nested def of the factory.
    Returns {func: how it became a root} for messages."""
    roots: Dict[FuncInfo, str] = {}
    for fi in index.functions:
        node = fi.node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _is_jax_jit(dec):
                    roots.setdefault(fi, "decorated with jax.jit")
    for fi in index.functions:
        for call, _tgts in index.calls_in(fi):
            if not _is_jax_jit(call.func):
                continue
            if not call.args:
                continue
            arg = call.args[0]
            for tgt in index.resolve(arg, fi):
                roots.setdefault(tgt, f"passed to jax.jit in "
                                      f"{fi.qualname}")
            if isinstance(arg, ast.Call):     # jax.jit(factory(...))
                for fac in index.resolve(arg.func, fi):
                    for nested in fac.locals_.values():
                        roots.setdefault(
                            nested, f"returned by factory {fac.qualname} "
                                    f"passed to jax.jit")
    # module-level jit calls: `step_fn = jax.jit(...)` outside any def
    for m in index.modules:
        ctx = FuncInfo(m, m.tree, "<module>", "<module>", None, None, 1,
                       None)
        for node in walk_in_func(m.tree):
            if not (isinstance(node, ast.Call) and _is_jax_jit(node.func)
                    and node.args):
                continue
            arg = node.args[0]
            for tgt in index.resolve(arg, ctx):
                roots.setdefault(tgt, f"passed to jax.jit at module level "
                                      f"of {m.name}")
            if isinstance(arg, ast.Call):
                for fac in index.resolve(arg.func, ctx):
                    for nested in fac.locals_.values():
                        roots.setdefault(
                            nested, f"returned by factory {fac.qualname} "
                                    f"passed to jax.jit")
    return roots


def jit_reachable(index: Index, roots: Dict[FuncInfo, str]
                  ) -> Dict[FuncInfo, str]:
    """Transitive closure of the jit roots over the call graph: a callee
    of a jitted function traces inside it."""
    out = dict(roots)
    work = list(roots)
    while work:
        fi = work.pop()
        via = out[fi]
        for _call, tgts in index.calls_in(fi):
            for t in tgts:
                if t not in out:
                    out[t] = f"called from jitted {fi.qualname}"
                    work.append(t)
    return out


# ----------------------------------------------------------------------
# File collection / pass driver
# ----------------------------------------------------------------------
def collect_files(paths: Sequence[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                for n in sorted(names):
                    if n.endswith(".py"):
                        files.append(os.path.join(root, n))
        elif p.endswith(".py"):
            files.append(p)
    return files


def load_modules(paths: Sequence[str]) -> List[Module]:
    mods = []
    for f in collect_files(paths):
        with open(f, encoding="utf-8") as fh:
            src = fh.read()
        mods.append(Module(f, src))
    return mods


def apply_waivers(findings: List[Finding], index: Index,
                  def_lines: Optional[Dict[Tuple[str, int], int]] = None
                  ) -> List[Finding]:
    """Mark findings waived where a matching pragma covers them."""
    by_path = {m.path: m for m in index.modules}
    for f in findings:
        mod = by_path.get(f.path)
        if mod is None:
            continue
        dl = (def_lines or {}).get((f.path, f.line))
        reason = mod.waiver_for(f.line, f.pass_id, dl)
        if reason:
            f.waived, f.reason = True, reason
    return findings


def enclosing_def_lines(index: Index) -> Dict[Tuple[str, int], int]:
    """(path, line) -> def line of the innermost enclosing function, for
    function-scoped waivers."""
    out: Dict[Tuple[str, int], int] = {}
    for fi in index.functions:
        node = fi.node
        end = getattr(node, "end_lineno", None) or node.lineno
        for line in range(node.lineno, end + 1):
            key = (fi.module.path, line)
            # innermost wins: later (nested) functions overwrite only if
            # they start later
            prev = out.get(key)
            if prev is None or node.lineno >= prev:
                out[key] = node.lineno
    return out


def run_passes(paths: Sequence[str],
               passes: Optional[Sequence[str]] = None
               ) -> Tuple[List[Finding], Index]:
    """Load ``paths``, run the requested passes (default: all four), apply
    waivers, and append malformed-waiver findings.  Returns (findings,
    index)."""
    from repro.analysis import billlint, jitlint, locklint, threadlint
    table = {"locklint": locklint.run, "threadlint": threadlint.run,
             "billlint": billlint.run, "jitlint": jitlint.run}
    mods = load_modules(paths)
    index = Index(mods)
    findings: List[Finding] = []
    for pid in (passes or PASS_IDS):
        findings.extend(table[pid](index))
    findings = apply_waivers(findings, index, enclosing_def_lines(index))
    for mod in mods:
        for line, text in mod.malformed:
            findings.append(Finding(
                mod.path, line, "waiver",
                f"malformed waiver pragma (need "
                f"`# leolint: waive[pass] reason=...` with a non-empty "
                f"reason): {text!r}"))
    findings.sort(key=lambda f: (f.path, f.line, f.pass_id))
    return findings, index
