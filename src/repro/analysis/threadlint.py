"""threadlint: executor work must never reach decode-thread-only code.

The device pool slab (and the engine's slot bookkeeping) are read by the
jitted decode step WITHOUT the store lock; the contract that makes that
safe is "the decode thread is the sole mutator".  This pass makes the
contract structural:

* **Entry points** are every first argument of an ``executor.submit(...)``
  call (``_admit``, the nested prefetch ``work``, ``_ingest_cold``,
  ``_requant_chunks``, the checkpoint writer) plus every function
  decorated ``@worker_thread``.
* From each entry the pass walks the call graph in *worker context*; a
  reachable call into a ``@decode_thread_only`` function is a finding at
  the call site (one example path from the entry is included in the
  message).  ``@any_thread`` and undecorated functions are traversed.
* Functions explicitly decorated ``@decode_thread_only`` are not
  descended into (the first bad edge is the bug; everything below it is
  noise).

Legitimate deferred-fold sites (worker defers a pool mutation through
``pending_place`` for the decode thread to apply) are expected to carry a
``# leolint: waive[threadlint] reason=...`` pragma explaining why the
edge is never taken in worker context.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.core import (DECODE_ONLY_NAME, Finding, FuncInfo, Index)

PASS_ID = "threadlint"


def _submit_entries(index: Index) -> List[Tuple[FuncInfo, str]]:
    """(entry function, description) for every ``*.submit(fn, ...)``."""
    out: List[Tuple[FuncInfo, str]] = []
    for fi in index.functions:
        for call, _tgts in index.calls_in(fi):
            if not (isinstance(call.func, ast.Attribute)
                    and call.func.attr == "submit" and call.args):
                continue
            for tgt in index.resolve(call.args[0], fi):
                out.append((tgt, f"submitted to an executor in "
                                 f"{fi.qualname} "
                                 f"({fi.module.name}:{call.lineno})"))
    return out


def run(index: Index) -> List[Finding]:
    entries: List[Tuple[FuncInfo, str]] = _submit_entries(index)
    for fi in index.functions:
        if fi.ownership == "worker_thread":
            entries.append((fi, "decorated @worker_thread"))

    findings: List[Finding] = []
    flagged: Set[Tuple[str, int, FuncInfo]] = set()
    # (visited func) -> already walked in worker context (entry-agnostic:
    # the first entry to reach a function claims it; findings are per call
    # site so coverage is unaffected)
    visited: Set[FuncInfo] = set()

    for entry, how in entries:
        if entry.ownership == DECODE_ONLY_NAME:
            findings.append(Finding(
                entry.module.path, entry.line, PASS_ID,
                f"{entry.qualname} is @decode_thread_only but is used as a "
                f"worker entry point ({how})"))
            continue
        stack: List[Tuple[FuncInfo, str]] = [(entry, entry.qualname)]
        while stack:
            fi, chain = stack.pop()
            if fi in visited:
                continue
            visited.add(fi)
            for call, tgts in index.calls_in(fi):
                for t in tgts:
                    if t.ownership == DECODE_ONLY_NAME:
                        key = (fi.module.path, call.lineno, t)
                        if key in flagged:
                            continue
                        flagged.add(key)
                        findings.append(Finding(
                            fi.module.path, call.lineno, PASS_ID,
                            f"call into decode-thread-only "
                            f"`{t.qualname}` reachable from worker entry "
                            f"`{entry.qualname}` ({how}) via {chain}"))
                    else:
                        stack.append((t, f"{chain} -> {t.qualname}"))
    return findings
