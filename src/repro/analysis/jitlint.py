"""jitlint: traced functions stay pure.

``jax.jit`` traces a function ONCE per input shape/dtype signature and
replays the compiled XLA program thereafter.  Side effects inside the
traced region therefore run at trace time only — a ``time.time()`` reads
the clock once and bakes the value in, a lock acquisition protects only
the first call, a ``self.x = ...`` mutation silently stops happening.
This pass walks every function that is jit-compiled (decorator, explicit
``jax.jit(f)`` call, inline lambda, or factory pattern) plus everything
reachable from one through the call graph, and flags:

* ``time.*`` calls (stale-clock values baked into the trace);
* Python-level RNG (``np.random.*``, ``random.*`` — traced once, the
  "random" stream is a constant; use ``jax.random`` with explicit keys);
* lock acquisition (``with <lock>`` / ``.acquire()`` — protects only the
  trace, then silently stops synchronizing);
* Python-state mutation: attribute assignment, subscript assignment into
  an attribute-held container, ``global`` / ``nonlocal`` declarations.
"""

from __future__ import annotations

import ast
from typing import Dict, List

from repro.analysis.core import (Finding, FuncInfo, Index, jit_reachable,
                                 jit_roots, lock_name_of, walk_in_func)

PASS_ID = "jitlint"

_RNG_MODULES = {"random"}


def _dotted_root(expr: ast.AST) -> List[str]:
    """['np', 'random', 'randint'] for ``np.random.randint``."""
    parts: List[str] = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if isinstance(expr, ast.Name):
        parts.append(expr.id)
    return parts[::-1]


def run(index: Index) -> List[Finding]:
    reach: Dict[FuncInfo, str] = jit_reachable(index, jit_roots(index))
    findings: List[Finding] = []
    for fi, how in reach.items():
        path = fi.module.path
        ctx = f"`{fi.qualname}` is traced ({how})"
        for node in walk_in_func(fi.node):
            if isinstance(node, ast.Call):
                parts = _dotted_root(node.func)
                if len(parts) >= 2 and parts[0] == "time":
                    findings.append(Finding(
                        path, node.lineno, PASS_ID,
                        f"`{'.'.join(parts)}()` inside a jitted function — "
                        f"the clock is read once at trace time; {ctx}"))
                elif len(parts) >= 2 and (
                        parts[0] in _RNG_MODULES
                        or (parts[0] in ("np", "numpy")
                            and len(parts) >= 3 and parts[1] == "random")):
                    findings.append(Finding(
                        path, node.lineno, PASS_ID,
                        f"Python RNG `{'.'.join(parts)}()` inside a jitted "
                        f"function — traced once, the stream is constant; "
                        f"use jax.random with an explicit key; {ctx}"))
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "acquire" \
                        and lock_name_of(node.func.value) is not None:
                    findings.append(Finding(
                        path, node.lineno, PASS_ID,
                        f"lock `.acquire()` inside a jitted function — "
                        f"synchronizes the trace only; {ctx}"))
            elif isinstance(node, ast.With):
                for item in node.items:
                    if lock_name_of(item.context_expr) is not None:
                        findings.append(Finding(
                            path, item.context_expr.lineno, PASS_ID,
                            f"lock held inside a jitted function — "
                            f"synchronizes the trace only; {ctx}"))
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    if isinstance(t, ast.Attribute):
                        findings.append(Finding(
                            path, node.lineno, PASS_ID,
                            f"attribute assignment "
                            f"`{_safe_unparse(t)} = ...` inside a jitted "
                            f"function — Python-state mutation happens at "
                            f"trace time only; {ctx}"))
                    elif isinstance(t, ast.Subscript) \
                            and isinstance(t.value, ast.Attribute):
                        findings.append(Finding(
                            path, node.lineno, PASS_ID,
                            f"subscript store into attribute "
                            f"`{_safe_unparse(t)}` inside a jitted "
                            f"function — mutation happens at trace time "
                            f"only; {ctx}"))
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                findings.append(Finding(
                    path, node.lineno, PASS_ID,
                    f"`{'global' if isinstance(node, ast.Global) else 'nonlocal'}"
                    f" {', '.join(node.names)}` inside a jitted function; "
                    f"{ctx}"))
    return findings


def _safe_unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover
        return "<expr>"
