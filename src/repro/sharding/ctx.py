"""Trace-time sharding-constraint context.

Model code is mesh-agnostic; launch code activates a mesh+rules context while
tracing, and ``constrain(x, logical_axes)`` resolves logical axes to a
``with_sharding_constraint`` (no-op outside the context, e.g. CPU unit
tests).  This is how activation-sharding decisions (vocab-sharded logits,
sequence-parallel residual streams) stay in one place.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding import partition as pt

_CTX: contextvars.ContextVar = contextvars.ContextVar("shard_ctx", default=None)


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check_vma: bool = False):
    """Version shim: ``jax.shard_map`` (new) vs ``jax.experimental.shard_map``
    (pinned 0.4.x, where ``check_vma`` is spelled ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, rules: Optional[Dict] = None):
    tok = _CTX.set((mesh, rules or pt.DEFAULT_RULES))
    try:
        yield
    finally:
        _CTX.reset(tok)


def current_mesh() -> Optional[Mesh]:
    v = _CTX.get()
    return v[0] if v else None


def constrain(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    """Apply a logical-axis sharding constraint if a context is active."""
    v = _CTX.get()
    if v is None:
        return x
    mesh, rules = v
    spec = pt.spec_for(tuple(x.shape), axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_priority(x: jax.Array, *options: Tuple[Optional[str], ...]
                       ) -> jax.Array:
    """Constrain with the first option that shards the most dims.

    Used for attention activations: shard q-heads over ``model`` when the
    head count divides, otherwise fall back to sequence sharding — keeps
    every arch's attention distributed on the fixed 16-way model axis
    without per-arch special cases.
    """
    v = _CTX.get()
    if v is None:
        return x
    mesh, rules = v
    best, best_n = None, -1
    for axes in options:
        spec = pt.spec_for(tuple(x.shape), axes, mesh, rules)
        n = sum(e is not None for e in spec)
        if n > best_n:
            best, best_n = spec, n
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, best))
