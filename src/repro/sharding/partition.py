"""Logical-axis -> mesh-axis resolution with divisibility fallback.

One rule table serves all ten architectures; when a tensor dim is not
divisible by the mesh extent of its mapped axes (e.g. 8 KV heads on a 16-way
``model`` axis) the mapping silently degrades to replication on that dim —
the scheme every fixed-mesh production system needs for heterogeneous archs.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# Logical axis -> mesh axes.  "fsdp" below means the composed batch axes
# (("pod","data") on the multi-pod mesh, ("data",) on a single pod).
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "embed": ("fsdp",),
    "ffn": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "batch": ("fsdp",),
    "seq": (),               # activations: sequence replicated by default
    "act_seq": ("model",),   # sequence-parallel activations (SP / CP)
    "act_embed": (),         # activations: d_model replicated (TP collects)
    "kv_seq": ("model",),    # decode KV cache: sequence sharded over model
    "layer": (),
}


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def rules_for(cfg, extra: Optional[Dict[str, Tuple[str, ...]]] = None
              ) -> Dict[str, Tuple[str, ...]]:
    """Arch-specific rules: small archs replicate params over data (pure
    TP+DP, no per-layer weight gathers); frontier archs FSDP-shard them."""
    rules = dict(DEFAULT_RULES)
    if not cfg.runtime.fsdp_params:
        rules["embed"] = ()
    if extra:
        rules.update(extra)
    return rules


def _resolve(axis: Optional[str], mesh: Mesh,
             rules: Dict[str, Tuple[str, ...]]) -> Tuple[str, ...]:
    if axis is None:
        return ()
    out: Tuple[str, ...] = ()
    for a in rules.get(axis, ()):
        out += fsdp_axes(mesh) if a == "fsdp" else ((a,) if a in mesh.axis_names else ())
    return out

def mesh_extent(mesh: Mesh, axes: Sequence[str]) -> int:
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None) -> P:
    """PartitionSpec for one tensor; drops mesh axes that don't divide."""
    rules = rules or DEFAULT_RULES
    used: set = set()
    entries = []
    for dim, ax in zip(shape, axes):
        maxes = _resolve(ax, mesh, rules)
        # trim to divisible prefix, skipping axes already used by another dim
        keep: Tuple[str, ...] = ()
        ext = 1
        for m in maxes:
            if m in used:
                continue
            if dim % (ext * mesh.shape[m]) == 0:
                keep += (m,)
                ext *= mesh.shape[m]
        used.update(keep)
        entries.append(keep if len(keep) > 1 else (keep[0] if keep else None))
    return P(*entries)


def spec_tree(axes_tree: Any, shape_tree: Any, mesh: Mesh,
              rules: Optional[Dict[str, Tuple[str, ...]]] = None) -> Any:
    """Map (axes, shapes) trees -> PartitionSpec tree."""
    return jax.tree.map(
        lambda ax, s: spec_for(tuple(s.shape), ax, mesh, rules),
        axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def sharding_tree(axes_tree: Any, shape_tree: Any, mesh: Mesh,
                  rules: Optional[Dict[str, Tuple[str, ...]]] = None) -> Any:
    specs = spec_tree(axes_tree, shape_tree, mesh, rules)
    return jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes a training/prefill global batch shards over."""
    return fsdp_axes(mesh)


def seq_shard_axes(mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
    """Axes the decode KV sequence shards over.

    Batch takes as much of the fsdp product as it can; whatever batch cannot
    absorb (plus the model axis) shards the KV sequence — for ``long_500k``
    (batch 1) the sequence is sharded over every mesh axis.
    """
    axes = ["model"] if "model" in mesh.axis_names else []
    b = global_batch
    for a in reversed(fsdp_axes(mesh)):      # consume inner axes for batch first
        if b % mesh.shape[a] == 0:
            b //= mesh.shape[a]
        else:
            axes.append(a)
    return tuple(axes)


def decode_batch_axes(mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
    axes = []
    b = global_batch
    for a in reversed(fsdp_axes(mesh)):
        if b % mesh.shape[a] == 0:
            b //= mesh.shape[a]
            axes.append(a)
    return tuple(reversed(axes))
