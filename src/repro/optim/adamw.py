"""AdamW with dtype-configurable moment states and global-norm clipping.

Moment dtype matters at frontier scale: f32 m/v for a 340B model is 2.7 TB of
optimizer state; bf16 moments halve it (the nemotron/jamba configs opt in via
``runtime.adam_dtype``).  States are sharded exactly like their parameters
(FSDP), so the optimizer update is fully local — no optimizer collectives.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWCfg:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"


def init_opt_state(params: Any, cfg: AdamWCfg) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(params: Any, cfg: AdamWCfg) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype)
    sd = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {
        "m": jax.tree.map(sd, params),
        "v": jax.tree.map(sd, params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def apply_updates(params: Any, grads: Any, state: Dict[str, Any],
                  cfg: AdamWCfg, lr: jax.Array, grad_scale=1.0
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  ``lr`` is the scheduled learning rate (traced).

    ``grad_scale`` (e.g. 1/num_microbatches) and the clip rescale are folded
    into the per-leaf update so no full-tree f32 gradient copy is ever
    materialized — at 340B that copy alone is 5.3 GiB/device.
    """
    metrics: Dict[str, jax.Array] = {}
    scale = jnp.asarray(grad_scale, jnp.float32)
    if cfg.clip_norm is not None:
        gnorm = global_norm(grads) * grad_scale
        scale = scale * jnp.minimum(1.0, cfg.clip_norm
                                    / jnp.maximum(gnorm, 1e-12))
        metrics["grad_norm"] = gnorm
    step = state["step"] + 1
    sdt = jnp.dtype(cfg.state_dtype)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m32.astype(sdt), v32.astype(sdt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    params_new = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics["param_norm"] = global_norm(params_new)
    return params_new, {"m": m_new, "v": v_new, "step": step}, metrics
