"""Seeded bursty arrival traces shared by the analytic simulator and the
measured load harness.

Public serving traffic is neither Poisson-smooth nor length-uniform: load
arrives in bursts (an MMPP — Markov-modulated Poisson process — with a
calm and a burst state captures the on/off character real traces show)
and prompt lengths are heavy-tailed (most requests are short chat turns,
a zipfian tail stretches to RAG contexts and whole-document prompts).
This module generates such traces deterministically from one integer
seed, so the analytic simulator (:mod:`repro.serving.simulator`) and the
measured :class:`~repro.serving.overload.LoadHarness` replay the *same*
arrival sequence — the fig15 simulator-vs-measured goodput row compares
like with like.

Scenarios shape the prompt-length mix:

=============  =========================================================
``chat``       short turns: zipfian lengths over the bottom quarter of
               the configured range
``rag``        retrieval contexts: the middle of the range
``longdoc``    whole-document prompts: the top half of the range
``mixed``      60% chat / 30% rag / 10% longdoc per arrival — the
               public-traffic blend the overload bench replays
=============  =========================================================
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["Arrival", "TraceCfg", "gen_trace"]

_SCENARIOS = ("chat", "rag", "longdoc", "mixed")

#: zipf ranks are capped here and mapped geometrically onto the
#: scenario's length band — rank 1 (the common case) lands at the short
#: end, the capped tail at the long end
_ZIPF_RANK_CAP = 64


@dataclass(frozen=True)
class Arrival:
    """One request of a trace: arrival time (seconds from trace start),
    prompt length and decode budget in tokens, scheduling class, and an
    optional per-request latency deadline."""

    t: float
    prompt_len: int
    max_new: int
    priority: int = 0
    deadline_s: Optional[float] = None


@dataclass
class TraceCfg:
    n_requests: int = 64
    base_rate: float = 4.0         # req/s in the calm MMPP state
    burst_rate: float = 32.0       # req/s in the burst state
    calm_dwell_s: float = 2.0      # mean dwell per calm episode
    burst_dwell_s: float = 0.5     # mean dwell per burst episode
    zipf_a: float = 1.4            # prompt-length tail exponent (>1;
                                   # smaller = heavier tail)
    min_prompt: int = 32
    max_prompt: int = 512
    max_new: int = 16
    scenario: str = "mixed"        # chat | rag | longdoc | mixed
    deadline_s: Optional[float] = None
    priorities: Tuple[int, ...] = (0,)
                                   # scheduling classes drawn uniformly
                                   # per arrival (e.g. (0, 0, 0, 1) for a
                                   # 25% high-priority slice)

    def __post_init__(self) -> None:
        if self.scenario not in _SCENARIOS:
            raise ValueError(f"unknown scenario {self.scenario!r} "
                             f"(one of {_SCENARIOS})")
        if not (self.zipf_a > 1.0):
            raise ValueError(
                f"zipf_a={self.zipf_a} must be > 1 (numpy's zipf sampler "
                f"requires it; 1.2–2.0 spans realistic tails)")
        if self.min_prompt < 1 or self.max_prompt < self.min_prompt:
            raise ValueError(
                f"need 1 <= min_prompt <= max_prompt, got "
                f"[{self.min_prompt}, {self.max_prompt}]")


def _length_band(cfg: TraceCfg, scenario: str) -> Tuple[int, int]:
    lo, hi = cfg.min_prompt, cfg.max_prompt
    if scenario == "chat":
        return lo, max(lo, hi // 4)
    if scenario == "rag":
        return max(lo, hi // 4), max(lo, hi // 2)
    return max(lo, hi // 2), hi        # longdoc


def _prompt_len(cfg: TraceCfg, rng: np.random.RandomState) -> int:
    scenario = cfg.scenario
    if scenario == "mixed":
        scenario = ("chat", "rag", "longdoc")[
            int(rng.choice(3, p=[0.6, 0.3, 0.1]))]
    lo, hi = _length_band(cfg, scenario)
    if hi <= lo:
        return lo
    rank = min(int(rng.zipf(cfg.zipf_a)), _ZIPF_RANK_CAP)
    frac = (rank - 1) / (_ZIPF_RANK_CAP - 1)
    # geometric interpolation keeps the tail heavy in LENGTH, not just
    # in rank: rank 1 -> lo, the capped tail -> hi
    return int(round(lo * (hi / lo) ** frac))


def gen_trace(cfg: TraceCfg, seed: int = 0) -> List[Arrival]:
    """Deterministic MMPP arrival trace: exponential state dwells switch
    between the calm and burst Poisson rates; each arrival draws a
    zipfian prompt length from its scenario band and a uniform priority
    class.  Two calls with the same (cfg, seed) return identical traces
    (the contract the simulator-vs-measured comparison relies on)."""
    rng = np.random.RandomState(int(seed) & 0x7FFFFFFF)
    out: List[Arrival] = []
    t = 0.0
    burst = False
    t_switch = rng.exponential(cfg.calm_dwell_s)
    while len(out) < cfg.n_requests:
        rate = cfg.burst_rate if burst else cfg.base_rate
        dt = rng.exponential(1.0 / max(rate, 1e-9))
        if t + dt >= t_switch:
            # state flip BEFORE the next arrival would land: re-draw the
            # interarrival under the new rate from the switch instant
            t = t_switch
            burst = not burst
            t_switch = t + rng.exponential(
                cfg.burst_dwell_s if burst else cfg.calm_dwell_s)
            continue
        t += dt
        out.append(Arrival(
            t=t,
            prompt_len=_prompt_len(cfg, rng),
            max_new=cfg.max_new,
            priority=int(cfg.priorities[
                int(rng.randint(len(cfg.priorities)))]),
            deadline_s=cfg.deadline_s))
    return out
