"""Overload control: resource-pressure watermarks and a trace-driven
load harness.

A single commodity GPU serving long-context traffic saturates three
resources long before compute: device pool slots (the working-set arena),
host KV bytes (the staging tier), and disk free space (the write-through
replica tier).  :class:`PressureMonitor` samples all three plus the
admission-queue depth every scheduler round and folds them into one of
three watermark states:

* **green** — headroom everywhere: admit freely, resume preempted work;
* **yellow** — some signal crossed its soft watermark: the scheduler
  pauses admission (resource pressure) or preempts low-priority work
  (queue pressure) — see ``ContinuousBatcher._apply_pressure``;
* **red** — a hard watermark crossed: queued requests shed with a
  structured :class:`~repro.serving.faults.RejectedOverload`.

The state STRINGS are the contract with the scheduler (it mirrors them as
``_GREEN/_YELLOW/_RED`` rather than importing this module, so this module
can import the scheduler for :class:`LoadHarness` without a cycle).

The monitor is also a fault site (``"pressure"``): a
:class:`~repro.serving.faults.FaultPlan` can force watermark transitions
(``latency`` ⇒ at least yellow, ``io_error`` ⇒ red) without any real
resource being exhausted — the chaos tests drive the whole
preempt/shed/resume path deterministically that way.

:class:`LoadHarness` replays a seeded bursty trace
(:func:`repro.serving.trace.gen_trace`) against the REAL
:class:`~repro.serving.scheduler.ContinuousBatcher` in wall-clock time
and reports p50/p99 TTFT, throughput and **goodput** — the fraction of
submitted requests that completed within their deadline.  Its numbers are
directly comparable with the analytic
:func:`repro.serving.simulator.simulate_trace_goodput` run on the same
trace (the fig15 simulator-vs-measured row).
"""

from __future__ import annotations

import shutil
import time
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Optional, Set, Tuple

import numpy as np

from repro.serving.sanitizer import any_thread
from repro.serving.scheduler import ContinuousBatcher, Request
from repro.serving.trace import Arrival

__all__ = ["GREEN", "YELLOW", "RED", "WatermarkCfg", "PressureMonitor",
           "LoadHarness"]

# watermark states — string values mirrored by scheduler._GREEN/_YELLOW/
# _RED (the contract; see module docstring)
GREEN, YELLOW, RED = "green", "yellow", "red"

_SEVERITY = {GREEN: 0, YELLOW: 1, RED: 2}


@dataclass
class WatermarkCfg:
    """Soft (yellow) and hard (red) watermarks per pressure signal.

    Defaults are deliberately permissive: the pool-fraction gates are OFF
    (a full pool is NORMAL steady state — the pool evicts LRU; a strict
    ``< 0.0`` never fires), the host-byte gates are unbounded, and the
    disk gates sit low enough that only a genuinely full filesystem
    trips them.  Production/test setups tighten whichever signals they
    actually want to react to."""

    pool_free_yellow: float = 0.0      # pool free-slot FRACTION below
    pool_free_red: float = 0.0         # which the state trips (strict <;
                                       # 0.0 = disabled)
    host_bytes_yellow: float = float("inf")
    host_bytes_red: float = float("inf")
                                       # store.host_bytes() above which
                                       # the staging tier is pressured
    disk_free_yellow: float = 64 << 20 # disk free bytes BELOW which the
    disk_free_red: float = 16 << 20    # replica tier is at risk
    queue_yellow: int = 8              # admission-queue depth; red
    queue_red: int = 32                # shedding drains back down to the
                                       # yellow watermark


class PressureMonitor:
    """Samples device-pool occupancy, host staging bytes, disk free
    space and queue depth against :class:`WatermarkCfg`; returns the
    WORST state crossed plus the set of signal names that crossed
    (``{"pool", "host", "disk", "queue", "forced"}``).

    ``disk_free_fn`` overrides the ``shutil.disk_usage(store._root)``
    probe (tests inject scripted values); ``fault_plan`` hooks the
    ``"pressure"`` site — a planned ``latency`` fault forces at least
    yellow, ``io_error`` forces red (the site never raises)."""

    def __init__(self, engine, cfg: Optional[WatermarkCfg] = None, *,
                 fault_plan=None,
                 disk_free_fn: Optional[Callable[[], float]] = None):
        self.engine = engine
        self.cfg = cfg or WatermarkCfg()
        self.faults = fault_plan
        self._disk_free_fn = disk_free_fn
        self.samples = 0
        self.forced = 0                # fault-injected transitions
        self.state_counts: Dict[str, int] = {GREEN: 0, YELLOW: 0, RED: 0}
        self.last_signals: Dict[str, float] = {}

    def _disk_free(self) -> Optional[float]:
        if self._disk_free_fn is not None:
            return float(self._disk_free_fn())
        root = getattr(getattr(self.engine, "store", None), "_root", None)
        if root is None:
            return None
        try:
            return float(shutil.disk_usage(root).free)
        except OSError:
            return None                # store torn down mid-sample

    @any_thread
    def sample(self, queue_depth: int = 0) -> Tuple[str, Set[str]]:
        self.samples += 1
        cfg = self.cfg
        state, reasons = GREEN, set()

        def trip(to: str, why: str) -> None:
            nonlocal state
            if _SEVERITY[to] > _SEVERITY[state]:
                state = to
            reasons.add(why)

        if self.faults is not None:
            kind = self.faults.check("pressure", self.samples)
            if kind is not None:
                self.forced += 1
                trip(RED if kind == "io_error" else YELLOW, "forced")
        pool = self.engine.pool_stats() \
            if hasattr(self.engine, "pool_stats") else {}
        slots = pool.get("slots") or 0
        if slots:
            frac = pool.get("free_slots", 0) / slots
            self.last_signals["pool_free_frac"] = frac
            if frac < cfg.pool_free_red:
                trip(RED, "pool")
            elif frac < cfg.pool_free_yellow:
                trip(YELLOW, "pool")
        store = getattr(self.engine, "store", None)
        if store is not None and hasattr(store, "host_bytes"):
            hb = float(store.host_bytes())
            self.last_signals["host_bytes"] = hb
            if hb > cfg.host_bytes_red:
                trip(RED, "host")
            elif hb > cfg.host_bytes_yellow:
                trip(YELLOW, "host")
        free = self._disk_free()
        if free is not None:
            self.last_signals["disk_free_bytes"] = free
            if free < cfg.disk_free_red:
                trip(RED, "disk")
            elif free < cfg.disk_free_yellow:
                trip(YELLOW, "disk")
        self.last_signals["queue_depth"] = float(queue_depth)
        if queue_depth > cfg.queue_red:
            trip(RED, "queue")
        elif queue_depth > cfg.queue_yellow:
            trip(YELLOW, "queue")
        self.state_counts[state] += 1
        return state, reasons


class LoadHarness:
    """Replay an arrival trace against a live :class:`ContinuousBatcher`.

    Arrivals submit at ``t * time_scale`` wall seconds after start
    (``time_scale=0`` submits everything up front — the as-fast-as-
    possible mode the CI smoke uses); the decode loop steps whenever
    work is pending, so measured TTFT/goodput include real queueing,
    admission, preemption and shedding effects.  Prompt token ids are
    drawn from a seeded RNG; prompt lengths are clamped to what the
    engine's ``max_len`` admits next to the arrival's decode budget."""

    def __init__(self, batcher: ContinuousBatcher,
                 arrivals: Iterable[Arrival], *, time_scale: float = 1.0,
                 seed: int = 0, vocab: int = 32000,
                 max_rounds: int = 100_000):
        self.batcher = batcher
        self.arrivals = sorted(arrivals, key=lambda a: a.t)
        self.time_scale = float(time_scale)
        self.vocab = int(vocab)
        self.max_rounds = int(max_rounds)
        self._rng = np.random.RandomState(int(seed) & 0x7FFFFFFF)
        self.rounds = 0

    def _make_request(self, rid: int, a: Arrival) -> Request:
        n = int(a.prompt_len)
        eng = self.batcher.engine
        if eng is not None and hasattr(eng, "ecfg"):
            # decode appends past the prompt: leave room for max_new + 1
            n = max(1, min(n, int(eng.ecfg.max_len) - int(a.max_new) - 1))
        prompt = self._rng.randint(1, self.vocab, size=n).astype(np.int32)
        return Request(rid=rid, prompt=prompt, max_new=int(a.max_new),
                       deadline_s=a.deadline_s, priority=int(a.priority))

    def run(self) -> Dict[str, float]:
        b = self.batcher
        t0 = time.perf_counter()
        i = 0
        while i < len(self.arrivals) or b.pending_work:
            if self.rounds >= self.max_rounds:
                break
            now = time.perf_counter() - t0
            while i < len(self.arrivals) \
                    and self.arrivals[i].t * self.time_scale <= now:
                b.submit(self._make_request(i, self.arrivals[i]))
                i += 1
            if b.pending_work:
                b.step()
                self.rounds += 1
            elif i < len(self.arrivals):
                # idle until the next arrival is due
                due = self.arrivals[i].t * self.time_scale
                time.sleep(min(max(due - (time.perf_counter() - t0), 0.0),
                               0.01))
        return self.result()

    def result(self) -> Dict[str, float]:
        """Batcher stats plus the goodput row: completed-within-deadline
        over submitted.  Deadline enforcement is the scheduler's (an
        expired request is cancelled, i.e. lands in ``failed``), so a
        request that completed WITH a deadline met it by construction;
        deadline-free completions count as within."""
        st = dict(self.batcher.stats())
        submitted = st.get("requests_submitted", 0.0)
        st["goodput"] = st.get("requests_completed", 0.0) \
            / max(1.0, submitted)
        st["harness_rounds"] = float(self.rounds)
        return st
