"""Deterministic seeded fault injection for the tiered serving stack.

The serving stack's three fragile boundaries — disk memmap I/O, packed
sidecar payloads, and the admission/prefetch executors — are treated as
infallible by a correctness-only reproduction, but they are exactly the
slow, *unreliable* part of a commodity GPU-CPU-Disk hierarchy.  This
module gives tests (and soak harnesses) a way to make them fail **on
purpose and reproducibly**:

* a :class:`FaultPlan` maps ``(site, call-index) -> fault kind``.  Every
  choke point in :mod:`repro.serving.offload` consults the plan exactly
  once per physical I/O attempt (``FaultPlan.check``), so a schedule is
  a deterministic function of the call sequence — two runs of the same
  engine configuration with the same plan inject byte-identical faults.
* :func:`FaultPlan.from_seed` derives a schedule from a single integer,
  which is what the chaos property test fuzzes over.
* the typed exceptions below are the *vocabulary* of the fault domain:
  the store raises them, the engine contains them.  They live here (not
  in ``offload.py``) so the engine/scheduler can catch them without
  importing store internals.

Fault sites (the choke points that consult the plan):

=================  =====================================================
``disk_read``      coalesced fp16-replica memmap gather (``_stage_disk``
                   / ``fetch_chunks``)
``sidecar_read``   coalesced packed int4/int8 sidecar gather
                   (``_read_sidecar``)
``pq_read``        coalesced PQ-code memmap gather
                   (``read_abstracts_pq_batch``) — degrades importance
                   evaluation to the min/max boxes, never fails a round
``disk_write``     cold-ingest replica/sidecar landing (``_ingest_cold``)
``worker``         executor work item entry (ingest worker body)
``pressure``       resource-pressure monitor sample
                   (``overload.PressureMonitor.sample``) — forces
                   watermark transitions: ``latency`` ⇒ at least yellow,
                   ``io_error`` ⇒ red
=================  =====================================================

Fault kinds:

=============  ========================================================
``io_error``   raise :class:`TransientDiskError`; the store retries
               with bounded backoff, so a *single* scheduled index
               models a transient error (the retry consumes the next,
               presumably clean, index) and ``io_retries + 1``
               *consecutive* indices model a persistent failure that
               exhausts the retry budget and degrades.
``latency``    sleep ``latency_s`` at the choke point (a seek storm /
               SSD GC pause); never changes values, only timing.
``bitflip``    flip one bit of the first targeted chunk's stored bytes
               *before* the read — the checksum layer must catch it.
``exception``  raise :class:`WorkerFault` (an arbitrary bug in an
               executor work item).
=============  ========================================================
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "FaultPlan", "FaultEvent", "FAULT_SITES", "FAULT_KINDS",
    "TransientDiskError", "DiskIOExhausted", "WorkerFault",
    "ChunkLostError", "IngestError", "AdmissionError",
    "RejectedOverload",
]

FAULT_SITES = ("disk_read", "sidecar_read", "pq_read", "disk_write",
               "worker", "pressure")
FAULT_KINDS = ("io_error", "latency", "bitflip", "exception")

# Default per-site kind pools for seeded schedules.  Read sites run on
# the decode thread, whose contract is: transient errors retry, media
# corruption degrades via checksums — arbitrary exceptions belong to the
# executor boundary ("worker"), where the engine's per-seq fence contains
# them.  Keeping "exception" off read sites mirrors where real faults
# live and keeps the chaos test's containment obligations well-defined.
_SITE_KINDS: Dict[str, Tuple[str, ...]] = {
    "disk_read": ("io_error", "latency", "bitflip"),
    "sidecar_read": ("io_error", "latency", "bitflip"),
    "pq_read": ("io_error", "latency", "bitflip"),
    "disk_write": ("io_error", "latency"),
    "worker": ("exception", "latency"),
    # the pressure site never raises: the monitor maps "latency" to a
    # forced yellow watermark and "io_error" to a forced red — chaos
    # tests use it to drive preemption/shed transitions on demand
    "pressure": ("latency", "io_error"),
}


# ---------------------------------------------------------------------------
# typed exceptions — the fault domain's vocabulary
# ---------------------------------------------------------------------------

class TransientDiskError(IOError):
    """An injected (or real) transient disk error; the store retries it."""


class DiskIOExhausted(IOError):
    """A disk operation failed past the bounded retry budget.

    Raised by the store's retry wrapper; callers degrade (fp16 fallback,
    recompute-from-prompt, or seq-level failure) instead of letting it
    reach ``decode_round`` raw.
    """


class WorkerFault(RuntimeError):
    """An injected exception inside an executor work item — stands in for
    an arbitrary bug on a worker thread."""


class ChunkLostError(RuntimeError):
    """One or more disk replicas failed checksum verification (or stayed
    unreadable past the retry budget).

    ``keys`` is ``[(seq, phys_row, chunk), ...]`` for ONE store layer
    ``layer``: the billing seq that requested the read, the physical
    storage row (== seq unless the chunk lives in a shared prefix-arena
    row), and the chunk index.  The engine recovers by recomputing the
    affected prompt span (bitwise-identical, PR-4 chunked prefill) or by
    failing just the affected sequence.
    """

    def __init__(self, layer: int, keys: List[Tuple[int, int, int]]):
        self.layer = int(layer)
        self.keys = list(keys)
        super().__init__(
            f"disk-lost chunks at layer {layer}: "
            f"{[(s, p, c) for s, p, c in self.keys]}")


class IngestError(RuntimeError):
    """A sequence's write-behind cold ingest failed.

    Raised by ``ingest_fence`` AFTER all of the seq's futures have been
    awaited (so no write is still in flight when the caller reclaims the
    row); wraps the first underlying failure as ``cause``.
    """

    def __init__(self, seq: int, cause: BaseException):
        self.seq = int(seq)
        self.cause = cause
        super().__init__(f"cold ingest failed for seq {seq}: {cause!r}")


class RejectedOverload(RuntimeError):
    """A queued request was shed under red resource pressure.

    The structured terminal state of load shedding (scheduler policy §3c):
    the request never admitted, so no slot/tier state exists for it —
    ``reasons`` carries the monitor signals that tripped red (e.g.
    ``{"queue", "pool"}``) so clients and audits can distinguish shed
    causes.  Stored on ``Request.error`` / the rejected list, never
    raised across the scheduler boundary.
    """

    def __init__(self, rid: int, reasons: Tuple[str, ...] = ()):
        self.rid = int(rid)
        self.reasons = tuple(reasons)
        super().__init__(
            f"request {rid} shed under red overload pressure "
            f"({', '.join(self.reasons) or 'forced'})")


class AdmissionError(RuntimeError):
    """An async admission work item failed for sequence ``sid``.

    The slot is NOT yet reclaimed when this surfaces from the admission
    future — the scheduler (decode thread) must call
    ``engine.abort_admission(sid)`` to drain and recycle it.
    """

    def __init__(self, sid: int, cause: BaseException):
        self.sid = int(sid)
        self.cause = cause
        super().__init__(f"admission failed for seq {sid}: {cause!r}")


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

@dataclass
class FaultEvent:
    """One fault that actually fired: ``site``, the per-site call index it
    fired at, the ``kind`` injected, and the choke point's opaque ``key``
    (for read sites: the ``(layer, phys_row, chunk)`` the fault landed
    on — what the chaos test uses to classify affected sequences)."""

    site: str
    index: int
    kind: str
    key: Any = None


@dataclass
class FaultPlan:
    """A deterministic ``(site, call-index) -> kind`` fault schedule.

    ``schedule`` maps each site name to ``{call_index: kind}``.  Call
    indices count *physical attempts* at the choke point (retries
    re-consult the plan at the next index), starting at 0, per site.
    Thread-safe: the per-site counters live behind one lock, so worker
    and decode threads draw a single global order per site.
    """

    schedule: Dict[str, Dict[int, str]] = field(default_factory=dict)
    latency_s: float = 0.0
    fired: List[FaultEvent] = field(default_factory=list)

    def __post_init__(self) -> None:
        self._lock = threading.Lock()
        self._calls: Dict[str, int] = {s: 0 for s in self.schedule}
        for site in self.schedule:
            if site not in FAULT_SITES:
                raise ValueError(f"unknown fault site {site!r}")
            for kind in self.schedule[site].values():
                if kind not in FAULT_KINDS:
                    raise ValueError(f"unknown fault kind {kind!r}")

    @classmethod
    def from_seed(cls, seed: int, *, rate: float = 0.02,
                  horizon: int = 400, latency_s: float = 0.0,
                  sites: Tuple[str, ...] = FAULT_SITES,
                  kinds: Optional[Tuple[str, ...]] = None) -> "FaultPlan":
        """Derive a schedule from one integer: each of the first
        ``horizon`` call indices at each site fails with probability
        ``rate``, with a kind drawn uniformly from that site's pool
        (``_SITE_KINDS``) — or from ``kinds`` when given explicitly."""
        rng = np.random.RandomState(int(seed) & 0x7FFFFFFF)
        schedule: Dict[str, Dict[int, str]] = {}
        for site in sites:
            pool = kinds if kinds is not None \
                else _SITE_KINDS.get(site, FAULT_KINDS)
            hits = {}
            for idx in np.nonzero(rng.random_sample(horizon) < rate)[0]:
                hits[int(idx)] = pool[int(rng.randint(len(pool)))]
            if hits:
                schedule[site] = hits
        return cls(schedule=schedule, latency_s=latency_s)

    def check(self, site: str, key: Any = None) -> Optional[str]:
        """Consume one call index at ``site``; return the scheduled fault
        kind (recording a :class:`FaultEvent`) or ``None``."""
        with self._lock:
            n = self._calls.get(site, 0)
            self._calls[site] = n + 1
            kind = self.schedule.get(site, {}).get(n)
            if kind is not None:
                self.fired.append(FaultEvent(site, n, kind, key))
            return kind

    def record_key(self, key: Any) -> None:
        """Back-fill the key of the most recent fired event (used by
        bitflip choke points that pick the victim after the draw)."""
        with self._lock:
            if self.fired:
                self.fired[-1].key = key

    def calls(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._calls)

    def fired_events(self) -> List[FaultEvent]:
        with self._lock:
            return list(self.fired)
