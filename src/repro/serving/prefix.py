"""Content-addressable shared-prefix index for the tiered KV store.

Cross-request KV reuse (KVDrive-style): requests that share a
chunk-aligned token prefix — system prompts, few-shot preambles, RAG
documents — should pay prefill FLOPs and tier bytes ONCE.  This module
is the pure-bookkeeping half: chain hashes over chunk-aligned token
spans, and a refcounted ``hash -> (arena row, chunk)`` index whose
entries live in *arena rows* — extra pseudo-sequence rows appended to
every per-sequence array of :class:`~repro.serving.offload.TieredKVStore`
(disk replica + sidecar, host copies, device-pool slots, abstracts).

Design points:

* **Chain hashing.** ``h_c = sha1(h_{c-1} || tokens_c)``, so a chunk
  hash commits to the entire prefix before it.  Equal hashes therefore
  imply equal (position, prefix, chunk-tokens) — a matched chunk can be
  adopted at the *same* chunk index without any position translation.
  The partial tail chunk is hashed too (with an explicit length marker,
  so a 10-token tail never collides with a 16-token chunk that extends
  it): sharing the tail is what makes the first decode append into a
  shared chunk exercise copy-on-write.
* **Refcounts, not ownership.** Every sequence that adopts a chunk (and
  the sequence that registered it) holds one reference per ``(row,
  chunk)``.  Zero references means *evictable*, not *gone*: entries stay
  warm-cached and are only reclaimed — whole rows at a time, LRU — when
  a new registration needs an arena row and none is free.
* **Publish-after-fence.** Registration writes chunk payloads into the
  arena row during normal ingest; the index entry becomes visible to
  other requests only at ``publish()``, which the store calls after the
  write-behind disk writes are fenced.  A concurrent registration of the
  same content loses the publish race benignly: its row simply stays
  private to its registrant and is reclaimed once released.

The store serializes every call under its own ``_lock``; this class has
no locking of its own and must stay numpy/stdlib-only (lock-friendly per
INVARIANTS.md I1).
"""

from __future__ import annotations

import hashlib
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

__all__ = ["chunk_hashes", "PrefixIndex"]


def chunk_hashes(tokens: np.ndarray, chunk: int) -> List[bytes]:
    """Chained per-chunk digests of a token prefix.

    Returns one digest per (possibly partial) chunk of ``tokens``.  Full
    chunks hash their token bytes; the final partial chunk (if any)
    additionally commits to its length so that a short tail and a longer
    chunk sharing its first tokens never alias.
    """
    toks = np.ascontiguousarray(np.asarray(tokens, dtype=np.int64))
    n = toks.shape[0]
    out: List[bytes] = []
    prev = b"leoam-prefix-v1"
    for c0 in range(0, n, chunk):
        span = toks[c0:c0 + chunk]
        h = hashlib.sha1(prev)
        h.update(span.tobytes())
        if span.shape[0] < chunk:
            h.update(b"|tail:%d" % span.shape[0])
        prev = h.digest()
        out.append(prev)
    return out


class PrefixIndex:
    """Refcounted content-addressable map of shared prefix chunks.

    All state is plain Python/numpy; the owning store's ``_lock``
    serializes access.  ``(row, c)`` keys name a chunk ``c`` stored in
    arena row ``row``.
    """

    def __init__(self, rows: Iterable[int]):
        # LIFO so the lowest row indices are handed out first (stable,
        # test-friendly ordering).
        self.free_rows: List[int] = sorted(rows, reverse=True)
        self.entries: Dict[bytes, Tuple[int, int]] = {}   # hash -> (row, c)
        self.entry_of: Dict[Tuple[int, int], bytes] = {}  # (row, c) -> hash
        self.refs: Dict[Tuple[int, int], int] = {}        # live adopters
        self.row_chunks: Dict[int, Set[int]] = {}         # row -> chunk ids
        self._tick = 0
        self.row_tick: Dict[int, int] = {}                # row -> last use
        # hit-rate accounting (request-granular lookups, chunk-granular
        # hit/miss tallies; read back via TieredKVStore.prefix_stats()).
        self.lookups = 0
        self.hit_chunks = 0
        self.miss_chunks = 0
        self.evicted_rows = 0

    # -- lookup ---------------------------------------------------------

    def match(self, hashes: Sequence[bytes],
              record: bool = True) -> List[Tuple[int, int]]:
        """Longest resident prefix of ``hashes`` as ``[(row, c), ...]``.

        The chain construction guarantees a hit at position ``c`` was
        registered at chunk index ``c``; the scan stops at the first
        miss (a later stray hit could not share the same prefix).
        """
        out: List[Tuple[int, int]] = []
        for c, h in enumerate(hashes):
            loc = self.entries.get(h)
            if loc is None:
                break
            assert loc[1] == c, "chain hash matched at a foreign position"
            out.append(loc)
        if record:
            self.lookups += 1
            self.hit_chunks += len(out)
            self.miss_chunks += len(hashes) - len(out)
        return out

    # -- refcounts ------------------------------------------------------

    def acquire(self, keys: Iterable[Tuple[int, int]]) -> None:
        for key in keys:
            self.refs[key] = self.refs.get(key, 0) + 1
            self._touch(key[0])

    def decref(self, keys: Iterable[Tuple[int, int]]) -> None:
        for key in keys:
            n = self.refs.get(key, 0)
            assert n > 0, f"refcount underflow on shared chunk {key}"
            if n == 1:
                del self.refs[key]
            else:
                self.refs[key] = n - 1

    def ref_count(self, key: Tuple[int, int]) -> int:
        return self.refs.get(key, 0)

    def _touch(self, row: int) -> None:
        self._tick += 1
        self.row_tick[row] = self._tick

    # -- registration ---------------------------------------------------

    def alloc_row(self) -> Optional[Tuple[int, List[int]]]:
        """Hand out an arena row for a new registration.

        Prefers free rows; under pressure evicts the least-recently-used
        row whose every chunk has zero references (zero-ref rows are
        cache, not garbage — they are reclaimed only here).  Returns
        ``(row, [chunks the caller must scrub])`` or ``None`` when every
        row is pinned by live references.
        """
        if self.free_rows:
            row = self.free_rows.pop()
            return row, []
        victim = None
        for row, chunks in self.row_chunks.items():
            if any(self.refs.get((row, c), 0) for c in chunks):
                continue
            if victim is None or self.row_tick.get(row, 0) < \
                    self.row_tick.get(victim, 0):
                victim = row
        if victim is None:
            return None
        chunks = sorted(self.row_chunks.pop(victim))
        for c in chunks:
            h = self.entry_of.pop((victim, c), None)
            if h is not None and self.entries.get(h) == (victim, c):
                del self.entries[h]
        self.row_tick.pop(victim, None)
        self.evicted_rows += 1
        return victim, chunks

    def plan(self, row: int, chunks: Iterable[int]) -> None:
        """Reserve ``chunks`` of ``row`` for an in-flight registration."""
        self.row_chunks[row] = set(chunks)
        self._touch(row)

    def publish(self, row: int, c: int, h: bytes) -> bool:
        """Make ``(row, c)`` adoptable under hash ``h``.

        First registrant wins: if ``h`` is already published (a
        concurrent registration of the same content landed first) the
        entry is left alone and the caller's copy stays private to its
        registrant — reclaimed by ``alloc_row`` once released.
        """
        if h in self.entries:
            return False
        self.entries[h] = (row, c)
        self.entry_of[(row, c)] = h
        self._touch(row)
        return True

    # -- stats ----------------------------------------------------------

    def shared_chunks(self) -> int:
        return len(self.entries)

    def live_refs(self) -> int:
        return sum(self.refs.values())
