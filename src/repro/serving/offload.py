"""Three-tier KV store: device / host / disk with byte-accurate accounting.

The unit of placement is the (layer, chunk) pair, matching IAKM.  The disk
tier holds FULL REPLICAS of every chunk plus its LKA abstract (paper §4.3):
demotions are metadata-only (no write I/O), promotions read either the
abstract (2 key vectors) or the chunk payload, optionally through the INT4
transit codec.  All traffic is tallied per (src, dst, kind) so benchmarks
and the simulator can audit exactly what LeoAM saves.
"""

from __future__ import annotations

import os
import tempfile
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import compression

DEVICE, HOST, DISK = "device", "host", "disk"


@dataclass
class TrafficLog:
    bytes: Dict[Tuple[str, str, str], float] = field(
        default_factory=lambda: defaultdict(float))
    ops: Dict[Tuple[str, str, str], int] = field(
        default_factory=lambda: defaultdict(int))

    def record(self, src: str, dst: str, kind: str, nbytes: float) -> None:
        self.bytes[(src, dst, kind)] += nbytes
        self.ops[(src, dst, kind)] += 1

    def total(self, src: Optional[str] = None, kind: Optional[str] = None
              ) -> float:
        return sum(v for (s, d, k), v in self.bytes.items()
                   if (src is None or s == src) and (kind is None or k == kind))


class TieredKVStore:
    """Per-layer chunked K/V with GPU/CPU/disk placement.

    K/V chunks are (chunk, Hkv, hd) numpy arrays.  ``disk`` is a real
    memory-mapped file (so promotion latency is a genuine read on whatever
    machine this runs on); device tier is represented by pinned host arrays
    handed to jax at attention time.
    """

    def __init__(self, n_layers: int, n_chunks: int, chunk: int, kv_heads: int,
                 head_dim: int, *, dtype=np.float16, transit_codec="int4",
                 root: Optional[str] = None):
        self.n_layers, self.n_chunks, self.chunk = n_layers, n_chunks, chunk
        self.kv_heads, self.head_dim = kv_heads, head_dim
        self.dtype = np.dtype(dtype)
        self.transit_codec = transit_codec
        self.tier: np.ndarray = np.full((n_layers, n_chunks), HOST, object)
        self.access: np.ndarray = np.zeros((n_layers, n_chunks))
        self.log = TrafficLog()
        self._host_k: Dict[Tuple[int, int], np.ndarray] = {}
        self._host_v: Dict[Tuple[int, int], np.ndarray] = {}
        self._dev_k: Dict[Tuple[int, int], np.ndarray] = {}
        self._dev_v: Dict[Tuple[int, int], np.ndarray] = {}
        self._abstracts: Dict[Tuple[int, int], Tuple[np.ndarray, np.ndarray]] = {}
        shape = (n_layers, n_chunks, 2, chunk, kv_heads, head_dim)
        self._root = root or tempfile.mkdtemp(prefix="leoam_kv_")
        self._disk = np.memmap(os.path.join(self._root, "kv.bin"),
                               dtype=self.dtype, mode="w+", shape=shape)

    # ------------------------------------------------------------------
    @property
    def chunk_bytes(self) -> int:
        return 2 * self.chunk * self.kv_heads * self.head_dim * self.dtype.itemsize

    @property
    def abstract_bytes(self) -> int:
        return 2 * self.kv_heads * self.head_dim * self.dtype.itemsize

    def ingest(self, layer: int, k: np.ndarray, v: np.ndarray,
               placement: Dict[int, str]) -> None:
        """Store prefill KV.  k/v: (S, Hkv, hd).  Every chunk is replicated
        to disk (with its abstract); ``placement`` assigns the hot tier."""
        S = k.shape[0]
        for c in range(min(self.n_chunks, (S + self.chunk - 1) // self.chunk)):
            kc = k[c * self.chunk: (c + 1) * self.chunk].astype(self.dtype)
            vc = v[c * self.chunk: (c + 1) * self.chunk].astype(self.dtype)
            if kc.shape[0] < self.chunk:
                pad = self.chunk - kc.shape[0]
                kc = np.pad(kc, ((0, pad), (0, 0), (0, 0)))
                vc = np.pad(vc, ((0, pad), (0, 0), (0, 0)))
            self._disk[layer, c, 0] = kc
            self._disk[layer, c, 1] = vc
            self._abstracts[(layer, c)] = (kc.max(0), kc.min(0))
            self.log.record(HOST, DISK, "kv_replica", self.chunk_bytes)
            self.log.record(HOST, DISK, "abstract", self.abstract_bytes)
            where = placement.get(c, HOST)
            self.tier[layer, c] = where
            if where in (HOST, DEVICE):
                self._host_k[(layer, c)], self._host_v[(layer, c)] = kc, vc
            if where == DEVICE:
                self._dev_k[(layer, c)], self._dev_v[(layer, c)] = kc, vc

    # ------------------------------------------------------------------
    def read_abstracts(self, layer: int, chunks: List[int]
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """LKA: fetch (kmax, kmin) for chunks; disk chunks cost abstract I/O."""
        kmaxs, kmins = [], []
        for c in chunks:
            if self.tier[layer, c] == DISK:
                self.log.record(DISK, HOST, "abstract", self.abstract_bytes)
            km, kn = self._abstracts[(layer, c)]
            kmaxs.append(km)
            kmins.append(kn)
        return np.stack(kmaxs), np.stack(kmins)

    def fetch_chunks(self, layer: int, chunks: List[int], *,
                     to_device: bool = True
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Promote chunks to the device working set; returns stacked K/V
        (n, chunk, Hkv, hd).  Disk promotions go through the transit codec."""
        ks, vs = [], []
        for c in chunks:
            key = (layer, c)
            self.access[layer, c] += 1
            tier = self.tier[layer, c]
            if key in self._dev_k:
                ks.append(self._dev_k[key])
                vs.append(self._dev_v[key])
                continue
            if tier == DISK or key not in self._host_k:
                kc = np.asarray(self._disk[layer, c, 0])
                vc = np.asarray(self._disk[layer, c, 1])
                nbytes = self.chunk_bytes
                if self.transit_codec:
                    nbytes *= compression.codec_ratio(self.transit_codec)
                self.log.record(DISK, HOST, "kv", nbytes)
                self._host_k[key], self._host_v[key] = kc, vc
            kc, vc = self._host_k[key], self._host_v[key]
            nbytes = self.chunk_bytes
            if self.transit_codec:
                nbytes *= compression.codec_ratio(self.transit_codec)
            self.log.record(HOST, DEVICE, "kv", nbytes)
            if to_device:
                self._dev_k[key], self._dev_v[key] = kc, vc
                self.tier[layer, c] = DEVICE
            ks.append(kc)
            vs.append(vc)
        return np.stack(ks), np.stack(vs)

    def demote(self, layer: int, chunks: List[int], to: str = HOST) -> None:
        """Eviction is free toward disk (replicas, §4.3)."""
        for c in chunks:
            key = (layer, c)
            self._dev_k.pop(key, None)
            self._dev_v.pop(key, None)
            if to == DISK:
                self._host_k.pop(key, None)
                self._host_v.pop(key, None)
            self.tier[layer, c] = to

    def append_token(self, layer: int, pos: int, k_new: np.ndarray,
                     v_new: np.ndarray) -> None:
        """Decode-step cache append: update chunk + abstract in place."""
        c, off = pos // self.chunk, pos % self.chunk
        self._disk[layer, c, 0, off] = k_new.astype(self.dtype)
        self._disk[layer, c, 1, off] = v_new.astype(self.dtype)
        km, kn = self._abstracts.get((layer, c),
                                     (np.full((self.kv_heads, self.head_dim),
                                              -np.inf, self.dtype),
                                      np.full((self.kv_heads, self.head_dim),
                                              np.inf, self.dtype)))
        self._abstracts[(layer, c)] = (np.maximum(km, k_new),
                                       np.minimum(kn, k_new))
        key = (layer, c)
        if key in self._host_k:
            self._host_k[key][off] = k_new
            self._host_v[key][off] = v_new
        if key in self._dev_k:
            self._dev_k[key][off] = k_new
            self._dev_v[key][off] = v_new
        self.log.record(HOST, DISK, "kv_append",
                        2 * self.kv_heads * self.head_dim * self.dtype.itemsize)

    def device_bytes(self) -> int:
        return len(self._dev_k) * self.chunk_bytes

    def close(self) -> None:
        del self._disk
