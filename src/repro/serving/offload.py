"""Three-tier KV store: device / host / disk with byte-accurate accounting.

The unit of placement is the (seq, layer, chunk) triple: one store serves a
whole decode batch, so transfers and importance evaluation amortize across
sequences (the paper's batched speedup regime).  The disk tier holds FULL
REPLICAS of every chunk plus its LKA abstract (paper §4.3): demotions are
metadata-only (no write I/O), promotions read either the abstract (2 key
vectors) or the chunk payload, optionally through the INT4 transit codec.

Batched round support:

* one shared disk memmap over all sequences — ``fetch_chunks_batch`` /
  ``fetch_chunks_pooled`` gather every disk-resident (seq, chunk) pair of a
  layer in ONE fancy-indexed read, so promotion I/O for a decode round is
  one gather per layer;
* a :class:`DeviceChunkPool` per layer — a persistent device-side slab of
  chunk slots.  ``fetch_chunks_pooled`` uploads ONLY the chunks not already
  resident (delta uploads) and returns slot indices; the engine's jitted
  attention dispatch gathers by slot on device, so host→device bytes per
  round are the newly-promoted delta, not the full selection;
* a REAL transit codec on the pooled upload path: with ``real_codec=True``
  the θ-fraction of each upload crosses the host→device link as packed
  int4/int8 payloads (``core.compression.quantize_chunks``) and is
  dequantized on device by ``kernels.kv_quant`` (Pallas on TPU, jnp
  reference elsewhere).  Billed bytes equal the actual payload:
  ``chunk_bytes * codec_ratio(codec, group=chunk)`` for compressed chunks,
  full fp16 bytes otherwise;
* ``stage_host`` lets the engine's DTP prefetch thread speculatively pull
  predicted chunks disk→host under the previous layer's compute — a miss
  costs only the staging read, never a wrong output;
* **write-behind prefill ingest**: ``ingest(..., executor=...)`` applies
  the hot-tier placement synchronously (tier labels, host copies, pool
  slots) and runs the cold half — the disk replica write, the packed
  sidecar write, the LKA abstract update and their billing — on the given
  executor.  Every deferred write is tracked as a per-sequence future;
  :meth:`TieredKVStore.ingest_fence` is the COMPLETION FENCE: it blocks
  until every in-flight cold write of the sequence has landed (and
  re-raises worker exceptions), so a reader that fences first can never
  observe a half-written replica or a stale abstract.  The engine fences
  each sequence at decode-round entry and before releasing its slot; the
  cold work itself takes the store lock, so fence callers must NOT hold it;
* **packed int4 disk sidecar** (``disk_sidecar=True``): next to the fp16
  replica memmap the store keeps ``kv_q.bin`` (int payload, two nibbles
  per byte for int4) and ``kv_scale.bin`` (one f32 scale per channel per
  chunk plane) — the layout of ``compression.quantize_chunks`` with
  group == chunk, so one chunk's K+V sidecar bytes are EXACTLY
  ``chunk_bytes * codec_ratio(codec, chunk)``.  Replica writes and
  disk→host promotions then move packed bytes (billed at that exact
  figure); decode appends invalidate the touched chunk's sidecar (its
  per-chunk scales would be stale), falling back to the lossless fp16
  replica, which also serves all reads when ``sidecar_lossless=True``;
* **content-addressable shared-prefix cache** (``prefix_rows > 0``):
  chunk-aligned token prefixes are chain-hashed at admission
  (``prefix_admit``) and matched against a refcounted index of published
  chunks living in ARENA ROWS — extra pseudo-sequence rows appended to
  every per-sequence array (disk replica + sidecar, host copies, device
  pool slots, abstracts).  A hit is adopted BY REFERENCE: zero bytes move
  (billed as zero-byte ``prefix_ref`` ops), every read path resolves
  (seq, chunk) → arena row via ``_phys``, promotions of a shared chunk
  are deduplicated per arena key and billed once (``kv_shared``) to the
  triggering sequence, and the first decode append into a shared chunk
  privatizes it copy-on-write (one ``cow_read`` + ``cow_copy`` chunk copy
  per layer) so still-shared readers keep their bytes bit-for-bit.
  Missed chunks register by REDIRECT: ingest writes them straight into a
  planned arena row (no second copy), captures pre-quantization fidelity
  rows for bitwise warm resume, and ``finish_admission`` publishes the
  index entries only after the ingest fence so adopters can never read a
  half-written replica.  Refcounts gate arena eviction: a zero-ref row is
  warm cache, reclaimed LRU only when a new registration needs a row;
* per-sequence ``TrafficLog`` mirrors: every byte recorded in the shared
  ``log`` is also attributed to its sequence (retired sequences' logs move
  to ``retired_logs`` so reused slots audit fresh), and benchmarks assert
  shared == Σ seq_logs + Σ retired_logs exactly;
* **latent (absorbed-MLA) layout** (``latent=True``): DeepSeek-class MLA
  models cache ONE latent row per token — concat(c_kv, k_rope), no
  separate V plane — so the store drops to a single storage plane: the
  disk replica, sidecar, device-pool slab and every byte figure
  (``chunk_bytes``, ``row_bytes``, packed sidecar bytes) cover exactly the
  latent payload instead of double-counting a phantom V.  The (k, v)
  entry points stay: callers pass the latent rows as ``k`` and ``v`` is
  ignored; reads return the latent rows in both positions so engine
  plumbing stays uniform.

All traffic is tallied per (src, dst, kind) so benchmarks and the simulator
can audit exactly what LeoAM saves.
"""

from __future__ import annotations

import functools
import os
import tempfile
import threading
import time
import zlib
from collections import OrderedDict, defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression
from repro.core.tiers import shared_prefix_savings
from repro.kernels.pq import pq_encode, pq_train
from repro.serving import sanitizer as _san
from repro.serving.faults import (ChunkLostError, DiskIOExhausted,
                                  IngestError, TransientDiskError,
                                  WorkerFault)
from repro.serving.prefix import PrefixIndex, chunk_hashes
from repro.serving.sanitizer import (any_thread, decode_thread_only,
                                     worker_thread)

DEVICE, HOST, DISK = "device", "host", "disk"

# per-chunk checksum states (persisted in kv_crc_state.bin): NONE = never
# written (a REOPENED store treats a read of it as lost — torn ingest);
# VALID = the stored CRC covers the replica bytes; DIRTY = a decode append
# changed the replica in place, so the chunk is served unverified until
# the requant sweep re-packs (and re-checksums) it once quiet — a CRC
# read-back per appended row would double the append write traffic.
_CRC_NONE, _CRC_VALID, _CRC_DIRTY = 0, 1, 2


@functools.partial(jax.jit, donate_argnums=(0,))
def _slab_set(slab, idx, vals):
    """Scatter whole chunk slots into the device slab.  Jitted so repeated
    bucketed shapes reuse the compiled program (a bare ``.at[].set``
    re-traces every call, ~1.5 ms each on CPU), and the slab is DONATED so
    XLA updates it in place — O(delta) per round, not an O(pool) copy."""
    return slab.at[idx].set(vals)


@functools.partial(jax.jit, donate_argnums=(0,))
def _slab_set_rows(slab, si, oi, rows):
    """Scatter single token rows (both K/V planes) into slab chunks."""
    return slab.at[si, :, oi].set(rows)


@functools.partial(jax.jit, donate_argnums=(0,))
def _slab_set_both(slab, idx, vals, si, oi, rows):
    """Fused slot upload + deferred append-row scatter: one slab update per
    (layer, round) instead of two, shortening the dependency chain the
    attention gather waits on."""
    return slab.at[idx].set(vals).at[si, :, oi].set(rows)


@dataclass
class TrafficLog:
    bytes: Dict[Tuple[str, str, str], float] = field(
        default_factory=lambda: defaultdict(float))
    ops: Dict[Tuple[str, str, str], int] = field(
        default_factory=lambda: defaultdict(int))

    def record(self, src: str, dst: str, kind: str, nbytes: float) -> None:
        self.bytes[(src, dst, kind)] += nbytes
        self.ops[(src, dst, kind)] += 1

    def total(self, src: Optional[str] = None, kind: Optional[str] = None
              ) -> float:
        return sum(v for (s, d, k), v in self.bytes.items()
                   if (src is None or s == src) and (kind is None or k == kind))


@dataclass
class FetchStats:
    """One pooled fetch's breakdown (per layer per round)."""
    hits: int = 0                # chunks already pool-resident
    uploads: int = 0             # chunks uploaded this call (the delta)
    compressed: int = 0          # uploads that crossed the link packed
    disk_reads: int = 0          # chunks staged disk→host first
    upload_bytes: float = 0.0    # host→device bytes billed
    disk_bytes: float = 0.0      # disk→host bytes billed
    gather_s: float = 0.0        # disk stage wall time
    upload_s: float = 0.0        # quantize + upload dispatch wall time


class DeviceChunkPool:
    """Fixed-capacity per-layer device slab of KV chunk slots.

    ``kv`` is ONE (n_slots + 1, planes, chunk, Hkv, hd) jax array living on
    device for the engine's lifetime (K and V share the slab so every
    upload / append is a single scatter dispatch; the latent/MLA layout
    uses a single plane); slot ``n_slots`` is a
    write-only scratch row used to pad delta uploads to a bucketed size, so
    the scatter's compiled shape is stable across rounds instead of
    recompiling for every distinct delta.  ``slot_of`` maps
    (seq, chunk_id) → slot in LRU order (OrderedDict: hits
    ``move_to_end``, evictions pop from the front — amortized O(1),
    replacing the old O(n) min-scan).  On accelerators XLA performs the
    ``at[].set`` in place; the CPU interpreter copies, which is fine for
    the test geometry.
    """

    def __init__(self, n_slots: int, chunk: int, kv_heads: int,
                 head_dim: int, dtype, planes: int = 2):
        self.n_slots = n_slots
        self.planes = planes
        self.kv = jnp.zeros((n_slots + 1, planes, chunk, kv_heads, head_dim),
                            dtype)
        self.slot_of: "OrderedDict[Tuple[int, int], int]" = OrderedDict()
        self.free: List[int] = list(range(n_slots - 1, -1, -1))
        # decode appends queue here and are folded into the next round's
        # slot upload — one slab update per (layer, round), not two
        self.pending: Dict[Tuple[int, int], Tuple[int, np.ndarray]] = {}
        # deferred prefill placements (admission under decode): the ingest
        # thread must never scatter into the slab the decode thread's
        # attention reads, so device-bound chunks queue here and the NEXT
        # pooled fetch folds them in — unbilled, exactly like the
        # synchronous prefill placement (the KV was produced on device)
        self.pending_place: Dict[Tuple[int, int], np.ndarray] = {}
        self.hits = 0
        self.misses = 0
        self.uploads = 0

    def lookup(self, key: Tuple[int, int]) -> Optional[int]:
        slot = self.slot_of.get(key)
        if slot is not None:
            self.slot_of.move_to_end(key)
            self.hits += 1
        else:
            self.misses += 1
        return slot

    def alloc(self, key: Tuple[int, int], pinned) -> Tuple[int,
                                                           Optional[Tuple]]:
        """Grab a slot for ``key``, evicting the LRU non-pinned resident if
        full.  Returns (slot, evicted key or None)."""
        if self.free:
            slot = self.free.pop()
            self.slot_of[key] = slot
            return slot, None
        for victim in self.slot_of:            # LRU → MRU
            if victim not in pinned:
                break
        else:
            raise RuntimeError(
                "device pool exhausted by a single round's working set; "
                "raise device_chunk_budget or lower the selection rate")
        slot = self.slot_of.pop(victim)
        self.pending.pop(victim, None)     # host copy keeps the rows
        self.slot_of[key] = slot
        return slot, victim

    def evict(self, key: Tuple[int, int]) -> None:
        slot = self.slot_of.pop(key, None)
        self.pending.pop(key, None)
        self.pending_place.pop(key, None)
        if slot is not None:
            self.free.append(slot)

    def evict_seq(self, seq: int) -> None:
        for key in [k for k in self.slot_of if k[0] == seq]:
            self.evict(key)
        for key in [k for k in self.pending_place if k[0] == seq]:
            self.pending_place.pop(key, None)

    @decode_thread_only
    def scatter(self, slots: Sequence[int], kv_new, *,
                pad_to: Optional[int] = None,
                row_pad: int = 8) -> List[Tuple[int, int]]:
        """One slab update per (layer, round): scatter the (m, planes,
        chunk, Hkv, hd) delta upload into ``slots`` AND flush the queued decode
        append rows.  Index rows past the real payload (bucket padding)
        land in the write-only scratch slot, so repeated rounds reuse the
        compiled scatter instead of recompiling per delta size.  ``kv_new``
        may be numpy (plain fp16 upload) or a jax array
        (device-dequantized codec payload).  Returns the (seq, chunk) keys
        whose append rows actually crossed to the device — the caller bills
        those (rows dropped by eviction are never billed)."""
        m = len(slots)
        rows = [(key, slot, off, row)
                for key, (off, row) in self.pending.items()
                if (slot := self.slot_of.get(key)) is not None]
        n = len(rows)
        width = -(-max(n, 1) // row_pad) * row_pad if n else 0
        if m:
            idx = np.full(max(pad_to or m, m), self.n_slots, np.int32)
            idx[:m] = np.asarray(slots, np.int32)
            if idx.shape[0] > m:
                pad = np.zeros((idx.shape[0] - m, *self.kv.shape[1:]),
                               self.kv.dtype)
                kv_new = jnp.concatenate([jnp.asarray(kv_new),
                                          jnp.asarray(pad)]) \
                    if isinstance(kv_new, jnp.ndarray) else \
                    np.concatenate([kv_new, pad])
        if n:
            si = np.full(width, self.n_slots, np.int32)
            oi = np.zeros(width, np.int32)
            kv_rows = np.zeros((width, self.planes, self.kv.shape[3],
                                self.kv.shape[4]), self.kv.dtype)
            for i, (_key, slot, off, row) in enumerate(rows):
                si[i], oi[i] = slot, off
                kv_rows[i] = row
        if m and n:
            self.kv = _slab_set_both(self.kv, jnp.asarray(idx),
                                     jnp.asarray(kv_new), jnp.asarray(si),
                                     jnp.asarray(oi), jnp.asarray(kv_rows))
        elif m:
            self.kv = _slab_set(self.kv, jnp.asarray(idx),
                                jnp.asarray(kv_new))
        elif n:
            self.kv = _slab_set_rows(self.kv, jnp.asarray(si),
                                     jnp.asarray(oi), jnp.asarray(kv_rows))
        # clear AFTER the slab updates land: an exception mid-scatter must
        # not drop queued append rows on the floor (the retry re-flushes
        # them, so the slab never serves a stale chunk row)
        self.pending.clear()
        self.uploads += m
        return [key for key, _, _, _ in rows]

    def queue_row(self, key: Tuple[int, int], off: int,
                  kv_row: np.ndarray) -> None:
        """Queue a decode-append row for a resident chunk; flushed by the
        next :meth:`scatter` (gathers read the slab only after it)."""
        self.pending[key] = (off, kv_row)


class TieredKVStore:
    """Multi-sequence chunked K/V with GPU/CPU/disk placement.

    K/V chunks are (chunk, Hkv, hd) numpy arrays keyed by (seq, layer,
    chunk).  ``disk`` is a real memory-mapped file shared by all sequences
    (so promotion latency is a genuine read on whatever machine this runs
    on).  The device tier has two representations: the legacy pinned-host
    dicts capped by ``device_budget`` (``fetch_chunks`` /
    ``fetch_chunks_batch``, kept for the synchronous PR-1 engine path), and
    the per-layer :class:`DeviceChunkPool` slabs (``use_pool=True``,
    ``fetch_chunks_pooled``) where residency is an actual device array and
    uploads are deltas.

    The single-sequence API (``seq`` defaulting to 0) is unchanged from the
    original per-request store, so a ``n_seqs=1`` store behaves exactly as
    before.  All mutating entry points take an RLock so the engine's DTP
    prefetch thread can stage disk reads while the main thread decodes.
    """

    def __init__(self, n_layers: int, n_chunks: int, chunk: int, kv_heads: int,
                 head_dim: int, *, n_seqs: int = 1, dtype=np.float16,
                 transit_codec="int4", root: Optional[str] = None,
                 device_budget: Optional[int] = None,
                 use_pool: bool = False, pool_slots: Optional[int] = None,
                 real_codec: bool = False, disk_sidecar: bool = False,
                 sidecar_lossless: bool = False, latent: bool = False,
                 prefix_rows: int = 0, debug_sync: bool = False,
                 checksums: bool = True, faults=None,
                 io_retries: int = 3, io_backoff_s: float = 1e-4,
                 reopen: bool = False, abstract_kind: str = "minmax",
                 pq_m: Optional[int] = None, pq_centroids: int = 256,
                 pq_train_iters: int = 4, pq_impl: Optional[str] = None):
        # sync-sanitizer: refcounted enable so overlapping debug stores
        # compose; locks get wrapped in TrackedLock further down
        self.debug_sync = bool(debug_sync)
        if self.debug_sync:
            _san.enable()
        self.n_seqs = n_seqs
        # arena rows for the content-addressable shared-prefix cache sit
        # AFTER the real sequence rows in every per-seq array; ``rows`` is
        # the physical row count everywhere below
        self.prefix_rows = prefix_rows
        rows = n_seqs + prefix_rows
        self.n_layers, self.n_chunks, self.chunk = n_layers, n_chunks, chunk
        self.kv_heads, self.head_dim = kv_heads, head_dim
        # latent (absorbed-MLA) layout: one storage plane of concat(ckv,
        # krope) rows instead of the (K, V) pair — byte accounting, the
        # disk replica, the sidecar and the pool slab all cover exactly
        # the latent payload
        self.latent = latent
        self.planes = 1 if latent else 2
        self.dtype = np.dtype(dtype)
        self.transit_codec = transit_codec
        self.real_codec = real_codec and transit_codec is not None
        self.disk_sidecar = disk_sidecar and transit_codec is not None
        self.sidecar_lossless = sidecar_lossless
        self.device_budget = device_budget
        self.tier: np.ndarray = np.full((rows, n_layers, n_chunks), HOST,
                                        object)
        self.access: np.ndarray = np.zeros((rows, n_layers, n_chunks))
        self.log = TrafficLog()
        self.seq_logs: Dict[int, TrafficLog] = defaultdict(TrafficLog)
        self.retired_logs: List[TrafficLog] = []
        Key = Tuple[int, int, int]
        self._host_k: Dict[Key, np.ndarray] = {}
        self._host_v: Dict[Key, np.ndarray] = {}
        self._dev_k: Dict[Key, np.ndarray] = {}
        self._dev_v: Dict[Key, np.ndarray] = {}
        # legacy device LRU: OrderedDict insertion order == recency (O(1)
        # touch/evict; the old dict+min-scan was O(n) per demotion)
        self._lru: "OrderedDict[Key, None]" = OrderedDict()
        # persistent stacked abstracts: one (n_seqs, n_chunks, Hkv, hd)
        # fancy-index per (layer, round) instead of a per-seq Python loop
        self._abs_km = np.full((rows, n_layers, n_chunks, kv_heads,
                                head_dim), -np.inf, np.float32)
        self._abs_kn = np.full_like(self._abs_km, np.inf)
        self._lock = threading.RLock()
        if self.debug_sync:
            self._lock = _san.TrackedLock(self._lock, "TieredKVStore._lock")
        self.upload_pad = 8            # delta-upload bucket (shape reuse)
        self.codec_uploads = 0         # pooled H2D chunks sent packed
        self.plain_uploads = 0         # pooled H2D chunks sent fp16
        self.pools: List[Optional[DeviceChunkPool]] = [None] * n_layers
        if use_pool:
            slots = pool_slots if pool_slots is not None \
                else n_seqs * n_chunks
            self.pools = [DeviceChunkPool(slots, chunk, kv_heads, head_dim,
                                          self.dtype, planes=self.planes)
                          for _ in range(n_layers)]
        shape = (rows, n_layers, n_chunks, self.planes, chunk, kv_heads,
                 head_dim)
        self._root = root or tempfile.mkdtemp(prefix="leoam_kv_")
        # reopen=True re-attaches to an existing root after a (real or
        # simulated) crash: memmaps open read-write over whatever bytes
        # survived, every chunk starts disk-tier, and the checksum layer
        # decides per read what is servable — a chunk whose cold ingest
        # never landed has CRC state NONE and is rejected as disk-lost
        # instead of served torn (crash-consistency test).
        self._reopened = bool(reopen)
        _mode = "r+" if reopen else "w+"
        self._disk = np.memmap(os.path.join(self._root, "kv.bin"),
                               dtype=self.dtype, mode=_mode, shape=shape)
        # packed sidecar: quantize_chunks(group=chunk) layout per (seq,
        # layer, chunk, K|V plane) — int payload + f32 per-channel scales.
        # _sidecar_valid gates reads: decode appends invalidate the chunk
        # (its scales go stale) and the fp16 replica serves as fallback.
        self._disk_q = self._disk_scale = None
        self._sidecar_valid = np.zeros((rows, n_layers, n_chunks), bool)
        if self.disk_sidecar:
            d = kv_heads * head_dim
            dq = compression.packed_dim(transit_codec, d)
            self._disk_q = np.memmap(
                os.path.join(self._root, "kv_q.bin"), dtype=np.int8,
                mode=_mode, shape=(rows, n_layers, n_chunks, self.planes,
                                   chunk, dq))
            self._disk_scale = np.memmap(
                os.path.join(self._root, "kv_scale.bin"), dtype=np.float32,
                mode=_mode, shape=(rows, n_layers, n_chunks, self.planes, d))
        # fault domain (PR 8): per-chunk CRC32 over the replica planes and
        # the packed sidecar payload+scales, persisted next to the data so
        # a reopened store rejects torn/corrupt chunks instead of serving
        # them.  ``faults`` is an optional serving.faults.FaultPlan threaded
        # through the single I/O choke points (tests/chaos harness only).
        self.checksums = bool(checksums)
        self.faults = faults
        self.io_retries = int(io_retries)
        self.io_backoff_s = float(io_backoff_s)
        self._crc = self._crc_state = self._q_crc = None
        if self.checksums:
            self._crc = np.memmap(
                os.path.join(self._root, "kv_crc.bin"), dtype=np.uint32,
                mode=_mode, shape=(rows, n_layers, n_chunks))
            self._crc_state = np.memmap(
                os.path.join(self._root, "kv_crc_state.bin"),
                dtype=np.uint8, mode=_mode,
                shape=(rows, n_layers, n_chunks))
            if self.disk_sidecar:
                self._q_crc = np.memmap(
                    os.path.join(self._root, "kv_q_crc.bin"),
                    dtype=np.uint32, mode=_mode,
                    shape=(rows, n_layers, n_chunks))
        # PQ abstract plane (abstract_kind="pq"): per-layer product-
        # quantization codebooks learned online from ingested key chunks,
        # plus per-(row, layer, chunk) uint8 codes on disk — the SECOND
        # abstract representation next to the min/max boxes (which stay
        # as the exactness fallback for append-dirtied / unreadable /
        # corrupt codes).  ``_pq_valid`` gates ADC reads exactly like
        # ``_sidecar_valid`` gates packed promotions: any mutation of a
        # chunk's replica clears it, and the requant sweep re-encodes
        # once the chunk goes quiet (docs/INVARIANTS.md I8).
        if abstract_kind not in ("minmax", "pq"):
            raise ValueError(f"unknown abstract_kind {abstract_kind!r}")
        self.pq = abstract_kind == "pq"
        self.pq_m = 0
        self.pq_centroids = int(pq_centroids)
        self.pq_train_iters = int(pq_train_iters)
        self.pq_impl = pq_impl
        self._pq_codes = self._pq_codebook = self._pq_crc = None
        self._pq_cb = self._pq_counts = None
        self._pq_valid = None
        self.pq_reencodes = 0
        if self.pq:
            self.pq_m = int(pq_m) if pq_m is not None \
                else max(1, head_dim // 8)
            if head_dim % self.pq_m:
                raise ValueError(
                    f"pq_m={self.pq_m} must divide head_dim={head_dim}")
            if not 0 < self.pq_centroids <= 256:
                raise ValueError("pq_centroids must fit uint8 codes")
            dsub = head_dim // self.pq_m
            self._pq_codes = np.memmap(
                os.path.join(self._root, "kv_pq.bin"), dtype=np.uint8,
                mode=_mode, shape=(rows, n_layers, n_chunks, chunk,
                                   kv_heads, self.pq_m))
            self._pq_codebook = np.memmap(
                os.path.join(self._root, "kv_pq_cb.bin"), dtype=np.float32,
                mode=_mode, shape=(n_layers, self.pq_m, self.pq_centroids,
                                   dsub))
            # RAM mirrors: codebook reads (selection, encode) never touch
            # the memmap; counts make the online k-means a running mean.
            # A REOPENED store starts with every code invalid (min/max
            # serves until the sweep re-encodes) but keeps the persisted
            # codebook so re-encodes continue it.
            self._pq_cb = np.array(self._pq_codebook)
            self._pq_counts = np.zeros((n_layers, self.pq_m,
                                        self.pq_centroids), np.float64)
            self._pq_valid = np.zeros((rows, n_layers, n_chunks), bool)
            if self.checksums:
                self._pq_crc = np.memmap(
                    os.path.join(self._root, "kv_pq_crc.bin"),
                    dtype=np.uint32, mode=_mode,
                    shape=(rows, n_layers, n_chunks))
        # codebook mutations (train/merge) serialize on a leaf lock so
        # cold-ingest workers never hold the store lock across them; the
        # k-means kernels themselves run OUTSIDE any lock
        # (snapshot-compute-merge) per docs/INVARIANTS.md I1
        self._pq_lock = threading.Lock()
        if self.debug_sync:
            self._pq_lock = _san.TrackedLock(self._pq_lock,
                                             "TieredKVStore._pq_lock")
        self.fault_counters: Dict[str, int] = {
            "io_retries": 0, "checksum_failures": 0, "chunks_recomputed": 0,
            "pq_fallbacks": 0}
        self._stats_lock = threading.Lock()   # counters only; leaf lock
        self._disk_lost: Set[Tuple[int, int, int]] = set()
        # sequences served degraded numerics this lifetime: a quarantined
        # sidecar fell back to the lossless fp16 replica, so their values
        # differ from the fault-free dequantized read (the chaos test
        # exempts exactly these from token-identity)
        self.degraded_seqs: Set[int] = set()
        # whole-sequence preemption (overload control): per-seq remembered
        # hot working set at swap-out time — {seq: {layer: [chunks]}} —
        # so swap_in_seq restores exactly the residency the victim had
        self._swapped: Dict[int, Dict[int, List[int]]] = {}
        self.seq_swapouts = 0
        self.seq_swapins = 0
        if reopen:
            # hot tiers died with the process; all surviving state is disk
            self.tier[:] = DISK
        # write-behind ingest: per-seq in-flight cold-write futures; the
        # fence pops under _futs_lock and waits OUTSIDE the store lock
        # (workers need the store lock to land their writes)
        self._ingest_futs: Dict[int, List] = defaultdict(list)
        self._futs_lock = threading.Lock()
        if self.debug_sync:
            self._futs_lock = _san.TrackedLock(self._futs_lock,
                                               "TieredKVStore._futs_lock")
        # sidecar requantization sweep: append-dirtied chunks keyed to the
        # sweep round of their LAST append; a chunk quiet for a full round
        # is re-packed in the background so long-running sequences regain
        # packed disk->host promotions.  The per-chunk version aborts a
        # repack that raced a newer append (or a slot reuse).
        self._requant_pending: Dict[Tuple[int, int, int], int] = {}
        self._chunk_version: Dict[Tuple[int, int, int], int] = \
            defaultdict(int)
        self._requant_futs: List = []
        self._sweep_round = 0
        self.sidecar_repacks = 0
        # content-addressable shared-prefix cache: index + refcounts over
        # arena rows n_seqs..rows-1 (PrefixIndex is pure bookkeeping; all
        # calls are serialized under _lock).  _shared_map resolves
        # (seq, chunk) → arena row; _reg_plan tracks in-flight
        # registrations (chunk → hash) pending publish; _fidelity keeps
        # the registrant's pre-quantization cache rows per (arena row,
        # layer, chunk) so warm resumes are bitwise-identical to cold.
        self._prefix = PrefixIndex(range(n_seqs, rows)) if prefix_rows \
            else None
        self._shared_map: Dict[int, Dict[int, int]] = {}
        self._reg_plan: Dict[int, Dict[int, bytes]] = {}
        self._fidelity: Dict[Tuple[int, int, int],
                             Tuple[np.ndarray, np.ndarray]] = {}
        self.bytes_deduped = 0.0
        self.cow_copies = 0
        self.warm_admissions = 0
        self.prefix_admissions = 0

    # ------------------------------------------------------------------
    @property
    def chunk_bytes(self) -> int:
        """One chunk's stored payload: K+V planes, or the single latent
        plane under the absorbed-MLA layout."""
        return (self.planes * self.chunk * self.kv_heads * self.head_dim
                * self.dtype.itemsize)

    @property
    def abstract_bytes(self) -> int:
        """One chunk's LKA abstract: the (min, max) box pair over the key
        plane (latent plane for MLA) — the 2 here is min+max, not planes."""
        return 2 * self.kv_heads * self.head_dim * self.dtype.itemsize

    @property
    def pq_bytes(self) -> int:
        """One chunk's PQ abstract: uint8 codes per (token, kv head, m)
        subvector — the bytes a ``pq_codes_read`` promotion moves."""
        return self.chunk * self.kv_heads * self.pq_m

    @property
    def row_bytes(self) -> int:
        """One appended token's stored bytes (K+V, or one latent row)."""
        return (self.planes * self.kv_heads * self.head_dim
                * self.dtype.itemsize)

    def _bill_flushed_rows(self, applied: List[Tuple[int, int]]) -> None:
        """Bill the HOST→DEVICE append rows a slab flush actually carried
        (queued rows dropped by eviction never cross, so never bill)."""
        for seq, _c in applied:
            self._record(seq, HOST, DEVICE, "kv_append", self.row_bytes)

    @property
    def use_pool(self) -> bool:
        return self.pools[0] is not None

    def _record(self, seq: int, src: str, dst: str, kind: str,
                nbytes: float) -> None:
        """Tally into the shared log AND the sequence's mirror, identically
        — the shared log is the exact sum of the per-seq logs by
        construction."""
        self.log.record(src, dst, kind, nbytes)
        self.seq_logs[seq].record(src, dst, kind, nbytes)

    def _transit_bytes(self) -> float:
        """Legacy ledger-only codec: chunk bytes scaled by the codec ratio."""
        nbytes = float(self.chunk_bytes)
        if self.transit_codec:
            nbytes *= compression.codec_ratio(self.transit_codec)
        return nbytes

    def _packed_bytes(self) -> float:
        """Actual packed payload bytes of one chunk through the real codec
        (per-chunk grouping, so the ratio is exact — tested)."""
        return float(self.chunk_bytes) * compression.codec_ratio(
            self.transit_codec, group=self.chunk)

    def _disk_read_bytes(self) -> float:
        """Disk→host promotion bytes for a chunk read off the FP16 replica:
        the real-codec / sidecar stores bill the honest full read; the
        legacy store kept the ledger-only codec scaling.  Sidecar-valid
        chunks never pay this — they move :meth:`_packed_bytes` instead
        (decided per key in :meth:`_stage_disk`)."""
        return float(self.chunk_bytes) if (self.real_codec
                                           or self.disk_sidecar) \
            else self._transit_bytes()

    def _plane_stack(self, kc: np.ndarray, vc: np.ndarray) -> np.ndarray:
        """Stack one chunk's storage planes: (planes, chunk, Hkv, hd) —
        the K/V pair, or just the latent plane under the MLA layout."""
        return kc[None] if self.planes == 1 else np.stack((kc, vc))

    def _sidecar_ok(self, seq: int, layer: int, c: int) -> bool:
        """True when the packed sidecar serves this chunk's disk reads."""
        return (self.disk_sidecar and not self.sidecar_lossless
                and bool(self._sidecar_valid[seq, layer, c]))

    # ------------------------------------------------------------------
    # Fault domain: checksums, injection choke points, bounded retry
    # ------------------------------------------------------------------
    @staticmethod
    def _crc32(arr: np.ndarray) -> int:
        """CRC32 over a chunk's stored bytes (cheap, no jax dispatch — safe
        under the store lock)."""
        return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF

    def _sidecar_crc(self, data: np.ndarray, scale: np.ndarray) -> int:
        """One chunk's packed-sidecar CRC: payload planes then scales, in
        the exact (planes, chunk, dq) / (planes, d) read layout."""
        z = zlib.crc32(np.ascontiguousarray(data).tobytes())
        return zlib.crc32(np.ascontiguousarray(scale).tobytes(), z) \
            & 0xFFFFFFFF

    def _count(self, name: str, n: int = 1) -> None:
        """Bump a fault counter (worker and decode threads both count)."""
        with self._stats_lock:
            self.fault_counters[name] = \
                self.fault_counters.get(name, 0) + n

    def _fault_point(self, site: str, key=None) -> None:
        """THE injection choke point: every physical disk/sidecar/worker
        attempt consults the plan here exactly once.  ``key`` for read
        sites is the list of (phys row, layer, chunk) the attempt covers —
        a scheduled bitflip corrupts the first one's stored bytes."""
        plan = self.faults
        if plan is None:
            return
        kind = plan.check(site, key)
        if kind is None:
            return
        if kind == "latency":
            time.sleep(plan.latency_s)
        elif kind == "io_error":
            raise TransientDiskError(f"injected transient {site} error")
        elif kind == "exception":
            raise WorkerFault(f"injected worker fault at {site}")
        elif kind == "bitflip" and site in ("disk_read", "sidecar_read",
                                            "pq_read"):
            self._flip_bit(site, key)

    def _flip_bit(self, site: str, key) -> None:  # leolint: waive[billlint] reason=fault-injection hook: corrupts stored bytes in place to model silent media corruption; no tier transfer occurs, nothing is promoted or billed
        """Flip one stored bit of the first targeted chunk — silent media
        corruption the checksum layer must catch at the next promotion."""
        if not key:
            return
        p, layer, c = key[0]
        if site == "pq_read" and self._pq_codes is not None:
            buf = self._pq_codes[p, layer, c].reshape(-1)
            buf[0] = np.uint8(int(buf[0]) ^ 0x01)
        elif site == "sidecar_read" and self._disk_q is not None:
            buf = self._disk_q[p, layer, c].reshape(-1)
            buf[0] = np.int8(int(buf[0]) ^ 0x40)
        else:
            flat = self._disk[p, layer, c].reshape(-1)
            word = np.uint16 if self.dtype.itemsize == 2 else np.uint32
            cell = flat[:1].view(word)
            cell[0] ^= np.asarray(1 << 10, word)
        if hasattr(self.faults, "record_key"):
            self.faults.record_key((int(p), int(layer), int(c)))

    def _with_retries(self, fn):
        """Run one physical I/O attempt with bounded retry-with-backoff on
        transient errors.  Each retry re-consults the fault plan at the
        NEXT call index, so one scheduled ``io_error`` models a transient
        blip (value-identical after retry) and ``io_retries + 1``
        consecutive ones a persistent failure, surfacing as
        :class:`DiskIOExhausted` for the caller to degrade on — never a
        raw ``IOError`` into ``decode_round``."""
        last: Optional[BaseException] = None
        for attempt in range(self.io_retries + 1):
            try:
                return fn()
            except TransientDiskError as e:
                last = e
                self._count("io_retries")
                if attempt < self.io_retries:
                    time.sleep(self.io_backoff_s * (2 ** attempt))
        raise DiskIOExhausted(
            f"disk I/O failed after {self.io_retries + 1} attempts: "
            f"{last}") from last

    def _read_sidecar(self, layer: int,  # leolint: waive[billlint] reason=coalesced read helper: every caller (_stage_disk, fetch_chunks) bills _packed_bytes() (or the fp16 fallback) per key at its own promotion site, where per-seq attribution is known
                      keys: Sequence[Tuple[int, int]]
                      ) -> Tuple[np.ndarray, Set[int]]:
        """Coalesced packed-sidecar read: dequantize every storage plane
        for every (seq, chunk) key.  Returns ``(out, bad)``: out is
        (n, planes, chunk, Hkv, hd) in store dtype; ``bad`` holds the
        positions whose payload failed CRC verification — those rows are
        garbage, the sidecar is quarantined (valid bit cleared, counted)
        and the caller falls back to the fp16 replica."""
        sq = np.array([s for s, _ in keys])
        cq = np.array([c for _, c in keys])

        def read():  # leolint: waive[billlint] reason=retryable attempt body of the coalesced helper; billing happens at the callers' promotion sites (see _read_sidecar waiver)
            self._fault_point("sidecar_read",
                              [(p, layer, c) for p, c in keys])
            return (np.asarray(self._disk_q[sq, layer, cq]),
                    np.asarray(self._disk_scale[sq, layer, cq]))

        data, scale = self._with_retries(read)   # (n, planes, c, dq) / (n, planes, d)
        bad: Set[int] = set()
        if self._q_crc is not None:
            for i, (p, c) in enumerate(keys):
                if self._sidecar_crc(data[i], scale[i]) != \
                        int(self._q_crc[p, layer, c]):
                    bad.add(i)
                    self._sidecar_valid[p, layer, c] = False
                    self._count("checksum_failures")
        out = np.empty((len(keys), self.planes, self.chunk, self.kv_heads,
                        self.head_dim), self.dtype)
        for plane in range(self.planes):
            out[:, plane] = compression.dequantize_chunks(
                data[:, plane], scale[:, plane], self.transit_codec,
                self.kv_heads, self.head_dim, dtype=self.dtype)
        return out, bad

    def _replica_read_verified(self, layer: int,  # leolint: waive[billlint] reason=coalesced verified-read helper: callers (_stage_disk, fetch_chunks) bill per key at their own promotion site, where per-seq attribution and the fallback kind are known
                               entries: Sequence[Tuple[int, int, int]]
                               ) -> Tuple[np.ndarray, Set[int]]:
        """Coalesced fp16-replica gather through the fault choke point with
        bounded retry, plus CRC verification.  ``entries`` is (bill seq,
        phys row, chunk).  Returns ``(blk, lost)``: blk is (n, planes,
        chunk, Hkv, hd); ``lost`` positions failed verification (replica
        corrupt, or — in a reopened store — never landed), are marked
        disk-lost, and must not be served."""
        sq = np.array([p for _, p, _ in entries])
        cq = np.array([c for _, _, c in entries])

        def read():  # leolint: waive[billlint] reason=retryable attempt body of the coalesced helper; billing happens at the callers' promotion sites (see _replica_read_verified waiver)
            self._fault_point("disk_read",
                              [(p, layer, c) for _, p, c in entries])
            return np.asarray(self._disk[sq, layer, cq])

        blk = self._with_retries(read)
        lost: Set[int] = set()
        if self._crc is not None:
            for i, (_, p, c) in enumerate(entries):
                state = int(self._crc_state[p, layer, c])
                ok = True
                if state == _CRC_VALID:
                    ok = self._crc32(blk[i]) == int(self._crc[p, layer, c])
                elif state == _CRC_NONE and self._reopened:
                    ok = False       # torn ingest: the cold write never landed
                if not ok:
                    lost.add(i)
                    if (p, layer, c) not in self._disk_lost:
                        self._disk_lost.add((p, layer, c))
                        self._count("checksum_failures")
        return blk, lost

    @worker_thread
    def ingest(self, layer: int, k: np.ndarray,
               v: Optional[np.ndarray] = None,
               placement: Optional[Dict[int, str]] = None, *, seq: int = 0,
               executor=None, pool_place: bool = True,
               start: int = 0) -> None:
        """Store prefill KV.  k/v: (S, Hkv, hd).  Every chunk is replicated
        to disk (with its abstract); ``placement`` assigns the hot tier.
        Under the latent (MLA) layout ``k`` carries the latent rows and
        ``v`` is ignored (may be None).

        With ``executor`` the cold half (disk replica + sidecar + abstract
        writes and their billing) runs write-behind on that executor; the
        hot-tier placement is applied synchronously, so host/device reads
        are immediately valid while disk/abstract reads need
        :meth:`ingest_fence` first.  ``pool_place=False`` downgrades
        would-be device-pool placements to HOST — used when ingest runs
        concurrently with decode rounds, whose attention gathers read the
        pool slab outside the store lock (the first fetch promotes the
        chunks instead; residency-only, so outputs never change).

        ``start`` (chunk-aligned token position) ingests a PARTIAL
        sequence: rows land in chunks ``start // chunk`` onward — chunked
        prefill streams each admission chunk in as it is forced, instead of
        one whole-prompt call.  ``placement`` stays keyed by GLOBAL chunk
        id; each call's cold writes join the same per-seq fence."""
        assert start % self.chunk == 0, (start, self.chunk)
        placement = placement or {}
        c0 = start // self.chunk
        with self._lock:
            S = k.shape[0]
            shared = self._shared_map.get(seq) or {}
            plan = self._reg_plan.get(seq) or {}
            to_pool: Dict[int, List[Tuple[int, np.ndarray, np.ndarray]]] = {}
            # chunks group by their storage row: ``seq`` itself for private
            # chunks, the planned arena row for registering chunks — the
            # registration writes land directly in the arena (no second
            # copy, ever); chunks adopted by reference are SKIPPED (every
            # tier already holds them under their arena row, and the
            # recomputed-suffix KV must never shadow the shared bytes)
            groups: Dict[int, Tuple[List[int], List[np.ndarray],
                                    List[np.ndarray]]] = {}
            for j in range(min(self.n_chunks - c0,
                               (S + self.chunk - 1) // self.chunk)):
                c = c0 + j
                if c in shared and c not in plan:
                    continue
                row = shared.get(c, seq)
                kr = k[j * self.chunk: (j + 1) * self.chunk]
                vr = kr if self.planes == 1 else \
                    v[j * self.chunk: (j + 1) * self.chunk]
                if kr.shape[0] < self.chunk:
                    pad = self.chunk - kr.shape[0]
                    kr = np.pad(kr, ((0, pad), (0, 0), (0, 0)))
                    vr = kr if self.planes == 1 else \
                        np.pad(vr, ((0, pad), (0, 0), (0, 0)))
                kc = kr.astype(self.dtype)
                vc = kc if self.planes == 1 else vr.astype(self.dtype)
                if c in plan:
                    # capture the pre-quantization rows: a warm adopter
                    # replays them into its cache, bitwise equal to the
                    # cold prefill it skips
                    fk = np.array(kr)
                    self._fidelity[(row, layer, c)] = \
                        (fk, fk if self.planes == 1 else np.array(vr))
                cids, kcs, vcs = groups.setdefault(row, ([], [], []))
                cids.append(c)
                kcs.append(kc)
                vcs.append(vc)
                where = placement.get(c, HOST)
                defer = where == DEVICE and self.use_pool and not pool_place
                if defer:
                    # decode thread reads the slab outside the lock: queue
                    # the placement; the next pooled fetch folds it in
                    # unbilled (device-produced KV, same as _pool_place)
                    self.pools[layer].pending_place[(row, c)] = \
                        self._plane_stack(kc, vc)
                    where = HOST
                self.tier[row, layer, c] = where
                key = (row, layer, c)
                if where in (HOST, DEVICE):
                    self._host_k[key], self._host_v[key] = kc, vc
                if where == DEVICE:
                    if self.use_pool:
                        to_pool.setdefault(row, []).append((c, kc, vc))
                    else:
                        self._promote_device(key, kc, vc)
            for row, items in to_pool.items():
                # leolint: waive[locklint,threadlint] reason=serial-path only: to_pool fills only when pool_place=True, which async admission never passes (workers defer via pending_place); here the decode thread is the caller
                self._pool_place(layer, row, items)
        if not groups:
            return
        for row, (cids, kcs, vcs) in groups.items():
            ks = np.stack(kcs)
            vs = ks if self.planes == 1 else np.stack(vcs)
            if executor is None:
                self._ingest_cold(layer, row, cids, ks, vs, bill_seq=seq)
            else:
                fut = executor.submit(self._ingest_cold, layer, row, cids,
                                      ks, vs, seq)
                with self._futs_lock:
                    self._ingest_futs[seq].append(fut)

    @worker_thread
    def _ingest_cold(self, layer: int, seq: int, cids: List[int],
                     kcs: np.ndarray, vcs: np.ndarray,
                     bill_seq: Optional[int] = None) -> None:
        """The write-behind half of :meth:`ingest`: fp16 replica + packed
        sidecar + abstract writes, with their billing.  kcs/vcs: (n, chunk,
        Hkv, hd) in store dtype, rows matching ``cids``.  ``seq`` is the
        STORAGE row (an arena row when a registration redirects);
        ``bill_seq`` attributes the traffic to the logical sequence."""
        bill = seq if bill_seq is None else bill_seq
        # injected worker-thread fault: an arbitrary bug in this work item.
        # It propagates through the future and surfaces at the seq's
        # ingest fence as IngestError — that sequence's terminal state,
        # never the batch's.
        self._fault_point("worker", (layer, seq))
        packed = None
        if self.disk_sidecar:
            # quantize OUTSIDE the lock (pure compute on private arrays) —
            # holding it here would stall decode fetches for the duration
            planes = (kcs,) if self.planes == 1 else (kcs, vcs)
            packed = tuple(compression.quantize_chunks(p, self.transit_codec)
                           for p in planes)
        # checksums over the exact bytes about to land, computed outside
        # the lock; the CRC rows are metadata (4B/chunk), not a tier
        # transfer — unbilled by I6 (see docs/INVARIANTS.md)
        crcs = q_crcs = None
        n = len(cids)
        if self._crc is not None:
            crcs = [self._crc32(self._plane_stack(kcs[i], vcs[i]))
                    for i in range(n)]
        if packed is not None and self._q_crc is not None:
            q_crcs = []
            for i in range(n):
                d = np.stack([pd.reshape(n, self.chunk, -1)[i]
                              for pd, _ in packed])
                s = np.stack([psc[i] for _, psc in packed])
                q_crcs.append(self._sidecar_crc(d, s))
        # PQ abstract plane: fold this batch's key vectors into the
        # layer's online codebook and encode every chunk.  The k-means
        # kernels (jax) run OUTSIDE any lock; the codebook mirror is
        # snapshotted and merged back under the leaf _pq_lock (last
        # writer wins — codebook drift is estimator error, never a
        # correctness hazard: attention always reads real KV).
        pq_codes_arr = pq_crcs = None
        if self.pq:
            vecs = kcs.reshape(-1, self.head_dim).astype(np.float32)
            # tail-chunk zero padding (and all-zero admission rows) must
            # not poison the codebook: train on non-zero rows only
            train = vecs[np.any(vecs != 0.0, axis=1)]
            with self._pq_lock:
                cb0 = self._pq_cb[layer].copy()
                cnt0 = self._pq_counts[layer].copy()
            cb1, cnt1 = pq_train(train, cb0, cnt0,
                                 iters=self.pq_train_iters,
                                 impl=self.pq_impl)
            pq_codes_arr = pq_encode(vecs, cb1, impl=self.pq_impl).reshape(
                n, self.chunk, self.kv_heads, self.pq_m)
            with self._pq_lock:
                self._pq_cb[layer] = cb1
                self._pq_counts[layer] = cnt1
                self._pq_codebook[layer] = cb1
            if self._pq_crc is not None:
                pq_crcs = [self._crc32(pq_codes_arr[i]) for i in range(n)]
        # transient write errors retry at the choke point; exhaustion
        # (DiskIOExhausted) surfaces at the fence, not into decode
        self._with_retries(
            lambda: self._fault_point("disk_write", (layer, seq)))
        with self._lock:
            idx = np.asarray(cids, np.int64)
            self._disk[seq, layer, idx, 0] = kcs
            if self.planes == 2:
                self._disk[seq, layer, idx, 1] = vcs
            self._abs_km[seq, layer, idx] = kcs.max(1)
            self._abs_kn[seq, layer, idx] = kcs.min(1)
            if crcs is not None:
                for i, c in enumerate(cids):
                    self._crc[seq, layer, c] = crcs[i]
                    self._crc_state[seq, layer, c] = _CRC_VALID
            rep_bytes = float(self.chunk_bytes)
            if packed is not None:
                for pl, (pd, psc) in enumerate(packed):
                    self._disk_q[seq, layer, idx, pl] = pd.reshape(
                        n, self.chunk, -1)
                    self._disk_scale[seq, layer, idx, pl] = psc
                self._sidecar_valid[seq, layer, idx] = True
                if q_crcs is not None:
                    for i, c in enumerate(cids):
                        self._q_crc[seq, layer, c] = q_crcs[i]
                rep_bytes = self._packed_bytes()
            if pq_codes_arr is not None:
                self._pq_codes[seq, layer, idx] = pq_codes_arr
                self._pq_valid[seq, layer, idx] = True
                if pq_crcs is not None:
                    for i, c in enumerate(cids):
                        self._pq_crc[seq, layer, c] = pq_crcs[i]
                # write-through codebook persistence, billed once per
                # cold batch (it is shared state, ~K*d floats)
                self._record(bill, HOST, DISK, "pq_codes_write",
                             4.0 * self.pq_m * self.pq_centroids
                             * (self.head_dim // self.pq_m))
            for _c in cids:
                self._record(bill, HOST, DISK, "kv_replica", rep_bytes)
                self._record(bill, HOST, DISK, "abstract",
                             self.abstract_bytes)
                if pq_codes_arr is not None:
                    self._record(bill, HOST, DISK, "pq_codes_write",
                                 float(self.pq_bytes))

    @any_thread
    def ingest_fence(self, seq: int) -> None:
        """Block until every in-flight write-behind ingest of ``seq`` has
        landed (replicas, sidecars, abstracts, billing).  Reads of the
        sequence's disk tier or abstracts are only ordered after this
        fence.  Must be called WITHOUT the store lock held — the pending
        workers need it to complete.

        Exception-safe: ALL futures are awaited even when one raises, so
        by the time the fence returns (or raises) no write of ``seq`` is
        still in flight and the row can be reclaimed safely.  The first
        failure re-raises wrapped as :class:`IngestError` — one typed,
        per-sequence terminal signal instead of a fence poisoned for
        every later admission of the slot."""
        with self._futs_lock:
            futs = self._ingest_futs.pop(seq, [])
        first: Optional[BaseException] = None
        for fut in futs:
            try:
                fut.result()
            except BaseException as e:
                if first is None:
                    first = e
        if first is not None:
            raise IngestError(seq, first) from first

    @any_thread
    def ingest_fence_all(self) -> None:
        """Fence every sequence (shutdown path).  Every sequence is drained
        even when one fails; the first failure re-raises at the end."""
        with self._futs_lock:
            seqs = list(self._ingest_futs)
        first: Optional[BaseException] = None
        for s in seqs:
            try:
                self.ingest_fence(s)
            except BaseException as e:
                if first is None:
                    first = e
        if first is not None:
            raise first

    @decode_thread_only
    def _pool_place(self, layer: int, seq: int,
                    items: List[Tuple[int, np.ndarray, np.ndarray]]) -> None:
        """Initial (prefill) pool placement: one scatter, no transit billing
        — the KV was produced on device; this is residency bookkeeping."""
        pool = self.pools[layer]
        slots = []
        for c, _, _ in items:
            slot, evicted = pool.alloc((seq, c), pinned=())
            if evicted is not None:
                self.tier[evicted[0], layer, evicted[1]] = HOST
            slots.append(slot)
        self._bill_flushed_rows(
            pool.scatter(slots, np.stack([self._plane_stack(kc, vc)
                                          for _, kc, vc in items])))

    # ------------------------------------------------------------------
    # Content-addressable shared-prefix cache (cross-request KV reuse)
    # ------------------------------------------------------------------
    def _phys(self, seq: int, c: int) -> int:
        """Resolve the storage row of (seq, chunk): chunks adopted by
        reference live in a shared arena row; everything else in place."""
        m = self._shared_map.get(seq)
        if m is None:
            return seq
        return m.get(c, seq)

    @any_thread
    def tier_view(self, seq: int, layer: int) -> np.ndarray:
        """Sequence-logical tier row with shared chunks resolved to their
        arena row's tier (the engine's prefetch planner reads this)."""
        with self._lock:
            t = np.array(self.tier[seq, layer], copy=True)
            m = self._shared_map.get(seq)
            if m:
                for c, row in m.items():
                    t[c] = self.tier[row, layer, c]
            return t

    @any_thread
    def prefix_probe(self, tokens) -> Dict[str, int]:
        """Read-only warm-span prediction (scheduler admission credit):
        how many chunks of ``tokens`` are adoptable right now, and how
        many of those already sit in the device pool.  Does not touch
        refcounts or skew the hit-rate counters."""
        if self._prefix is None:
            return {"hit_chunks": 0, "hit_tokens": 0, "device_hits": 0}
        hashes = chunk_hashes(np.asarray(tokens), self.chunk)
        with self._lock:
            matched = self._prefix.match(hashes, record=False)
            pool = self.pools[0]
            dev = sum(1 for row, c in matched
                      if pool is not None and (row, c) in pool.slot_of)
            ht = len(tokens) if len(matched) == len(hashes) \
                else len(matched) * self.chunk
            return {"hit_chunks": len(matched), "hit_tokens": int(ht),
                    "device_hits": int(dev)}

    @any_thread
    def prefix_admit(self, seq: int, tokens) -> int:
        """Content-addressable admission for ``seq``'s prompt.

        Matches the chunk-aligned (chain-hashed) prefix against the
        shared index and adopts every hit BY REFERENCE: a refcount per
        (arena row, chunk), zero bytes moved — billed as zero-byte
        ``prefix_ref`` ops so the ledger shows the op without inventing
        traffic.  Missed chunks are planned for registration into an
        arena row: ingest redirects their writes straight into that row
        (no second copy) and :meth:`finish_admission` publishes them.
        Returns the number of prompt tokens covered by adopted chunks
        (the engine resumes chunked prefill at the cold suffix)."""
        if self._prefix is None:
            return 0
        toks = np.asarray(tokens)
        hashes = chunk_hashes(toks, self.chunk)
        with self._lock:
            matched = self._prefix.match(hashes)
            mapping = {c: row for c, (row, _rc) in enumerate(matched)}
            self._prefix.acquire(matched)
            for _ in mapping:
                self._record(seq, HOST, DISK, "prefix_ref", 0.0)
            self.bytes_deduped += shared_prefix_savings(
                len(mapping), self.n_layers, self.chunk_bytes,
                self.abstract_bytes)
            miss = list(range(len(matched), len(hashes)))
            if miss:
                got = self._prefix.alloc_row()
                if got is not None:       # None: every arena row is pinned
                    row, scrub = got
                    if scrub:
                        self._scrub_row(row, scrub)
                    self._prefix.plan(row, miss)
                    self._prefix.acquire([(row, c) for c in miss])
                    for c in miss:
                        mapping[c] = row
                    self._reg_plan[seq] = {c: hashes[c] for c in miss}
            if mapping:
                self._shared_map[seq] = mapping
            self.prefix_admissions += 1
            if matched:
                self.warm_admissions += 1
            return len(toks) if len(matched) == len(hashes) \
                else len(matched) * self.chunk

    @any_thread
    def finish_admission(self, seq: int) -> None:
        """Publish the chunks ``seq`` registered, making them adoptable.

        MUST be ordered after :meth:`ingest_fence` — adopters read the
        arena row's disk replica, which is only guaranteed written once
        the write-behind ingest has landed.  Losing a publish race (a
        concurrent registration of identical content landed first) is
        benign: the row stays private to this sequence and is reclaimed
        once released."""
        with self._lock:
            plan = self._reg_plan.pop(seq, None)
            if not plan or self._prefix is None:
                return
            mapping = self._shared_map.get(seq, {})
            for c, h in plan.items():
                row = mapping.get(c)
                if row is not None and row >= self.n_seqs:
                    self._prefix.publish(row, c, h)

    @any_thread
    def prefix_fill_rows(self, seq: int, n_tokens: int
                         ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Assemble the adopted span's KV rows for the warm cache fill.

        One (k_rows, v_rows) pair per layer, each (n_tokens, Hkv, hd) in
        the ORIGINAL cache dtype: registration captured the registrant's
        pre-quantization rows precisely so a warm resume is bitwise
        identical to the cold chunked prefill it skips.  ``n_tokens``
        must be chunk-aligned and inside the adopted span."""
        assert n_tokens % self.chunk == 0, (n_tokens, self.chunk)
        nc = n_tokens // self.chunk
        with self._lock:
            mapping = self._shared_map.get(seq, {})
            out: List[Tuple[np.ndarray, np.ndarray]] = []
            for layer in range(self.n_layers):
                ks, vs = [], []
                for c in range(nc):
                    fk, fv = self._fidelity[(mapping[c], layer, c)]
                    ks.append(fk)
                    vs.append(fv)
                out.append((np.concatenate(ks), np.concatenate(vs)))
            return out

    def _scrub_row(self, row: int, cs: Sequence[int]) -> None:
        """Reclaim an evicted arena row's residue across every tier view
        (host copies, legacy device dicts, pool slots, fidelity rows,
        abstracts, sidecar validity) before a new registration reuses it.
        Caller holds ``_lock``.  Disk bytes need no scrub: the new
        registration overwrites every chunk it publishes."""
        for layer in range(self.n_layers):
            pool = self.pools[layer]
            for c in cs:
                key = (row, layer, c)
                self._host_k.pop(key, None)
                self._host_v.pop(key, None)
                self._dev_k.pop(key, None)
                self._dev_v.pop(key, None)
                self._lru.pop(key, None)
                self._fidelity.pop(key, None)
                if pool is not None:
                    pool.evict((row, c))
                self.tier[row, layer, c] = HOST
                self._sidecar_valid[row, layer, c] = False
                if self._pq_valid is not None:
                    self._pq_valid[row, layer, c] = False
                if self._crc_state is not None:
                    self._crc_state[row, layer, c] = _CRC_NONE
                self._disk_lost.discard((row, layer, c))
                self._abs_km[row, layer, c] = -np.inf
                self._abs_kn[row, layer, c] = np.inf
                self._requant_pending.pop(key, None)
                if key in self._chunk_version:
                    self._chunk_version[key] += 1

    def _cow(self, seq: int, c: int) -> None:
        """Copy-on-write: privatize a shared chunk ``seq`` is about to
        append into.  Copies the arena row's payload (disk replica,
        sidecar, abstracts, host copy) into the sequence's own row and
        drops the reference — exactly one chunk copy per layer, billed
        as ``cow_read`` (disk→host) + ``cow_copy`` (host→disk).  The
        arena chunk itself is untouched: still-shared readers keep their
        bytes bit-for-bit.  Caller holds ``_lock``."""
        mapping = self._shared_map.get(seq)
        if not mapping or c not in mapping:
            return
        row = mapping.pop(c)
        if not mapping:
            self._shared_map.pop(seq, None)
        cb = float(self.chunk_bytes)
        for layer in range(self.n_layers):
            self._record(seq, DISK, HOST, "cow_read", cb)
            self._disk[seq, layer, c] = self._disk[row, layer, c]
            self._abs_km[seq, layer, c] = self._abs_km[row, layer, c]
            self._abs_kn[seq, layer, c] = self._abs_kn[row, layer, c]
            if self.disk_sidecar:
                self._disk_q[seq, layer, c] = self._disk_q[row, layer, c]
                self._disk_scale[seq, layer, c] = \
                    self._disk_scale[row, layer, c]
                self._sidecar_valid[seq, layer, c] = \
                    self._sidecar_valid[row, layer, c]
            if self.pq:
                # the private copy inherits the arena chunk's codes and
                # their validity/CRC — same bytes, same codes
                self._pq_codes[seq, layer, c] = self._pq_codes[row, layer, c]
                self._pq_valid[seq, layer, c] = \
                    self._pq_valid[row, layer, c]
                if self._pq_crc is not None:
                    self._pq_crc[seq, layer, c] = self._pq_crc[row, layer, c]
            if self._crc is not None:
                # the private copy inherits the arena chunk's checksum
                # state — same bytes, same CRC
                self._crc[seq, layer, c] = self._crc[row, layer, c]
                self._crc_state[seq, layer, c] = \
                    self._crc_state[row, layer, c]
                if self._q_crc is not None:
                    self._q_crc[seq, layer, c] = self._q_crc[row, layer, c]
            src = (row, layer, c)
            dst = (seq, layer, c)
            if src in self._host_k:
                self._host_k[dst] = np.array(self._host_k[src])
                self._host_v[dst] = self._host_k[dst] if self.planes == 1 \
                    else np.array(self._host_v[src])
                self.tier[seq, layer, c] = HOST
            else:
                self.tier[seq, layer, c] = DISK
            self._record(seq, HOST, DISK, "cow_copy", cb)
        if self._prefix is not None:
            self._prefix.decref([(row, c)])
        self.cow_copies += 1

    def prefix_stats(self) -> Dict[str, float]:
        """Cross-request reuse counters (merged into scheduler stats)."""
        if self._prefix is None:
            return {}
        with self._lock:
            px = self._prefix
            total = px.hit_chunks + px.miss_chunks
            return {"prefix_hit_rate": px.hit_chunks / max(1, total),
                    "prefix_hit_chunks": float(px.hit_chunks),
                    "prefix_miss_chunks": float(px.miss_chunks),
                    "prefix_lookups": float(px.lookups),
                    "shared_chunks": float(px.shared_chunks()),
                    "shared_refs": float(px.live_refs()),
                    "bytes_deduped": float(self.bytes_deduped),
                    "cow_copies": float(self.cow_copies),
                    "warm_admissions": float(self.warm_admissions),
                    "prefix_admissions": float(self.prefix_admissions),
                    "arena_evictions": float(px.evicted_rows)}

    # ------------------------------------------------------------------
    def read_abstracts(self, layer: int, chunks: Sequence[int], *,
                       seq: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """LKA: fetch (kmax, kmin) for chunks; disk chunks cost abstract I/O."""
        with self._lock:
            idx = np.asarray(list(chunks), np.int64)
            rows = np.asarray([self._phys(seq, int(c)) for c in idx],
                              np.int64)
            for r, c in zip(rows, idx):
                if self.tier[r, layer, c] == DISK:
                    self._record(seq, DISK, HOST, "abstract",
                                 self.abstract_bytes)
            return (self._abs_km[rows, layer, idx].copy(),
                    self._abs_kn[rows, layer, idx].copy())

    @any_thread
    def read_abstracts_batch(self, layer: int,
                             chunks_by_seq: Dict[int, Sequence[int]]
                             ) -> Tuple[np.ndarray, np.ndarray, Dict[int, float]]:
        """Batched LKA read: one padded (B, ncmax, Hkv, hd) fancy-index into
        the persistent abstract stack for the round's importance evaluation
        (no per-sequence Python loop).  Returns (kmax, kmin, abstract bytes
        billed per sequence); rows follow dict order, padded with zeros.
        Billing is exact per sequence: every disk-tier chunk read bills one
        abstract, mirrored to the owner's log."""
        with self._lock:
            B = len(chunks_by_seq)
            ncmax = max((len(c) for c in chunks_by_seq.values()), default=0)
            km = np.zeros((B, ncmax, self.kv_heads, self.head_dim), np.float32)
            kn = np.zeros_like(km)
            billed: Dict[int, float] = {}
            for i, (seq, chunks) in enumerate(chunks_by_seq.items()):
                idx = np.asarray(list(chunks), np.int64)
                # shared chunks read the arena row's abstract (computed
                # once by the registrant); private sequences keep the
                # scalar-row fancy-index fast path
                m = self._shared_map.get(seq)
                rows = seq if m is None else np.asarray(
                    [m.get(int(c), seq) for c in idx], np.int64)
                km[i, :len(idx)] = self._abs_km[rows, layer, idx]
                kn[i, :len(idx)] = self._abs_kn[rows, layer, idx]
                n_disk = int(np.count_nonzero(
                    self.tier[rows, layer, idx] == DISK))
                for _ in range(n_disk):
                    self._record(seq, DISK, HOST, "abstract",
                                 self.abstract_bytes)
                billed[seq] = n_disk * float(self.abstract_bytes)
            return km, kn, billed

    @any_thread
    def read_abstracts_pq_batch(self, layer: int,
                                chunks_by_seq: Dict[int, Sequence[int]]
                                ) -> Tuple[np.ndarray, np.ndarray,
                                           np.ndarray, np.ndarray,
                                           np.ndarray, Dict[int, float]]:
        """Batched PQ abstract read: codes + validity next to the min/max
        boxes, so the engine can score valid chunks via ADC and fall back
        to the bounds matmul BITWISE for the rest (append-dirtied, torn,
        corrupt, or unreadable codes).  Returns ``(kmax, kmin, codes,
        valid, codebook, billed)``; codes is (B, ncmax, chunk, Hkv, m)
        uint8, valid (B, ncmax) bool, codebook the layer's live (m, K,
        dsub) snapshot.  Billing per disk-tier chunk: ``pq_codes_read``
        (code bytes) when its codes serve, ``abstract`` (min/max bytes)
        when it degrades — degradations are observable in the ledger.

        The code gather runs through the ``pq_read`` fault choke point
        with bounded retry; exhaustion degrades the whole gather to
        min/max (counted in ``fault_counters['pq_fallbacks']``) instead
        of surfacing I/O errors into importance evaluation — selection
        is an estimator, never worth failing a round over.
        """
        assert self.pq, "store built with abstract_kind='minmax'"
        with self._lock:
            B = len(chunks_by_seq)
            ncmax = max((len(c) for c in chunks_by_seq.values()), default=0)
            km = np.zeros((B, ncmax, self.kv_heads, self.head_dim),
                          np.float32)
            kn = np.zeros_like(km)
            codes = np.zeros((B, ncmax, self.chunk, self.kv_heads,
                              self.pq_m), np.uint8)
            valid = np.zeros((B, ncmax), bool)
            billed: Dict[int, float] = {}
            for i, (seq, chunks) in enumerate(chunks_by_seq.items()):
                idx = np.asarray(list(chunks), np.int64)
                m = self._shared_map.get(seq)
                rows = seq if m is None else np.asarray(
                    [m.get(int(c), seq) for c in idx], np.int64)
                km[i, :len(idx)] = self._abs_km[rows, layer, idx]
                kn[i, :len(idx)] = self._abs_kn[rows, layer, idx]
                pqv = np.array(self._pq_valid[rows, layer, idx])
                rlist = np.broadcast_to(rows, idx.shape)

                def read():
                    self._fault_point(
                        "pq_read",
                        [(int(p), layer, int(c))
                         for p, c in zip(rlist, idx)])
                    return np.asarray(self._pq_codes[rows, layer, idx])

                blk = None
                if pqv.any():
                    try:
                        blk = self._with_retries(read)
                    except DiskIOExhausted:
                        # persistent code-read failure: every chunk of
                        # this gather degrades to its min/max box
                        self._count("pq_fallbacks",
                                    int(np.count_nonzero(pqv)))
                        pqv[:] = False
                if blk is not None and self._pq_crc is not None:
                    for j in np.nonzero(pqv)[0]:
                        p, c = int(rlist[j]), int(idx[j])
                        if self._crc32(blk[j]) != int(
                                self._pq_crc[p, layer, c]):
                            # silent media corruption: quarantine the
                            # codes (min/max serves; the requant sweep
                            # re-encodes off the replica)
                            pqv[j] = False
                            self._pq_valid[p, layer, c] = False
                            key = (p, layer, c)
                            self._requant_pending.setdefault(
                                key, self._sweep_round)
                            self._count("checksum_failures")
                            self._count("pq_fallbacks")
                if blk is not None:
                    codes[i, :len(idx)][pqv] = blk[pqv]
                valid[i, :len(idx)] = pqv
                disk = np.asarray(self.tier[rows, layer, idx] == DISK)
                n_pq = int(np.count_nonzero(disk & pqv))
                n_mm = int(np.count_nonzero(disk & ~pqv))
                for _ in range(n_pq):
                    self._record(seq, DISK, HOST, "pq_codes_read",
                                 float(self.pq_bytes))
                for _ in range(n_mm):
                    self._record(seq, DISK, HOST, "abstract",
                                 self.abstract_bytes)
                billed[seq] = (n_pq * float(self.pq_bytes)
                               + n_mm * float(self.abstract_bytes))
            with self._pq_lock:
                cb = self._pq_cb[layer].copy()
            return km, kn, codes, valid, cb, billed

    # ------------------------------------------------------------------
    def _promote_device(self, key: Tuple[int, int, int], kc: np.ndarray,
                        vc: np.ndarray) -> None:
        """Pin a chunk device-side (legacy dict tier), demoting LRU chunks
        past the shared budget (free: host copies + disk replicas survive).
        OrderedDict front == LRU, so budgeted eviction is O(1)."""
        self._dev_k[key], self._dev_v[key] = kc, vc
        self.tier[key[0], key[1], key[2]] = DEVICE
        self._lru[key] = None
        self._lru.move_to_end(key)
        if self.device_budget is not None:
            while len(self._dev_k) > self.device_budget:
                victim, _ = self._lru.popitem(last=False)
                self._dev_k.pop(victim, None)
                self._dev_v.pop(victim, None)
                self.tier[victim[0], victim[1], victim[2]] = HOST

    def _touch(self, key: Tuple[int, int, int]) -> None:
        self._lru.move_to_end(key)

    @decode_thread_only
    def fetch_chunks(self, layer: int, chunks: Sequence[int], *,
                     seq: int = 0, to_device: bool = True
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Promote chunks to the device working set; returns stacked K/V
        (n, chunk, Hkv, hd).  Disk promotions go through the transit codec."""
        with self._lock:
            ks, vs = [], []
            for c in chunks:
                p = self._phys(seq, c)
                key = (p, layer, c)
                self.access[seq, layer, c] += 1
                if key in self._dev_k:
                    self._touch(key)
                    ks.append(self._dev_k[key])
                    vs.append(self._dev_v[key])
                    continue
                if self.tier[p, layer, c] == DISK or key not in self._host_k:
                    kc = vc = None
                    fell_back = False
                    if self._sidecar_ok(p, layer, c):
                        try:
                            # leolint: waive[locklint] reason=decode-thread fetch path: sidecar dequant under the short fetch critical section is the accepted PR-2 design (tier tables must not move mid-fetch)
                            kv, bad = self._read_sidecar(layer, [(p, c)])
                        except DiskIOExhausted:
                            kv, bad = None, {0}
                        if bad:
                            # quarantined (CRC mismatch) or unreadable:
                            # degrade to the lossless fp16 replica below
                            fell_back = True
                        else:
                            kc, vc = kv[0][0], kv[0][-1]
                            nb = self._packed_bytes()
                    if kc is None:
                        try:
                            blk, lost = self._replica_read_verified(
                                layer, [(seq, p, c)])
                        except DiskIOExhausted:
                            blk, lost = None, {0}
                            self._disk_lost.add((p, layer, c))
                        if blk is None or lost:
                            # the replica is gone too: surface the typed
                            # loss for the engine to recompute or contain
                            raise ChunkLostError(layer, [(seq, p, c)])
                        kc, vc = blk[0][0], blk[0][-1]
                        nb = (self._disk_read_bytes() if self.disk_sidecar
                              else self._transit_bytes())
                    if fell_back:
                        self.degraded_seqs.add(seq)
                        self._record(seq, DISK, HOST, "kv_fallback", nb)
                    elif p != seq:
                        self._record(seq, DISK, HOST, "kv_shared", nb)
                    else:
                        self._record(seq, DISK, HOST, "kv", nb)
                    self._host_k[key], self._host_v[key] = kc, vc
                kc, vc = self._host_k[key], self._host_v[key]
                if p != seq:
                    self._record(seq, HOST, DEVICE, "kv_shared",
                                 self._transit_bytes())
                else:
                    self._record(seq, HOST, DEVICE, "kv",
                                 self._transit_bytes())
                if to_device:
                    self._promote_device(key, kc, vc)
                ks.append(kc)
                vs.append(vc)
            return np.stack(ks), np.stack(vs)

    @decode_thread_only
    def fetch_chunks_batch(self, layer: int,
                           chunks_by_seq: Dict[int, Sequence[int]], *,
                           pad_to: Optional[int] = None, to_device: bool = True
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batch-coalesced promotion for one decode round of one layer
        (legacy host-assembled working set; the PR-1 synchronous path).

        All disk-resident (seq, chunk) pairs across the batch are read from
        the shared memmap in ONE fancy-indexed gather, then every sequence's
        ragged selection is padded to ``pad_to`` (default: the round's max).

        Returns (kg, vg, nsel): kg/vg (B, pad_to, chunk, Hkv, hd) in store
        dtype with zero padding, nsel (B,) the per-row valid chunk counts.
        Rows follow dict order.  Accounting matches per-seq ``fetch_chunks``
        byte-for-byte; only the I/O issue pattern differs.
        """
        with self._lock:
            items = list(chunks_by_seq.items())
            B = len(items)
            nsel = np.array([len(c) for _, c in items], np.int32)
            nmax = int(pad_to if pad_to is not None
                       else (nsel.max() if B else 0))

            # leolint: waive[locklint] reason=decode-thread batch fetch: disk staging (and its sidecar dequant) stays under _lock so the gathered tier view is atomic; accepted PR-2 design
            self._stage_disk(layer, [(seq, c) for seq, chunks in items
                                     for c in chunks],
                             nbytes=(self._disk_read_bytes()
                                     if self.disk_sidecar
                                     else self._transit_bytes()),
                             skip_pool=False)

            kg = np.zeros((B, nmax, self.chunk, self.kv_heads, self.head_dim),
                          self.dtype)
            # latent layout: there is no V plane — return the latent stack
            # in both positions instead of assembling a duplicate copy
            vg = kg if self.planes == 1 else np.zeros_like(kg)
            for i, (seq, chunks) in enumerate(items):
                for j, c in enumerate(chunks):
                    p = self._phys(seq, c)
                    key = (p, layer, c)
                    self.access[seq, layer, c] += 1
                    if key in self._dev_k:
                        self._touch(key)
                        kg[i, j] = self._dev_k[key]
                        if self.planes == 2:
                            vg[i, j] = self._dev_v[key]
                        continue
                    # the legacy path assembles a host-side stack per
                    # sequence, so a shared chunk genuinely crosses the
                    # link per reader — billed honestly, attributed as
                    # kv_shared (the pooled path dedupes instead)
                    if p != seq:
                        self._record(seq, HOST, DEVICE, "kv_shared",
                                     self._transit_bytes())
                    else:
                        self._record(seq, HOST, DEVICE, "kv",
                                     self._transit_bytes())
                    if to_device:
                        self._promote_device(key, self._host_k[key],
                                             self._host_v[key])
                    kg[i, j] = self._host_k[key]
                    if self.planes == 2:
                        vg[i, j] = self._host_v[key]
            return kg, vg, nsel

    # ------------------------------------------------------------------
    # Pooled path: device-resident slab, delta uploads, real codec
    # ------------------------------------------------------------------
    def _stage_disk(self, layer: int, keys: Sequence[Tuple[int, int]], *,
                    nbytes: float, skip_pool: bool,
                    retier: bool = False) -> Tuple[int, float]:
        """Coalesce disk→host reads for every key lacking a host copy.
        One fancy-indexed memmap gather per representation: sidecar-valid
        chunks move packed bytes (dequantized host-side), the rest read
        the fp16 replica and bill ``nbytes``.  ``skip_pool``: pool-resident
        chunks need no host copy.  ``retier`` marks staged chunks HOST so a
        later fetch sees the copy instead of re-reading (and re-billing)
        the disk.  Returns (chunks read, bytes billed)."""
        need: List[Tuple[int, int, int]] = []   # (billed seq, phys row, c)
        seen = set()
        for seq, c in keys:
            p = self._phys(seq, c)
            key = (p, layer, c)
            if key in seen:
                continue            # shared chunks dedupe on the arena key
            seen.add(key)
            if skip_pool and self.pools[layer] is not None \
                    and (p, c) in self.pools[layer].slot_of:
                continue
            if not skip_pool and key in self._dev_k:
                continue
            if key in self._host_k and self.tier[p, layer, c] != DISK:
                continue
            need.append((seq, p, c))
        billed = 0.0
        need_q = [e for e in need if self._sidecar_ok(e[1], layer, e[2])]
        need_fp = [e for e in need if not self._sidecar_ok(e[1], layer,
                                                           e[2])]
        # sidecar group first: a CRC-quarantined (or unreadable) sidecar
        # key degrades into the fp16 group below and bills kv_fallback —
        # the read that actually happened, at its honest full-chunk cost
        fallback: Set[Tuple[int, int]] = set()
        if need_q:
            per_chunk = self._packed_bytes()
            try:
                blk, bad = self._read_sidecar(
                    layer, [(p, c) for _, p, c in need_q])
            except DiskIOExhausted:
                blk, bad = None, set(range(len(need_q)))
            for i, (seq, p, c) in enumerate(need_q):
                if blk is None or i in bad:
                    fallback.add((p, c))
                    need_fp.append((seq, p, c))
                    continue
                key = (p, layer, c)
                if p != seq:
                    # refcounted promotion of a shared chunk: read once
                    # per arena key, billed to the triggering sequence
                    self._record(seq, DISK, HOST, "kv_shared", per_chunk)
                else:
                    self._record(seq, DISK, HOST, "kv", per_chunk)
                billed += per_chunk
                self._host_k[key], self._host_v[key] = blk[i][0], blk[i][-1]
                if retier:
                    self.tier[p, layer, c] = HOST
        lost: List[Tuple[int, int, int]] = []
        if need_fp:
            try:
                blk, bad = self._replica_read_verified(layer, need_fp)
            except DiskIOExhausted:
                # unreadable past the retry budget: degrade the whole
                # gather to disk-lost — the engine recomputes the span
                # from the prompt or fails just the affected sequence
                blk, bad = None, set(range(len(need_fp)))
                for _, p, c in need_fp:
                    self._disk_lost.add((p, layer, c))
            for i, (seq, p, c) in enumerate(need_fp):
                if blk is None or i in bad:
                    lost.append((seq, p, c))
                    continue
                key = (p, layer, c)
                if (p, c) in fallback:
                    self.degraded_seqs.add(seq)
                    self._record(seq, DISK, HOST, "kv_fallback", nbytes)
                elif p != seq:
                    self._record(seq, DISK, HOST, "kv_shared", nbytes)
                else:
                    self._record(seq, DISK, HOST, "kv", nbytes)
                billed += nbytes
                self._host_k[key], self._host_v[key] = blk[i][0], blk[i][-1]
                if retier:
                    self.tier[p, layer, c] = HOST
        if lost:
            raise ChunkLostError(layer, lost)
        return len(need), billed

    @worker_thread
    def stage_host(self, layer: int,
                   chunks_by_seq: Dict[int, Sequence[int]]) -> int:
        """Speculative disk→host staging (DTP prefetch).  Pulls predicted
        chunks off disk so the true fetch finds them host-resident (they
        are re-tiered HOST — without that the fetch would re-read and
        re-bill the same chunk, and the prefetch would hide nothing);
        wrong predictions cost only this read.  Returns #chunks staged.

        Faults are swallowed here BY DESIGN: the staging is speculative,
        so a lost/unreadable chunk costs nothing now — the decode thread's
        own fetch re-detects it on the authoritative path and recovers
        there (the disk-lost marking this call already made is kept)."""
        with self._lock:
            keys = [(seq, c) for seq, chunks in chunks_by_seq.items()
                    for c in chunks]
            try:
                # leolint: waive[locklint] reason=prefetch staging holds _lock so the re-tier to HOST is atomic with the read; the decode thread stalls at most one speculative batch (measured in fig13 prefetch bench)
                n, _ = self._stage_disk(layer, keys,
                                        nbytes=self._disk_read_bytes(),
                                        skip_pool=True, retier=True)
            except (ChunkLostError, DiskIOExhausted):
                return 0
            return n

    @decode_thread_only
    def fetch_chunks_pooled(self, layer: int,  # leolint: waive[locklint] reason=decode-thread pooled fetch: dequant+scatter run under _lock by design so tier tables stay consistent across the gather; workers stall for the short critical section (PR-2/PR-3 accepted cost)
                            chunks_by_seq: Dict[int, Sequence[int]], *,
                            pad_to: Optional[int] = None,
                            theta: float = 1.0
                            ) -> Tuple[np.ndarray, np.ndarray, FetchStats]:
        """Delta promotion into the layer's device slab.

        Chunks already pool-resident cost NOTHING (no host stack, no
        upload, no bytes billed); only the missing delta is stacked and
        scattered into freshly-allocated slots.  With ``real_codec``, the
        first ``round(theta * missing)`` chunks (canonical key order) cross
        host→device as packed int4/int8 + f32 scales and are dequantized on
        device (``kernels.kv_quant``); the rest go as fp16.  Billing is the
        actual payload per chunk.

        Returns (slots, nsel, stats): slots (B, pad_to) int32 indices into
        ``pools[layer]`` (padding rows point at slot 0 — the engine masks
        them), nsel (B,) valid counts.  Rows follow dict order.
        """
        if not self.use_pool:
            raise ValueError(
                "fetch_chunks_pooled requires a pooled store — construct "
                "TieredKVStore(use_pool=True, ...) or use fetch_chunks / "
                "fetch_chunks_batch on the legacy host-assembled path")
        with self._lock:
            st = FetchStats()
            pool = self.pools[layer]
            items = list(chunks_by_seq.items())
            B = len(items)
            nsel = np.array([len(c) for _, c in items], np.int32)
            nmax = int(pad_to if pad_to is not None
                       else (nsel.max() if B else 0))

            t0 = time.perf_counter()
            st.disk_reads, st.disk_bytes = self._stage_disk(
                layer, [(seq, c) for seq, chunks in items for c in chunks],
                nbytes=self._disk_read_bytes(), skip_pool=True)
            st.gather_s = time.perf_counter() - t0

            slots = np.zeros((B, nmax), np.int32)
            pinned = {(self._phys(seq, c), c)
                      for seq, chunks in items for c in chunks}
            # fold deferred prefill placements (admission under decode)
            # into this round's slab update — unbilled, the decode thread
            # is the only pool mutator so the attend gather never races
            place_keys: List[Tuple[int, int]] = []
            place_slots: List[int] = []
            place_kv: List[np.ndarray] = []
            fresh: Dict[Tuple[int, int], int] = {}
            if pool.pending_place:
                for key, kv in list(pool.pending_place.items()):
                    pool.pending_place.pop(key)
                    if not pool.free and all(v in pinned
                                             for v in pool.slot_of):
                        continue       # pool pinned solid: stays on host
                    slot, evicted = pool.alloc(key, pinned)
                    if evicted is not None:
                        self.tier[evicted[0], layer, evicted[1]] = HOST
                    self.tier[key[0], layer, key[1]] = DEVICE
                    place_keys.append(key)
                    place_slots.append(slot)
                    place_kv.append(kv)
            missing: List[Tuple[int, int, int, int, int]] = []
            for i, (seq, chunks) in enumerate(items):
                for j, c in enumerate(chunks):
                    self.access[seq, layer, c] += 1
                    p = self._phys(seq, c)
                    slot = pool.lookup((p, c))
                    if slot is None:
                        missing.append((i, j, seq, p, c))
                    else:
                        slots[i, j] = slot
                        st.hits += 1
            t1 = time.perf_counter()

            def scrub_partial():
                # a worker future / jit dispatch raised between slot
                # allocation and the slab scatter landing: residency must
                # never point at a slab row the scatter did not write.
                # Evict the half-uploaded slots back to HOST (host copies
                # and disk replicas are intact, so nothing is lost) and
                # return deferred placements to pending_place for the
                # next fetch.  The lock itself is released by ``with``.
                for pk_, slot_ in fresh.items():
                    if pool.slot_of.get(pk_) == slot_:
                        pool.slot_of.pop(pk_, None)
                        pool.free.append(slot_)
                    self.tier[pk_[0], layer, pk_[1]] = HOST
                for pk_, slot_, kv_ in zip(place_keys, place_slots,
                                           place_kv):
                    if pool.slot_of.get(pk_) == slot_:
                        pool.slot_of.pop(pk_, None)
                        pool.free.append(slot_)
                    self.tier[pk_[0], layer, pk_[1]] = HOST
                    pool.pending_place[pk_] = kv_

            if missing:
                # shared chunks dedupe here too: two sequences missing the
                # same arena chunk allocate ONE slot and bill ONE upload
                # (attributed to the first waiter); allocating the key
                # twice would orphan the first slot
                up_slots: List[int] = []
                up_keys: List[Tuple[int, int, int]] = []  # (seq, phys, c)
                try:
                    for i, j, seq, p, c in missing:
                        pk = (p, c)
                        slot = fresh.get(pk)
                        if slot is None:
                            slot, evicted = pool.alloc(pk, pinned)
                            if evicted is not None:
                                self.tier[evicted[0], layer,
                                          evicted[1]] = HOST
                            self.tier[p, layer, c] = DEVICE
                            fresh[pk] = slot
                            up_slots.append(slot)
                            up_keys.append((seq, p, c))
                        slots[i, j] = slot
                    kv_stack = np.stack(
                        [self._plane_stack(self._host_k[(p, layer, c)],
                                           self._host_v[(p, layer, c)])
                         for _, p, c in up_keys])  # (m, planes, c, Hkv, hd)
                    m = len(up_keys)
                    n_comp = 0
                    if self.real_codec:
                        n_comp = int(round(min(1.0, max(0.0, theta)) * m))
                    if n_comp:
                        from repro.kernels.kv_quant.ops import kv_dequant
                        dq = lambda d, s: kv_dequant(
                            jnp.asarray(d), jnp.asarray(s),
                            codec=self.transit_codec,
                            out_dtype=self.dtype).reshape(
                                n_comp, self.chunk, self.kv_heads,
                                self.head_dim)
                        kv_dev = jnp.stack(
                            [dq(*compression.quantize_chunks(
                                kv_stack[:n_comp, pl], self.transit_codec))
                             for pl in range(self.planes)], axis=1)
                        if n_comp < m:
                            kv_dev = jnp.concatenate(
                                [kv_dev, jnp.asarray(kv_stack[n_comp:])])
                    else:
                        kv_dev = kv_stack
                    if place_kv:           # deferred placements ride along
                        pk = np.stack(place_kv)
                        kv_dev = jnp.concatenate([kv_dev, jnp.asarray(pk)]) \
                            if isinstance(kv_dev, jnp.ndarray) \
                            else np.concatenate([kv_dev, pk])
                        up_slots = up_slots + place_slots
                    # bucket the scatter shape so repeated rounds reuse the
                    # compiled program instead of recompiling per delta size
                    pad_to = -(-len(up_slots) // self.upload_pad) \
                        * self.upload_pad
                    self._bill_flushed_rows(
                        pool.scatter(up_slots, kv_dev, pad_to=pad_to))
                except BaseException:
                    scrub_partial()
                    raise
                per_comp = self._packed_bytes() if self.real_codec \
                    else self._transit_bytes()
                per_plain = float(self.chunk_bytes) if self.real_codec \
                    else self._transit_bytes()
                for idx, (seq, p, _c) in enumerate(up_keys):
                    nb = per_comp if idx < n_comp else per_plain
                    if p != seq:
                        self._record(seq, HOST, DEVICE, "kv_shared", nb)
                    else:
                        self._record(seq, HOST, DEVICE, "kv", nb)
                    st.upload_bytes += nb
                st.uploads = m
                st.compressed = n_comp
                self.codec_uploads += n_comp
                self.plain_uploads += m - n_comp
            elif place_slots:
                pad_to = -(-len(place_slots) // self.upload_pad) \
                    * self.upload_pad
                try:
                    self._bill_flushed_rows(
                        pool.scatter(place_slots, np.stack(place_kv),
                                     pad_to=pad_to))
                except BaseException:
                    scrub_partial()
                    raise
            elif pool.pending:
                self._bill_flushed_rows(pool.scatter([], None))
            st.upload_s = time.perf_counter() - t1
            return slots, nsel, st

    def pool_stats(self) -> Dict[str, float]:
        """Aggregate pool residency counters across layers (+ hit rate and
        live occupancy — the scheduler's pool-aware admission reads the
        free/resident slot counts instead of estimating analytically)."""
        pools = [p for p in self.pools if p is not None]
        hits = sum(p.hits for p in pools)
        misses = sum(p.misses for p in pools)
        uploads = sum(p.uploads for p in pools)
        return {"hits": hits, "misses": misses, "uploads": uploads,
                "hit_rate": hits / max(1, hits + misses),
                "slots": pools[0].n_slots if pools else 0,
                "free_slots": (min(len(p.free) for p in pools)
                               if pools else 0),
                "resident": (max(len(p.slot_of) for p in pools)
                             if pools else 0)}

    # ------------------------------------------------------------------
    @decode_thread_only
    def demote(self, layer: int, chunks: Sequence[int], to: str = HOST, *,
               seq: int = 0) -> None:
        """Eviction is free toward disk (replicas, §4.3)."""
        with self._lock:
            for c in chunks:
                p = self._phys(seq, c)
                key = (p, layer, c)
                self._dev_k.pop(key, None)
                self._dev_v.pop(key, None)
                self._lru.pop(key, None)
                if self.pools[layer] is not None:
                    self.pools[layer].evict((p, c))
                if to == DISK:
                    self._host_k.pop(key, None)
                    self._host_v.pop(key, None)
                self.tier[p, layer, c] = to

    # ------------------------------------------------------------------
    # Whole-sequence preemption (overload control)
    # ------------------------------------------------------------------
    @decode_thread_only
    def swap_out_seq(self, seq: int) -> int:
        """Demote a preempted victim's ENTIRE hot working set down-tier.

        The disk replica is write-through (appends land every round), so
        swap-out moves no payload bytes — like :meth:`demote` it RELEASES
        resources: shared prefix chunks privatize first (their arena refs
        drop — a suspended victim must not pin arena rows), device-pool
        slots and legacy device entries free, and every host copy drops.
        Each previously host-resident chunk is billed as a zero-byte
        ``kv_swapout`` audit op (the ``prefix_ref`` precedent: the ledger
        records the op without claiming traffic that never crossed).  The
        resident set is remembered so :meth:`swap_in_seq` restores exactly
        it.  The caller (engine) fences the seq's write-behind ingest
        first.  Unlike :meth:`clear_seq` this preserves the slot's access
        counts, abstracts, logs and CRC state — the sequence is paused,
        not retired.  Returns the number of chunks swapped out."""
        with self._lock:
            if self._prefix is not None:
                for c in list(self._shared_map.get(seq) or {}):
                    self._cow(seq, c)
            resident: Dict[int, List[int]] = {}
            n = 0
            for layer in range(self.n_layers):
                pool = self.pools[layer]
                cs = {c for (s, l, c) in self._host_k
                      if s == seq and l == layer}
                cs |= {c for (s, l, c) in self._dev_k
                       if s == seq and l == layer}
                if pool is not None:
                    cs |= {c for (s, c) in pool.slot_of if s == seq}
                    pool.evict_seq(seq)
                for c in sorted(cs):
                    key = (seq, layer, c)
                    host = key in self._host_k
                    self._host_k.pop(key, None)
                    self._host_v.pop(key, None)
                    self._dev_k.pop(key, None)
                    self._dev_v.pop(key, None)
                    self._lru.pop(key, None)
                    self.tier[seq, layer, c] = DISK
                    if host:
                        self._record(seq, HOST, DISK, "kv_swapout", 0.0)
                if cs:
                    resident[layer] = sorted(cs)
                    n += len(cs)
            self._swapped[seq] = resident
            self.seq_swapouts += 1
            return n

    @decode_thread_only
    def swap_in_seq(self, seq: int) -> int:
        """Restore a suspended sequence's remembered host working set from
        the disk replicas (CRC-verified coalesced read; ``kv_swapin``
        bills the re-staged bytes — unlike swap-out, these really cross).

        A chunk that fails verification stays disk-tier and is marked
        lost — the next decode fetch routes it through the engine's
        recompute/containment path exactly like any other disk-lost
        chunk; an exhausted retry budget likewise degrades to lazy
        re-reads instead of failing the resume.  Returns the number of
        chunks restored host-side."""
        with self._lock:
            resident = self._swapped.pop(seq, {})
            n = 0
            for layer, cs in resident.items():
                entries = [(seq, seq, c) for c in cs]
                try:
                    blk, lost = self._replica_read_verified(layer, entries)
                except (TransientDiskError, DiskIOExhausted):
                    # stays disk-tier; decode's own fetch re-reads (and
                    # retries/degrades) through its containment path
                    continue
                for i, c in enumerate(cs):
                    if i in lost:
                        continue
                    key = (seq, layer, c)
                    self._host_k[key], self._host_v[key] = \
                        blk[i][0], blk[i][-1]
                    self.tier[seq, layer, c] = HOST
                    self._record(seq, DISK, HOST, "kv_swapin",
                                 float(self.chunk_bytes))
                    n += 1
            self.seq_swapins += 1
            return n

    @any_thread
    def host_bytes(self) -> int:
        """Live host-tier copy bytes (pressure-monitor surface)."""
        with self._lock:
            return len(self._host_k) * self.chunk_bytes

    def append_token(self, layer: int, pos: int, k_new: np.ndarray,
                     v_new: np.ndarray, *, seq: int = 0) -> None:
        """Decode-step cache append: update chunk + abstract in place."""
        self.append_tokens_batch(layer, np.asarray([pos]), k_new[None],
                                 v_new[None], seqs=[seq])

    @decode_thread_only
    def append_tokens_batch(self, layer: int, positions: np.ndarray,
                            k_news: np.ndarray, v_news: np.ndarray, *,
                            seqs: Sequence[int]) -> None:
        """One round's appends for a layer: vectorized disk writes +
        abstract updates, per-seq host/device mirror updates, and ONE pool
        row-scatter for resident tail chunks.

        positions: (B,), k_news/v_news: (B, Hkv, hd), seqs: (B,).  Latent
        layout: ``k_news`` carries the latent rows, ``v_news`` is ignored.
        """
        with self._lock:
            sq = np.asarray(list(seqs), np.int64)
            pos = np.asarray(positions, np.int64)
            cs, offs = pos // self.chunk, pos % self.chunk
            if self._prefix is not None:
                # copy-on-write: the first append into a chunk held by
                # reference privatizes it (all layers at once) before the
                # row lands — later layers' appends find it private
                for i in range(len(sq)):
                    self._cow(int(sq[i]), int(cs[i]))
            kd = k_news.astype(self.dtype)
            vd = kd if self.planes == 1 else v_news.astype(self.dtype)
            self._disk[sq, layer, cs, 0, offs] = kd
            if self.planes == 2:
                self._disk[sq, layer, cs, 1, offs] = vd
            if self._crc_state is not None:
                # append-dirtied: the replica changed under its checksum;
                # serve unverified until the requant sweep re-packs (and
                # re-checksums) the chunk once quiet — a CRC read-back
                # per appended row would double the append write traffic
                self._crc_state[sq, layer, cs] = _CRC_DIRTY
            if self.disk_sidecar:
                # the chunk's per-channel scales no longer cover the new
                # row — reads fall back to the lossless fp16 replica until
                # the requant sweep re-packs the chunk once it goes quiet
                self._sidecar_valid[sq, layer, cs] = False
            if self.pq:
                # same staleness rule for PQ codes (I8): the appended row
                # is not in the codes, so importance falls back to the
                # chunk's min/max box — bitwise the minmax-path score —
                # until the sweep re-encodes the quiet chunk
                self._pq_valid[sq, layer, cs] = False
            if self.disk_sidecar or self.pq:
                for i in range(len(sq)):
                    key = (int(sq[i]), layer, int(cs[i]))
                    self._requant_pending[key] = self._sweep_round
                    self._chunk_version[key] += 1
            self._abs_km[sq, layer, cs] = np.maximum(
                self._abs_km[sq, layer, cs], k_news)
            self._abs_kn[sq, layer, cs] = np.minimum(
                self._abs_kn[sq, layer, cs], k_news)
            row_bytes = self.row_bytes
            pool = self.pools[layer]
            p_slots, p_offs, p_rows = [], [], []
            for i in range(len(sq)):
                seq, c, off = int(sq[i]), int(cs[i]), int(offs[i])
                key = (seq, layer, c)
                if key in self._host_k:
                    self._host_k[key][off] = kd[i]
                    if self.planes == 2:
                        self._host_v[key][off] = vd[i]
                if key in self._dev_k:
                    self._dev_k[key][off] = kd[i]
                    if self.planes == 2:
                        self._dev_v[key][off] = vd[i]
                if pool is not None and (seq, c) in pool.slot_of:
                    # H2D billing happens when the flush actually carries
                    # the row (see _bill_flushed_rows), not at queue time
                    pool.queue_row((seq, c), off,
                                   self._plane_stack(kd[i], vd[i]))
                self._record(seq, HOST, DISK, "kv_append", row_bytes)

    # ------------------------------------------------------------------
    # Sidecar requantization sweep
    # ------------------------------------------------------------------
    @decode_thread_only
    def requant_sweep(self, executor=None) -> int:
        """Advance the sweep clock one decode round and re-pack every
        append-dirtied sidecar whose chunk stayed quiet for at least one
        FULL round since its last append (the live tail chunk keeps
        refreshing its entry every round, so it is never repacked while
        appends still land in it).  With ``executor`` the repack runs
        write-behind on that worker; a concurrent append (or slot reuse)
        bumps the chunk's version and aborts that chunk's repack.  Returns
        the number of chunks submitted for repack."""
        if not (self.disk_sidecar or self.pq):
            return 0
        # prune landed repacks so the in-flight list stays bounded on a
        # long-running server (one append per sweep otherwise), surfacing
        # any worker exception instead of swallowing it — exception-safe:
        # the whole list is pruned even when an early future raised, then
        # the first failure re-raises
        still, first = [], None
        for f in self._requant_futs:
            if f.done():
                try:
                    f.result()
                except BaseException as e:
                    if first is None:
                        first = e
            else:
                still.append(f)
        self._requant_futs = still
        if first is not None:
            raise first
        with self._lock:
            self._sweep_round += 1
            r = self._sweep_round
            ready = [key for key, rr in self._requant_pending.items()
                     if rr < r - 1]
            for key in ready:
                self._requant_pending.pop(key)
            vers = {key: self._chunk_version[key] for key in ready}
        if not ready:
            return 0
        if executor is None:
            self._requant_chunks(ready, vers)
        else:
            self._requant_futs.append(
                executor.submit(self._requant_chunks, ready, vers))
        return len(ready)

    @worker_thread
    def _requant_chunks(self, keys: List[Tuple[int, int, int]],
                        vers: Dict[Tuple[int, int, int], int]) -> None:
        """Re-pack the fp16 replica of each chunk into its int sidecar
        and/or re-encode its PQ codes off the current replica bytes.
        Quantization and the PQ encode (jax) run OUTSIDE the lock on
        private copies; the write re-validates the per-chunk version
        under the lock so a repack can never mark a sidecar (or codes)
        valid over rows it did not see."""
        for seq, layer, c in keys:
            key = (seq, layer, c)
            with self._lock:
                if self._chunk_version[key] != vers[key]:
                    continue            # a newer append re-dirtied it
                planes = [np.array(self._disk[seq, layer, c, pl])
                          for pl in range(self.planes)]
                # the repack READS the fp16 replica off disk before it
                # writes the packed sidecar / fresh codes back — both
                # directions bill (pq-only stores pay the same read)
                self._record(seq, DISK, HOST, "sidecar_repack_read",
                             float(self.chunk_bytes))
            packed = None
            if self.disk_sidecar:
                packed = [compression.quantize_chunks(p[None],
                                                      self.transit_codec)
                          for p in planes]
            pq_codes_c = None
            if self.pq:
                with self._pq_lock:
                    cb = self._pq_cb[layer].copy()
                pq_codes_c = pq_encode(
                    planes[0].reshape(-1, self.head_dim).astype(np.float32),
                    cb, impl=self.pq_impl).reshape(
                        self.chunk, self.kv_heads, self.pq_m)
            # the repack already paid for reading the whole replica — use
            # it to refresh the chunk's checksums for free: the replica
            # CRC leaves append-dirtied (state 2) for valid (state 1),
            # and the sidecar/code CRCs cover the fresh derived bytes
            rep_crc = self._crc32(np.stack(planes)) \
                if self._crc is not None else None
            side_crc = None
            if packed is not None and self._q_crc is not None:
                side_crc = self._sidecar_crc(
                    np.stack([pd.reshape(self.chunk, -1)
                              for pd, _ in packed]),
                    np.stack([psc[0] for _, psc in packed]))
            pq_crc_v = self._crc32(pq_codes_c) \
                if pq_codes_c is not None and self._pq_crc is not None \
                else None
            with self._lock:
                if self._chunk_version[key] != vers[key]:
                    continue            # raced an append mid-repack
                if packed is not None:
                    for pl, (pd, psc) in enumerate(packed):
                        self._disk_q[seq, layer, c, pl] = \
                            pd.reshape(self.chunk, -1)
                        self._disk_scale[seq, layer, c, pl] = psc[0]
                    self._sidecar_valid[seq, layer, c] = True
                    if side_crc is not None:
                        self._q_crc[seq, layer, c] = side_crc
                    self.sidecar_repacks += 1
                    self._record(seq, HOST, DISK, "sidecar_repack",
                                 self._packed_bytes())
                if rep_crc is not None:
                    self._crc[seq, layer, c] = rep_crc
                    self._crc_state[seq, layer, c] = _CRC_VALID
                if pq_codes_c is not None:
                    self._pq_codes[seq, layer, c] = pq_codes_c
                    self._pq_valid[seq, layer, c] = True
                    if pq_crc_v is not None:
                        self._pq_crc[seq, layer, c] = pq_crc_v
                    self.pq_reencodes += 1
                    self._record(seq, HOST, DISK, "pq_codes_write",
                                 float(self.pq_bytes))

    @any_thread
    def requant_fence(self) -> None:
        """Drain in-flight background repacks (shutdown / test ordering).
        Exception-safe: every future is awaited even when one raises —
        nothing is left in flight — and the first failure re-raises."""
        futs, self._requant_futs = self._requant_futs, []
        first: Optional[BaseException] = None
        for f in futs:
            try:
                f.result()
            except BaseException as e:
                if first is None:
                    first = e
        if first is not None:
            raise first

    # ------------------------------------------------------------------
    @decode_thread_only
    def clear_seq(self, seq: int) -> None:
        """Retire a sequence: free its hot-tier entries so the slot can be
        reused by the next admitted request.  The slot's traffic log moves
        to ``retired_logs`` so a reused slot starts a fresh audit; the
        shared ``log`` always equals Σ seq_logs + Σ retired_logs.  Stale
        disk data needs no scrub: the next ingest overwrites every chunk it
        will read, and appended chunks are masked by pos <= length."""
        with self._lock:
            if self._prefix is not None:
                # drop the sequence's shared-chunk references FIRST: a
                # zero-ref arena chunk stays warm-cached (evicted only
                # under registration pressure), so releasing N sharers
                # leaves the arena bytes exactly as a single owner would
                mapping = self._shared_map.pop(seq, None)
                if mapping:
                    self._prefix.decref([(row, c)
                                          for c, row in mapping.items()])
                self._reg_plan.pop(seq, None)
            for d in (self._host_k, self._host_v, self._dev_k, self._dev_v,
                      self._lru):
                for key in [k for k in d if k[0] == seq]:
                    d.pop(key, None)
            for pool in self.pools:
                if pool is not None:
                    pool.evict_seq(seq)
            self._abs_km[seq] = -np.inf
            self._abs_kn[seq] = np.inf
            self.tier[seq] = HOST
            self.access[seq] = 0.0
            self._sidecar_valid[seq] = False
            if self._pq_valid is not None:
                self._pq_valid[seq] = False
            # retire the slot's requant state: pending entries drop and the
            # version bump aborts any in-flight repack of the old data
            for key in [k for k in self._requant_pending if k[0] == seq]:
                self._requant_pending.pop(key)
            for key in [k for k in self._chunk_version if k[0] == seq]:
                self._chunk_version[key] += 1
            if seq in self.seq_logs:
                self.retired_logs.append(self.seq_logs.pop(seq))
            # fault-domain state is per-slot: a reused slot must not
            # inherit the old request's degradation or lost-chunk marks
            self._swapped.pop(seq, None)
            self.degraded_seqs.discard(seq)
            self._disk_lost = {k for k in self._disk_lost if k[0] != seq}
            if self._crc_state is not None:
                self._crc_state[seq] = _CRC_NONE

    # ------------------------------------------------------------------
    # fault-domain recovery surface
    # ------------------------------------------------------------------
    @any_thread
    def restore_chunk(self, layer: int, seq: int, c: int,
                      k_rows: np.ndarray, v_rows: np.ndarray) -> None:
        """Re-land one disk-lost chunk from recomputed prompt KV.

        ``k_rows``/``v_rows`` are the chunk's ``(chunk, Hkv, hd)`` rows
        (possibly short for the tail chunk — zero-padded here exactly
        like ingest so the replica CRC matches a fresh ingest).  Rebuilds
        the fp16 replica, abstracts, and replica CRC; the packed sidecar
        is left quarantined (``_sidecar_valid`` False) — the requant
        sweep repacks it lazily off the restored replica.
        """
        kc = np.asarray(k_rows, dtype=self.dtype)
        vc = np.asarray(v_rows, dtype=self.dtype)
        if kc.shape[0] < self.chunk:
            pad = np.zeros((self.chunk - kc.shape[0],) + kc.shape[1:],
                           dtype=self.dtype)
            kc = np.concatenate([kc, pad], axis=0)
            vc = np.concatenate([vc, pad], axis=0)
        with self._lock:
            p = self._phys(seq, c)
            self._disk[p, layer, c, 0] = kc
            if self.planes > 1:
                self._disk[p, layer, c, 1] = vc
            self._abs_km[p, layer, c] = kc.max(axis=0)
            self._abs_kn[p, layer, c] = kc.min(axis=0)
            self._sidecar_valid[p, layer, c] = False
            if self._pq_valid is not None:
                # restored bytes carry no fresh codes: min/max serves the
                # chunk until the sweep lazily re-encodes it
                self._pq_valid[p, layer, c] = False
                self._requant_pending.setdefault((p, layer, c),
                                                 self._sweep_round)
            # abort any in-flight repack that read the pre-restore bytes:
            # its version check fails and it never re-marks stale CRCs
            if (p, layer, c) in self._chunk_version:
                self._chunk_version[(p, layer, c)] += 1
            if self._crc is not None:
                self._crc[p, layer, c] = self._crc32(
                    self._plane_stack(kc, vc))
                self._crc_state[p, layer, c] = _CRC_VALID
            self._disk_lost.discard((p, layer, c))
            self.fault_counters["chunks_recomputed"] += 1
            self._record(seq, HOST, DISK, "kv_recompute",
                         float(self.chunk_bytes))

    @any_thread
    def disk_lost_keys(self) -> Set[Tuple[int, int, int]]:
        """Snapshot of ``(phys_row, layer, chunk)`` keys marked disk-lost."""
        with self._lock:
            return set(self._disk_lost)

    @any_thread
    def fault_stats(self) -> Dict[str, float]:
        """Fault-domain counters for ``stats()`` / ``engine_audit``."""
        with self._stats_lock:
            out = {k: float(v) for k, v in self.fault_counters.items()}
        with self._lock:
            out["disk_lost"] = float(len(self._disk_lost))
            out["degraded_seqs"] = float(len(self.degraded_seqs))
            out["pq_reencodes"] = float(self.pq_reencodes)
        return out

    def device_bytes(self) -> int:
        resident = len(self._dev_k) + sum(
            len(p.slot_of) for p in self.pools if p is not None)
        return resident * self.chunk_bytes

    def tier_bytes(self) -> Dict[str, float]:
        """Bytes moved so far, by (src, dst) pair — benchmark reporting."""
        out: Dict[str, float] = defaultdict(float)
        for (src, dst, _kind), v in self.log.bytes.items():
            out[f"{src}->{dst}"] += v
        return dict(out)

    def close(self) -> None:
        # the fences still drain every in-flight write before the memmaps
        # go away, but close() itself is best-effort: a fault that already
        # failed a worker must not block shutdown of the survivors
        try:
            self.ingest_fence_all()
        except Exception:
            pass
        try:
            self.requant_fence()
        except Exception:
            pass
        if self.debug_sync:
            _san.disable()
            self.debug_sync = False    # idempotent on double-close
        del self._disk
        if self._disk_q is not None:
            del self._disk_q
            del self._disk_scale
            self._disk_q = self._disk_scale = None
        if self._crc is not None:
            del self._crc
            del self._crc_state
            self._crc = self._crc_state = None
        if self._q_crc is not None:
            del self._q_crc
            self._q_crc = None
        if self._pq_codes is not None:
            del self._pq_codes
            del self._pq_codebook
            self._pq_codes = self._pq_codebook = None
        if self._pq_crc is not None:
            del self._pq_crc
            self._pq_crc = None
