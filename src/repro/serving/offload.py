"""Three-tier KV store: device / host / disk with byte-accurate accounting.

The unit of placement is the (seq, layer, chunk) triple: one store serves a
whole decode batch, so transfers and importance evaluation amortize across
sequences (the paper's batched speedup regime).  The disk tier holds FULL
REPLICAS of every chunk plus its LKA abstract (paper §4.3): demotions are
metadata-only (no write I/O), promotions read either the abstract (2 key
vectors) or the chunk payload, optionally through the INT4 transit codec.

Batched round support:

* one shared disk memmap over all sequences — ``fetch_chunks_batch`` gathers
  every disk-resident (seq, chunk) pair of a layer in ONE fancy-indexed
  read, so promotion I/O for a decode round is one gather per layer;
* a shared DEVICE chunk budget across sequences with LRU demotion (eviction
  is free: the host copy survives and disk always holds the replica);
* per-sequence ``TrafficLog`` mirrors: every byte recorded in the shared
  ``log`` is also attributed to its sequence (retired sequences' logs move
  to ``retired_logs`` so reused slots audit fresh), and benchmarks assert
  shared == Σ seq_logs + Σ retired_logs exactly.

All traffic is tallied per (src, dst, kind) so benchmarks and the simulator
can audit exactly what LeoAM saves.
"""

from __future__ import annotations

import os
import tempfile
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import compression

DEVICE, HOST, DISK = "device", "host", "disk"


@dataclass
class TrafficLog:
    bytes: Dict[Tuple[str, str, str], float] = field(
        default_factory=lambda: defaultdict(float))
    ops: Dict[Tuple[str, str, str], int] = field(
        default_factory=lambda: defaultdict(int))

    def record(self, src: str, dst: str, kind: str, nbytes: float) -> None:
        self.bytes[(src, dst, kind)] += nbytes
        self.ops[(src, dst, kind)] += 1

    def total(self, src: Optional[str] = None, kind: Optional[str] = None
              ) -> float:
        return sum(v for (s, d, k), v in self.bytes.items()
                   if (src is None or s == src) and (kind is None or k == kind))


class TieredKVStore:
    """Multi-sequence chunked K/V with GPU/CPU/disk placement.

    K/V chunks are (chunk, Hkv, hd) numpy arrays keyed by (seq, layer,
    chunk).  ``disk`` is a real memory-mapped file shared by all sequences
    (so promotion latency is a genuine read on whatever machine this runs
    on); the device tier is represented by pinned host arrays handed to jax
    at attention time, capped by ``device_budget`` total chunks across the
    batch with LRU demotion.

    The single-sequence API (``seq`` defaulting to 0) is unchanged from the
    original per-request store, so a ``n_seqs=1`` store behaves exactly as
    before.
    """

    def __init__(self, n_layers: int, n_chunks: int, chunk: int, kv_heads: int,
                 head_dim: int, *, n_seqs: int = 1, dtype=np.float16,
                 transit_codec="int4", root: Optional[str] = None,
                 device_budget: Optional[int] = None):
        self.n_seqs = n_seqs
        self.n_layers, self.n_chunks, self.chunk = n_layers, n_chunks, chunk
        self.kv_heads, self.head_dim = kv_heads, head_dim
        self.dtype = np.dtype(dtype)
        self.transit_codec = transit_codec
        self.device_budget = device_budget
        self.tier: np.ndarray = np.full((n_seqs, n_layers, n_chunks), HOST,
                                        object)
        self.access: np.ndarray = np.zeros((n_seqs, n_layers, n_chunks))
        self.log = TrafficLog()
        self.seq_logs: Dict[int, TrafficLog] = defaultdict(TrafficLog)
        self.retired_logs: List[TrafficLog] = []
        Key = Tuple[int, int, int]
        self._host_k: Dict[Key, np.ndarray] = {}
        self._host_v: Dict[Key, np.ndarray] = {}
        self._dev_k: Dict[Key, np.ndarray] = {}
        self._dev_v: Dict[Key, np.ndarray] = {}
        self._abstracts: Dict[Key, Tuple[np.ndarray, np.ndarray]] = {}
        self._lru: Dict[Key, int] = {}        # device keys -> last-use tick
        self._tick = 0
        shape = (n_seqs, n_layers, n_chunks, 2, chunk, kv_heads, head_dim)
        self._root = root or tempfile.mkdtemp(prefix="leoam_kv_")
        self._disk = np.memmap(os.path.join(self._root, "kv.bin"),
                               dtype=self.dtype, mode="w+", shape=shape)

    # ------------------------------------------------------------------
    @property
    def chunk_bytes(self) -> int:
        return 2 * self.chunk * self.kv_heads * self.head_dim * self.dtype.itemsize

    @property
    def abstract_bytes(self) -> int:
        return 2 * self.kv_heads * self.head_dim * self.dtype.itemsize

    def _record(self, seq: int, src: str, dst: str, kind: str,
                nbytes: float) -> None:
        """Tally into the shared log AND the sequence's mirror, identically
        — the shared log is the exact sum of the per-seq logs by
        construction."""
        self.log.record(src, dst, kind, nbytes)
        self.seq_logs[seq].record(src, dst, kind, nbytes)

    def _transit_bytes(self) -> float:
        nbytes = float(self.chunk_bytes)
        if self.transit_codec:
            nbytes *= compression.codec_ratio(self.transit_codec)
        return nbytes

    def ingest(self, layer: int, k: np.ndarray, v: np.ndarray,
               placement: Dict[int, str], *, seq: int = 0) -> None:
        """Store prefill KV.  k/v: (S, Hkv, hd).  Every chunk is replicated
        to disk (with its abstract); ``placement`` assigns the hot tier."""
        S = k.shape[0]
        for c in range(min(self.n_chunks, (S + self.chunk - 1) // self.chunk)):
            kc = k[c * self.chunk: (c + 1) * self.chunk].astype(self.dtype)
            vc = v[c * self.chunk: (c + 1) * self.chunk].astype(self.dtype)
            if kc.shape[0] < self.chunk:
                pad = self.chunk - kc.shape[0]
                kc = np.pad(kc, ((0, pad), (0, 0), (0, 0)))
                vc = np.pad(vc, ((0, pad), (0, 0), (0, 0)))
            self._disk[seq, layer, c, 0] = kc
            self._disk[seq, layer, c, 1] = vc
            self._abstracts[(seq, layer, c)] = (kc.max(0), kc.min(0))
            self._record(seq, HOST, DISK, "kv_replica", self.chunk_bytes)
            self._record(seq, HOST, DISK, "abstract", self.abstract_bytes)
            where = placement.get(c, HOST)
            self.tier[seq, layer, c] = where
            key = (seq, layer, c)
            if where in (HOST, DEVICE):
                self._host_k[key], self._host_v[key] = kc, vc
            if where == DEVICE:
                self._promote_device(key, kc, vc)

    # ------------------------------------------------------------------
    def read_abstracts(self, layer: int, chunks: Sequence[int], *,
                       seq: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """LKA: fetch (kmax, kmin) for chunks; disk chunks cost abstract I/O."""
        kmaxs, kmins = [], []
        for c in chunks:
            if self.tier[seq, layer, c] == DISK:
                self._record(seq, DISK, HOST, "abstract", self.abstract_bytes)
            km, kn = self._abstracts[(seq, layer, c)]
            kmaxs.append(km)
            kmins.append(kn)
        return np.stack(kmaxs), np.stack(kmins)

    def read_abstracts_batch(self, layer: int,
                             chunks_by_seq: Dict[int, Sequence[int]]
                             ) -> Tuple[np.ndarray, np.ndarray, Dict[int, float]]:
        """Batched LKA read: one padded (B, ncmax, Hkv, hd) stack for the
        round's importance evaluation.  Returns (kmax, kmin, abstract bytes
        billed per sequence); rows follow dict order, padded with zeros."""
        B = len(chunks_by_seq)
        ncmax = max((len(c) for c in chunks_by_seq.values()), default=0)
        km = np.zeros((B, ncmax, self.kv_heads, self.head_dim), np.float32)
        kn = np.zeros_like(km)
        billed: Dict[int, float] = {}
        for i, (seq, chunks) in enumerate(chunks_by_seq.items()):
            before = self.seq_logs[seq].total(kind="abstract")
            a, b = self.read_abstracts(layer, chunks, seq=seq)
            km[i, :len(chunks)] = a
            kn[i, :len(chunks)] = b
            billed[seq] = self.seq_logs[seq].total(kind="abstract") - before
        return km, kn, billed

    # ------------------------------------------------------------------
    def _promote_device(self, key: Tuple[int, int, int], kc: np.ndarray,
                        vc: np.ndarray) -> None:
        """Pin a chunk device-side, demoting LRU chunks past the shared
        budget (free: host copies + disk replicas survive)."""
        self._dev_k[key], self._dev_v[key] = kc, vc
        self.tier[key[0], key[1], key[2]] = DEVICE
        self._tick += 1
        self._lru[key] = self._tick
        if self.device_budget is not None:
            while len(self._dev_k) > self.device_budget:
                victim = min(self._lru, key=self._lru.get)
                self._dev_k.pop(victim, None)
                self._dev_v.pop(victim, None)
                self._lru.pop(victim, None)
                self.tier[victim[0], victim[1], victim[2]] = HOST

    def fetch_chunks(self, layer: int, chunks: Sequence[int], *,
                     seq: int = 0, to_device: bool = True
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Promote chunks to the device working set; returns stacked K/V
        (n, chunk, Hkv, hd).  Disk promotions go through the transit codec."""
        ks, vs = [], []
        for c in chunks:
            key = (seq, layer, c)
            self.access[seq, layer, c] += 1
            if key in self._dev_k:
                self._tick += 1
                self._lru[key] = self._tick
                ks.append(self._dev_k[key])
                vs.append(self._dev_v[key])
                continue
            if self.tier[seq, layer, c] == DISK or key not in self._host_k:
                kc = np.asarray(self._disk[seq, layer, c, 0])
                vc = np.asarray(self._disk[seq, layer, c, 1])
                self._record(seq, DISK, HOST, "kv", self._transit_bytes())
                self._host_k[key], self._host_v[key] = kc, vc
            kc, vc = self._host_k[key], self._host_v[key]
            self._record(seq, HOST, DEVICE, "kv", self._transit_bytes())
            if to_device:
                self._promote_device(key, kc, vc)
            ks.append(kc)
            vs.append(vc)
        return np.stack(ks), np.stack(vs)

    def fetch_chunks_batch(self, layer: int,
                           chunks_by_seq: Dict[int, Sequence[int]], *,
                           pad_to: Optional[int] = None, to_device: bool = True
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batch-coalesced promotion for one decode round of one layer.

        All disk-resident (seq, chunk) pairs across the batch are read from
        the shared memmap in ONE fancy-indexed gather, then every sequence's
        ragged selection is padded to ``pad_to`` (default: the round's max).

        Returns (kg, vg, nsel): kg/vg (B, pad_to, chunk, Hkv, hd) in store
        dtype with zero padding, nsel (B,) the per-row valid chunk counts.
        Rows follow dict order.  Accounting matches per-seq ``fetch_chunks``
        byte-for-byte; only the I/O issue pattern differs.
        """
        items = list(chunks_by_seq.items())
        B = len(items)
        nsel = np.array([len(c) for _, c in items], np.int32)
        nmax = int(pad_to if pad_to is not None else (nsel.max() if B else 0))

        # one gather per layer for everything that must come off disk
        need_disk = [(seq, c) for seq, chunks in items for c in chunks
                     if (seq, layer, c) not in self._dev_k
                     and ((seq, layer, c) not in self._host_k
                          or self.tier[seq, layer, c] == DISK)]
        if need_disk:
            sq = np.array([s for s, _ in need_disk])
            cq = np.array([c for _, c in need_disk])
            blk = np.asarray(self._disk[sq, layer, cq])   # (n, 2, chunk, ...)
            for (seq, c), kv in zip(need_disk, blk):
                key = (seq, layer, c)
                self._record(seq, DISK, HOST, "kv", self._transit_bytes())
                self._host_k[key], self._host_v[key] = kv[0], kv[1]

        kg = np.zeros((B, nmax, self.chunk, self.kv_heads, self.head_dim),
                      self.dtype)
        vg = np.zeros_like(kg)
        for i, (seq, chunks) in enumerate(items):
            for j, c in enumerate(chunks):
                key = (seq, layer, c)
                self.access[seq, layer, c] += 1
                if key in self._dev_k:
                    self._tick += 1
                    self._lru[key] = self._tick
                    kg[i, j], vg[i, j] = self._dev_k[key], self._dev_v[key]
                    continue
                self._record(seq, HOST, DEVICE, "kv", self._transit_bytes())
                if to_device:
                    self._promote_device(key, self._host_k[key],
                                         self._host_v[key])
                kg[i, j], vg[i, j] = self._host_k[key], self._host_v[key]
        return kg, vg, nsel

    def demote(self, layer: int, chunks: Sequence[int], to: str = HOST, *,
               seq: int = 0) -> None:
        """Eviction is free toward disk (replicas, §4.3)."""
        for c in chunks:
            key = (seq, layer, c)
            self._dev_k.pop(key, None)
            self._dev_v.pop(key, None)
            self._lru.pop(key, None)
            if to == DISK:
                self._host_k.pop(key, None)
                self._host_v.pop(key, None)
            self.tier[seq, layer, c] = to

    def append_token(self, layer: int, pos: int, k_new: np.ndarray,
                     v_new: np.ndarray, *, seq: int = 0) -> None:
        """Decode-step cache append: update chunk + abstract in place."""
        c, off = pos // self.chunk, pos % self.chunk
        self._disk[seq, layer, c, 0, off] = k_new.astype(self.dtype)
        self._disk[seq, layer, c, 1, off] = v_new.astype(self.dtype)
        km, kn = self._abstracts.get((seq, layer, c),
                                     (np.full((self.kv_heads, self.head_dim),
                                              -np.inf, self.dtype),
                                      np.full((self.kv_heads, self.head_dim),
                                              np.inf, self.dtype)))
        self._abstracts[(seq, layer, c)] = (np.maximum(km, k_new),
                                            np.minimum(kn, k_new))
        key = (seq, layer, c)
        if key in self._host_k:
            self._host_k[key][off] = k_new
            self._host_v[key][off] = v_new
        if key in self._dev_k:
            self._dev_k[key][off] = k_new
            self._dev_v[key][off] = v_new
        self._record(seq, HOST, DISK, "kv_append",
                     2 * self.kv_heads * self.head_dim * self.dtype.itemsize)

    # ------------------------------------------------------------------
    def clear_seq(self, seq: int) -> None:
        """Retire a sequence: free its hot-tier entries so the slot can be
        reused by the next admitted request.  The slot's traffic log moves
        to ``retired_logs`` so a reused slot starts a fresh audit; the
        shared ``log`` always equals Σ seq_logs + Σ retired_logs.  Stale
        disk data needs no scrub: the next ingest overwrites every chunk it
        will read, and appended chunks are masked by pos <= length."""
        for d in (self._host_k, self._host_v, self._dev_k, self._dev_v,
                  self._abstracts, self._lru):
            for key in [k for k in d if k[0] == seq]:
                d.pop(key, None)
        self.tier[seq] = HOST
        self.access[seq] = 0.0
        if seq in self.seq_logs:
            self.retired_logs.append(self.seq_logs.pop(seq))

    def device_bytes(self) -> int:
        return len(self._dev_k) * self.chunk_bytes

    def tier_bytes(self) -> Dict[str, float]:
        """Bytes moved so far, by (src, dst) pair — benchmark reporting."""
        out: Dict[str, float] = defaultdict(float)
        for (src, dst, _kind), v in self.log.bytes.items():
            out[f"{src}->{dst}"] += v
        return dict(out)

    def close(self) -> None:
        del self._disk
