"""Runtime sync-sanitizer + thread-ownership markers for the tiered engine.

The serving stack's concurrency contracts (docstrings in ``engine.py`` /
``offload.py``, catalogued in ``docs/INVARIANTS.md``) are enforced twice:

* statically by ``python -m repro.analysis`` (the ``leolint`` passes read
  the ownership decorators below straight off the AST and walk the call
  graph from every executor entry point);
* dynamically by this module when ``EngineCfg(debug_sync=True)`` — the
  decorators become live owning-thread assertions, store/pool mutating
  entry points get a concurrent-mutation (epoch) guard, and the store's
  locks are wrapped in :class:`TrackedLock`, which records the lock
  acquisition graph per thread and fails on the first cycle instead of
  leaving a latent ABBA deadlock for production traffic to find.

Ownership classes (strict to permissive):

* ``@decode_thread_only`` — must never execute on a worker thread (the
  DTP prefetch / admission / requant executors, thread names
  ``leoam-*``).  These functions mutate state the decode thread reads
  WITHOUT the store lock (the device pool slab, the engine's slot
  free-list), so a worker calling one is a data race even if it happens
  to win today.
* ``@worker_thread`` — runs on executor workers (and inline on the decode
  thread in the serial modes).  May call ``@worker_thread`` /
  ``@any_thread`` code; a reachable call into ``@decode_thread_only``
  code is rejected by the static pass and (via the thread-name check) at
  runtime.
* ``@any_thread`` — safe from every thread; every touched structure is
  lock-protected.

All checks compile to a single integer compare when the sanitizer is
disabled (the default), so decorated hot-path functions cost one ``if``
per call.  ``benchmarks/run.py`` refuses to produce measured numbers with
the sanitizer live; its overhead is recorded by the fig13 bench instead.
"""

from __future__ import annotations

import os
import threading
from functools import wraps
from typing import Dict, List, Optional, Set, Tuple

DECODE_THREAD_ONLY = "decode_thread_only"
WORKER_THREAD = "worker_thread"
ANY_THREAD = "any_thread"

#: thread-name prefix shared by every serving executor (DTP prefetch,
#: admission, write-behind ingest, requant) — the runtime worker test.
WORKER_PREFIX = "leoam-"

OWNERSHIP_ATTR = "__leolint_ownership__"


class SyncViolation(AssertionError):
    """A concurrency contract was broken under ``debug_sync=True``."""


# ----------------------------------------------------------------------
# Activation (refcounted: every debug_sync store/engine enables on build
# and disables on close, so overlapping debug engines compose)
# ----------------------------------------------------------------------
_enabled = 0
_state_lock = threading.Lock()


def enable() -> None:
    global _enabled
    with _state_lock:
        _enabled += 1


def disable() -> None:
    global _enabled
    with _state_lock:
        _enabled = max(0, _enabled - 1)


def active() -> bool:
    """True while at least one ``debug_sync`` store/engine is live (or the
    ``REPRO_DEBUG_SYNC`` escape hatch is set)."""
    return _enabled > 0 or bool(int(os.environ.get("REPRO_DEBUG_SYNC", "0")))


class _TLS(threading.local):
    def __init__(self):
        self.held: List[str] = []        # TrackedLock names, outermost first
        self.registered_worker = False


_tls = _TLS()


def register_worker_thread() -> None:
    """Mark the CURRENT thread as a worker for the sanitizer — for test
    doubles / external executors whose threads are not named ``leoam-*``."""
    _tls.registered_worker = True


def _is_worker_thread() -> bool:
    return (_tls.registered_worker
            or threading.current_thread().name.startswith(WORKER_PREFIX))


# ----------------------------------------------------------------------
# Concurrent-mutation (epoch) guard
# ----------------------------------------------------------------------
# per-object mutation bookkeeping: id(obj) -> [owner thread ident, depth,
# epoch].  The decode-thread-only mutators are NOT lock-protected (that is
# the point of the ownership contract), so two threads interleaving inside
# one is a real race — the guard turns the interleaving into a hard error
# with both thread names in the message instead of silent corruption.
_mut: Dict[int, List] = {}
_mut_lock = threading.Lock()


def _mutation_enter(obj, fname: str) -> None:
    me = threading.get_ident()
    name = threading.current_thread().name
    with _mut_lock:
        ent = _mut.get(id(obj))
        if ent is None:
            _mut[id(obj)] = [me, 1, 0, name]
        elif ent[0] == me:
            ent[1] += 1
        else:
            raise SyncViolation(
                f"concurrent mutation: {type(obj).__name__}.{fname} entered "
                f"on thread '{name}' while thread '{ent[3]}' is still inside "
                f"a decode-thread-only mutator of the same object (epoch "
                f"{ent[2]}) — the decode thread must stay the sole mutator")


def _mutation_exit(obj) -> None:
    with _mut_lock:
        ent = _mut.get(id(obj))
        if ent is None:
            return
        ent[1] -= 1
        if ent[1] <= 0:
            ent[2] += 1
            if ent[2] > 1 << 30:       # bounded bookkeeping on long runs
                ent[2] = 0
            ent[0] = None
            del _mut[id(obj)]


# ----------------------------------------------------------------------
# Ownership decorators
# ----------------------------------------------------------------------
def _mark(fn, ownership: str):
    setattr(fn, OWNERSHIP_ATTR, ownership)
    return fn


def decode_thread_only(fn):
    """The function mutates (or publishes) state the decode thread reads
    without the store lock; only the decode thread may run it.  Under
    ``debug_sync`` a call from a worker thread raises
    :class:`SyncViolation`, and concurrent entry from two threads trips
    the epoch guard even when neither is a named worker."""

    @wraps(fn)
    def wrapper(*args, **kwargs):
        if _enabled:
            if _is_worker_thread():
                raise SyncViolation(
                    f"{fn.__qualname__} is decode-thread-only but ran on "
                    f"worker thread "
                    f"'{threading.current_thread().name}' — route this "
                    f"mutation through the decode thread (pending_place / "
                    f"deferred-fold pattern)")
            if args and not isinstance(args[0], (int, float, str, bytes)):
                _mutation_enter(args[0], fn.__name__)
                try:
                    return fn(*args, **kwargs)
                finally:
                    _mutation_exit(args[0])
        return fn(*args, **kwargs)

    return _mark(wrapper, DECODE_THREAD_ONLY)


def worker_thread(fn):
    """The function is an executor work item (or runs inline in the serial
    modes).  Marker for the static pass; runtime cost is one compare."""

    @wraps(fn)
    def wrapper(*args, **kwargs):
        return fn(*args, **kwargs)

    return _mark(wrapper, WORKER_THREAD)


def any_thread(fn):
    """Explicitly safe from every thread (all touched state is
    lock-protected).  Marker for the static pass."""

    @wraps(fn)
    def wrapper(*args, **kwargs):
        return fn(*args, **kwargs)

    return _mark(wrapper, ANY_THREAD)


# ----------------------------------------------------------------------
# Lock-order tracker
# ----------------------------------------------------------------------
class LockOrderTracker:
    """Directed lock-acquisition graph shared by every :class:`TrackedLock`.

    Each first acquisition of lock B while holding lock A records the edge
    A→B; an acquisition that would close a cycle (a path B→…→A already
    exists) raises immediately — the two call sites jointly form an ABBA
    deadlock waiting for the right schedule."""

    def __init__(self):
        self._edges: Dict[str, Set[str]] = {}
        self._lock = threading.Lock()

    def _path(self, src: str, dst: str) -> bool:
        seen, stack = set(), [src]
        while stack:
            n = stack.pop()
            if n == dst:
                return True
            if n in seen:
                continue
            seen.add(n)
            stack.extend(self._edges.get(n, ()))
        return False

    def on_acquire(self, name: str, held: List[str]) -> None:
        with self._lock:
            for h in held:
                if h == name:
                    continue
                if name not in self._edges.setdefault(h, set()):
                    if self._path(name, h):
                        raise SyncViolation(
                            f"lock-order cycle: acquiring '{name}' while "
                            f"holding '{h}', but the reverse order "
                            f"'{name}'->…->'{h}' was already recorded — "
                            f"these call sites can deadlock")
                    self._edges[h].add(name)

    def edges(self) -> Dict[str, Set[str]]:
        with self._lock:
            return {k: set(v) for k, v in self._edges.items()}


_TRACKER = LockOrderTracker()


class TrackedLock:
    """Context-manager wrapper over a ``threading`` lock that feeds the
    process-wide :class:`LockOrderTracker` and the per-thread held-lock
    stack.  API-compatible with the wrapped lock for ``with`` use."""

    def __init__(self, lock, name: str, tracker: LockOrderTracker = None):
        self._lock = lock
        self.name = name
        self._tracker = tracker or _TRACKER

    def acquire(self, *a, **kw):
        # record BEFORE blocking: a would-deadlock acquisition must raise
        # rather than hang the sanitized run
        self._tracker.on_acquire(self.name, _tls.held)
        ok = self._lock.acquire(*a, **kw)
        if ok:
            _tls.held.append(self.name)
        return ok

    def release(self):
        self._lock.release()
        for i in range(len(_tls.held) - 1, -1, -1):
            if _tls.held[i] == self.name:
                del _tls.held[i]
                break

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


def held_locks() -> Tuple[str, ...]:
    """The current thread's tracked-lock stack (diagnostics / tests)."""
    return tuple(_tls.held)


def lock_order_edges() -> Dict[str, Set[str]]:
    """The recorded acquisition graph (diagnostics / tests)."""
    return _TRACKER.edges()
