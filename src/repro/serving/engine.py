"""LeoAM serving engine: real batched tiered decoding on a live model.

The engine exercises every paper mechanism with genuine data movement:
prefill populates the three-tier store (full replicas + abstracts on disk),
each decode round evaluates chunk importance on the host from abstracts
(IAKM tree or flat selection), fetches ONLY the selected chunks through the
transit codec, attends over the assembled working set on device, and appends
the new token's KV + abstract update.  An access-frequency table pins hot
chunks above the disk tier.  Traffic is audited by the TieredKVStore log —
benchmarks assert the LKA ratio r = α + 2/n' on it.

The decode round is the paper's Dynamic Three-tier Pipeline (§4.4), three
stages per attention layer:

1. **Evaluate** (CPU): one ``chunk_bounds_gqa_matmul`` over the stacked
   per-request queries and the layer's (padded) abstract stack, then
   chunk-level adaptive selection (IAKM tree or flat) per sequence —
   importance evaluation amortizes across the batch.
2. **Transfer** (disk→host→device): one batch-coalesced disk gather stages
   cold chunks host-side; the device-resident chunk pool
   (:class:`~repro.serving.offload.DeviceChunkPool`) then uploads ONLY the
   newly-promoted delta — pool-resident chunks cost zero bytes.  With
   ``real_codec`` the θ-fraction of the delta crosses the link as packed
   int4/int8 payloads and is dequantized on device
   (``kernels.kv_quant``); θ is chosen per layer each round by the paper's
   balance ``optimal_theta`` from measured compute/transfer costs.
3. **Attend** (GPU): one jitted dispatch gathers the working set from the
   pool by slot index and runs padded+masked attention — ragged
   per-sequence selections are padded to the round's (bucketed) max, which
   is FP-exact: padded keys score -inf, contribute exp(-inf)=0, and adding
   zeros never perturbs the f32 accumulators.

With ``pipeline=True`` a one-worker prefetch executor overlaps stage 2 of
layer l+1 under stage 3 of layer l: while layer l's attention runs, the
worker reads layer l+1's abstracts and speculatively stages its predicted
selection (previous round's selection, else the AccessTable hot set)
disk→host.  Predictions only move residency, never values — a miss falls
back to the synchronous path, so pipelined output is bit-identical to
``pipeline=False``.

The ADMISSION path is pipelined too (PR 3): ``add_sequence`` streams each
attention layer's K/V into the tier store as it is forced off the device,
with the disk replica + abstract writes running write-behind on the shared
prefetch executor under the remaining layers' prefill compute
(``overlap_ingest``; a per-sequence completion fence at decode-round entry
and release keeps every read ordered after the writes).
``add_sequence_async`` runs the whole prefill+ingest on a one-worker
admission executor so new requests admit UNDER the active batch's decode
rounds — only the store's lock-protected critical sections serialize, and
the new sequence defers device-pool placement so the decode thread's
attention gathers never race a pool scatter.  Both are token-identical to
the serial path (tested): write-behind moves bytes, never values.

Admission is BUCKETED and CHUNKABLE (PR 4): ``_prefill`` pads the prompt
to a power-of-two length bucket and threads the true length through the
jitted program (logits row, cache zeroing, recurrent-state masking), so
O(log max_len) compiled programs serve any public-traffic length mix —
token-identical to exact-length prefill (property-tested at bucket
edges).  ``begin_admission`` returns a resumable :class:`ChunkedAdmission`
that forces one fixed-size prefill chunk per ``step()`` (ONE compiled
program for every chunk of every prompt — offset-causal attention over
the zero-initialised decode cache) and streams each chunk into the store
through chunk-aligned partial ingest, so the scheduler can run decode
rounds between a long prompt's chunks instead of stalling behind its
whole prefill.

DeepSeek-class absorbed-MLA models ride the SAME pipeline (PR 5): the
tier store keeps one latent plane per token (concat(c_kv, k_rope), a
single logical kv head of width kv_lora_rank + qk_rope_head_dim) instead
of a K/V pair, importance evaluation reuses the positive/negative-split
bounds matmul against latent min/max boxes (q_lat·ckv + q_rope·krope is
exactly the concatenated dot product), the pooled/legacy dispatches
gather latent rows and apply the absorbed W_UV once after the softmax,
and both whole-prompt AND chunked admission stream latent rows through
``ingest`` — so ``ContinuousBatcher(chunked_admission=True)`` serves MLA
traffic with the same O(log L) compiled-program and bounded-stall
guarantees as GQA (property-tested token-identical).

``pooled=False, pipeline=False`` reproduces the PR-1 synchronous engine
(full working-set re-upload per layer) for A/B tests and benchmarks;
``overlap_ingest=False`` reproduces the PR-2 serial admission path;
``bucket_prefill=False`` reproduces the PR-3 compile-per-length prefill.

``LeoAMEngine`` is the single-sequence view: a thin wrapper over a B=1
batched engine preserving the original prefill/decode_step/generate API.
"""

from __future__ import annotations

import functools
import math
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import compression
from repro.core import pipeline as dtp
from repro.core.adaptive import flat_select_chunks, tree_select_chunks
from repro.core.bounds import chunk_bounds_gqa_matmul
from repro.core.tiers import AccessTable
from repro.kernels.pq import adc_chunk_scores
from repro.models import lm
from repro.models import attention as attn_mod
from repro.serving.faults import AdmissionError, ChunkLostError
from repro.serving.offload import DEVICE, DISK, HOST, TieredKVStore
from repro.serving.sanitizer import decode_thread_only, worker_thread


@dataclass
class EngineCfg:
    max_len: int = 1024
    gpu_chunk_frac: float = 0.15     # device-resident fraction
    cpu_chunk_frac: float = 0.45     # host tier fraction (rest -> disk)
    selection: str = "tree"          # tree | flat
    hot_frac: float = 0.05
    transit_codec: Optional[str] = "int4"
    sel_pad: int = 4                 # pad round working sets to a multiple
                                     # of this many chunks (bounds jit
                                     # recompiles; masking keeps it exact)
    pooled: bool = True              # device-resident chunk pool (delta
                                     # uploads); False = PR-1 full re-upload
    pipeline: bool = True            # async DTP overlap (prefetch thread)
    real_codec: bool = False         # carry actual packed int4/int8 transit
                                     # payloads (vs ledger-only scaling)
    overlap_ingest: bool = True      # write-behind prefill ingest: replica/
                                     # abstract writes ride the shared
                                     # prefetch executor under the next
                                     # layer's prefill compute (fenced);
                                     # False = PR-2 serial ingest
    jit_prefill: bool = True         # compile lm.prefill per prompt length
                                     # (one XLA call per admission instead
                                     # of thousands of GIL-bound op
                                     # dispatches — admission under decode
                                     # then truly overlaps, and TTFT drops
                                     # even standalone)
    bucket_prefill: bool = True      # pad prompts to power-of-two (or
                                     # prefill_buckets) lengths with a
                                     # validity mask: O(log max_len)
                                     # compiled programs serve EVERY prompt
                                     # length, token-identical to
                                     # exact-length prefill (tested);
                                     # False = PR-3 one program per length
    prefill_buckets: Optional[Tuple[int, ...]] = None
                                     # explicit ascending bucket schedule
                                     # (None = powers of two from 16)
    prefill_chunk_tokens: int = 64   # chunk size for begin_admission's
                                     # resumable chunked prefill; must
                                     # divide max_len and be a multiple of
                                     # the store chunk
    sidecar_requant: bool = True     # background sweep re-packs append-
                                     # dirtied disk sidecars once a chunk
                                     # goes a full round without appends
                                     # (no-op unless disk_sidecar)
    disk_sidecar: bool = False       # packed int4/int8 disk replicas: tier
                                     # writes + disk->host promotions move
                                     # packed bytes (fp16 stays as the
                                     # lossless fallback)
    sidecar_lossless: bool = False   # flag the fallback on: promotions
                                     # read the fp16 replica (full bytes)
                                     # even when the sidecar is valid
    pq_abstracts: bool = False       # PQ abstract plane: per-layer online
                                     # k-means codebooks over ingested key
                                     # chunks; importance evaluation scores
                                     # code-valid chunks via the ADC lookup
                                     # table (codes are a fraction of the
                                     # min/max box bytes), falling back
                                     # BITWISE to the bounds matmul for
                                     # append-dirtied/corrupt chunks; off
                                     # = the exact min/max path, untouched
    pq_m: Optional[int] = None       # key subvectors per head dim (None =
                                     # head_dim // 8)
    pq_centroids: int = 256          # codebook entries per subspace
                                     # (uint8 codes: <= 256; the codebook
                                     # is shared per-layer state, so more
                                     # centroids sharpen ADC at zero
                                     # per-chunk byte cost)
    pq_train_iters: int = 4          # Lloyd iterations on the first
                                     # (codebook-initializing) ingest
    prefix_cache: bool = False       # content-addressable cross-request
                                     # shared-prefix reuse: warm prompts
                                     # adopt matching chunk-aligned spans
                                     # by reference (zero prefill FLOPs,
                                     # zero duplicate tier bytes) and
                                     # resume chunked prefill at the cold
                                     # suffix; opt-in — admission routes
                                     # through the chunked-prefill path
    prefix_arena_rows: int = 8       # shared-chunk arena rows appended to
                                     # the store's per-seq arrays; bounds
                                     # how many distinct prefix sets stay
                                     # resident (LRU beyond that)
    profile: bool = False            # block per stage, fill round_profiles
    debug_sync: bool = False         # runtime sync-sanitizer: ownership
                                     # decorators assert the owning
                                     # thread, store/pool mutators get a
                                     # concurrent-entry epoch guard, and
                                     # the store locks feed a lock-order
                                     # tracker that fails on cycles.  For
                                     # debugging/stress only — never for
                                     # measured runs (benchmarks/run.py
                                     # refuses)
    checksums: bool = True           # per-chunk CRC32 on disk replicas +
                                     # packed sidecars, verified at every
                                     # promotion: a corrupt sidecar falls
                                     # back to the fp16 replica, a corrupt
                                     # replica triggers recompute-from-
                                     # prompt (or seq-level failure)
    fault_plan: Optional[Any] = None  # serving.faults.FaultPlan threaded
                                     # through the store's I/O choke
                                     # points (chaos tests only)
    io_retries: int = 3              # bounded retry budget on transient
    io_backoff_s: float = 1e-4       # disk errors, exponential backoff
    # measured-cost θ balance (paper §4.4); defaults mirror TierBW
    pcie_bw: float = 16e9
    disk_bw: float = 3.5e9
    kappa: float = 1.0 / 80e9


# one process-wide DTP prefetch worker, shared by every pipelined engine:
# per-engine executors would leak a thread per engine (benchmark sweeps
# build dozens), and a single queue preserves per-engine FIFO ordering.
# Write-behind ingest rides the SAME worker: its FIFO order guarantees a
# layer's replica/abstract writes land before any prefetch submitted later,
# and the per-seq ingest fence covers everything else.
_PF_EXECUTOR: Optional[ThreadPoolExecutor] = None

# a separate one-worker admission executor runs whole add_sequence calls
# (prefill + ingest) under the active batch's decode rounds — on the DTP
# worker a long prefill would stall every decode round's prefetch
_ADMIT_EXECUTOR: Optional[ThreadPoolExecutor] = None


def _prefetch_executor() -> ThreadPoolExecutor:
    global _PF_EXECUTOR
    if _PF_EXECUTOR is None:
        _PF_EXECUTOR = ThreadPoolExecutor(max_workers=1,
                                          thread_name_prefix="leoam-dtp")
    return _PF_EXECUTOR


def _admit_executor() -> ThreadPoolExecutor:
    global _ADMIT_EXECUTOR
    if _ADMIT_EXECUTOR is None:
        _ADMIT_EXECUTOR = ThreadPoolExecutor(max_workers=1,
                                             thread_name_prefix="leoam-admit")
    return _ADMIT_EXECUTOR


@dataclass
class StepStats:
    evaluations: int = 0
    fetched_chunks: int = 0
    fetched_bytes: float = 0.0
    abstract_bytes: float = 0.0


@dataclass
class _SeqState:
    """Host-side per-sequence decode state (model cache + bookkeeping)."""
    cache: Any                       # non-attention state + dense caches
    length: int
    access: AccessTable
    stats: List[StepStats] = field(default_factory=list)
    tokens: Optional[np.ndarray] = None  # prompt tokens (recompute source
                                     # for disk-lost prompt-span chunks)
    prompt_len: int = 0              # tokens covered by the prompt — only
                                     # chunks entirely within this span
                                     # are recomputable (decode appends
                                     # exist nowhere but the lost replica)


def _attend_core(q, kg, vg, k_new, v_new, valid, wo, attn_softcap):
    """Padded-working-set attention shared by the pooled and legacy paths.

    q: (B, 1, H, hd) model dtype; kg/vg: (B, nmax, chunk, Hkv, hd) store
    dtype; k_new/v_new: (B, 1, Hkv, hd); valid: (B, 1, 1, S) bool with
    S = nmax*chunk + 1; wo: (H*hd, d).  Padded / beyond-length positions are
    masked to -inf before the softmax partials, so ragged per-sequence
    selections cost nothing numerically.
    """
    from repro.core import sparse_attention as sa
    B, _, H, hd = q.shape
    _, n, c, Hkv, _ = kg.shape
    G = H // Hkv
    kg = kg.reshape(B, n * c, Hkv, hd)
    vg = vg.reshape(B, n * c, Hkv, hd)
    kg = jnp.concatenate([kg.astype(q.dtype), k_new.astype(q.dtype)], axis=1)
    vg = jnp.concatenate([vg.astype(q.dtype), v_new.astype(q.dtype)], axis=1)
    qs = q[:, 0] * (1.0 / math.sqrt(hd))
    kt = jnp.swapaxes(kg, 1, 2)
    vt = jnp.swapaxes(vg, 1, 2)
    scores = jnp.einsum("bkgd,bksd->bkgs",
                        qs.reshape(B, Hkv, G, hd).astype(jnp.float32),
                        kt.astype(jnp.float32))
    if attn_softcap is not None:
        scores = attn_softcap * jnp.tanh(scores / attn_softcap)
    part = sa._masked_softmax_partials(scores, vt, valid)
    out = sa._finish(part).astype(q.dtype).reshape(B, 1, H * hd)
    return out @ wo


@functools.partial(jax.jit, static_argnames=("attn_softcap",))
def _attend_workingset(q, kg, vg, k_new, v_new, valid, wo, *,
                       attn_softcap: Optional[float]):
    """Legacy dispatch: host-assembled working set uploaded whole (PR-1)."""
    return _attend_core(q, kg, vg, k_new, v_new, valid, wo, attn_softcap)


@functools.partial(jax.jit, static_argnames=("attn_softcap",))
def _attend_pooled(q, pool_kv, slots, chunk_ids, lengths, k_new, v_new,
                   wo, *, attn_softcap: Optional[float]):
    """Pooled dispatch: gather the working set from the device slab by slot
    index — the only host→device traffic this op needs is the (B, nmax)
    ``slots``/``chunk_ids`` index arrays (the validity mask is derived on
    device, not uploaded).

    pool_kv: (n_slots + 1, 2, chunk, Hkv, hd); slots: (B, nmax) int32
    (padding rows point at slot 0); chunk_ids: (B, nmax) int32 with -1 on
    padding; lengths: (B,) int32."""
    kv = pool_kv[slots]                  # (B, nmax, 2, chunk, Hkv, hd)
    B, nmax = slots.shape
    chunk = pool_kv.shape[2]
    pos = (chunk_ids[..., None] * chunk
           + jnp.arange(chunk, dtype=jnp.int32)).reshape(B, nmax * chunk)
    # the store holds tokens 0..length-1 at attend time (this round's token
    # arrives via k_new/v_new, its append lands after the dispatch), so the
    # grid mask is STRICT — `pos == length` is an unwritten/stale row
    ok = (chunk_ids[..., None] >= 0).repeat(chunk, -1).reshape(B, -1) \
        & (pos < lengths[:, None])
    valid = jnp.concatenate(
        [ok, jnp.ones((B, 1), bool)], axis=1)[:, None, None]  # + new token
    return _attend_core(q, kv[:, :, 0], kv[:, :, 1], k_new, v_new, valid,
                        wo, attn_softcap)


def _attend_core_mla(q_lat, q_rope, lat, lat_new, valid, wv_b, wo):
    """Absorbed-MLA working-set attention shared by the pooled and legacy
    paths.

    q_lat: (B, H, r) and q_rope: (B, H, rr), both pre-scaled; lat: (B, S,
    D) gathered latent rows (D = r + rr, store dtype); lat_new: (B, D) the
    current token's latent row; valid: (B, 1, 1, S + 1) bool.  Scores are
    q_lat·ckv + q_rope·krope over the latent plane, the weighted sum stays
    in latent space, and W_UV is applied once afterwards (absorbed value
    projection) — masked rows contribute exact zeros, so ragged selections
    cost nothing numerically."""
    from repro.core import sparse_attention as sa
    B, H, r = q_lat.shape
    lat = jnp.concatenate([lat, lat_new[:, None].astype(lat.dtype)], axis=1)
    ckv, krope = lat[..., :r], lat[..., r:]
    scores = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                         ckv.astype(jnp.float32))
              + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                           krope.astype(jnp.float32)))
    # single logical kv head: reuse the shared masked partials with Hkv=1
    part = sa._masked_softmax_partials(scores[:, None],
                                       ckv[:, None], valid)
    out_lat = sa._finish(part)                               # (B, H, r)
    out = jnp.einsum("bhr,hrv->bhv", out_lat.astype(jnp.float32),
                     wv_b.astype(jnp.float32))
    return out.reshape(B, 1, -1).astype(q_lat.dtype) @ wo


@jax.jit
def _attend_pooled_mla(q_lat, q_rope, pool_kv, slots, chunk_ids, lengths,
                       lat_new, wv_b, wo):
    """Pooled MLA dispatch: gather latent chunk rows from the single-plane
    device slab by slot index (see :func:`_attend_pooled` for the
    masking/billing contract — identical, with D-wide latent rows in place
    of the K/V pair)."""
    lat = pool_kv[slots][:, :, 0]        # (B, nmax, chunk, 1, D)
    B, nmax = slots.shape
    chunk = pool_kv.shape[2]
    lat = lat.reshape(B, nmax * chunk, -1)
    pos = (chunk_ids[..., None] * chunk
           + jnp.arange(chunk, dtype=jnp.int32)).reshape(B, nmax * chunk)
    # strict mask, exactly as _attend_pooled: pos == length is unwritten
    ok = (chunk_ids[..., None] >= 0).repeat(chunk, -1).reshape(B, -1) \
        & (pos < lengths[:, None])
    valid = jnp.concatenate(
        [ok, jnp.ones((B, 1), bool)], axis=1)[:, None, None]
    return _attend_core_mla(q_lat, q_rope, lat, lat_new, valid, wv_b, wo)


@jax.jit
def _attend_workingset_mla(q_lat, q_rope, latg, lat_new, valid, wv_b, wo):
    """Legacy MLA dispatch: host-assembled latent working set uploaded
    whole (the PR-1 synchronous A/B path).  latg: (B, nmax, chunk, 1, D)."""
    B = latg.shape[0]
    lat = latg.reshape(B, latg.shape[1] * latg.shape[2], -1)
    return _attend_core_mla(q_lat, q_rope, lat, lat_new, valid, wv_b, wo)


class BatchedLeoAMEngine:
    """Batched tiered-decoding engine over a decoder-only model.

    Sequences join via :meth:`add_sequence` (per-request prefill, as in
    continuous batching), decode together via :meth:`decode_round`, and
    leave via :meth:`release` — the scheduler drives exactly this API.
    """

    def __init__(self, cfg: ArchConfig, params, ecfg: EngineCfg, *,
                 max_seqs: int = 1,
                 device_chunk_budget: Optional[int] = None):
        if cfg.is_encdec:
            raise ValueError(
                f"LeoAMEngine drives decoder-only models; '{cfg.name}' is "
                f"an encoder-decoder architecture — serve it with the "
                f"per-request runtime paths instead")
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.chunk = cfg.leoam.chunk_size
        self.n_chunks = ecfg.max_len // self.chunk
        self.max_seqs = max_seqs
        self.attn_layers = [i for i, k in enumerate(cfg.layer_kinds())
                            if k.startswith("attn")]
        # absorbed-MLA stacks tier ONE latent row per token — concat(ckv,
        # krope), a single logical kv head of width kv_lora_rank +
        # qk_rope_head_dim — through the same store/selection machinery:
        # the LKA box over the concatenated latent IS the MLA bound
        # (q_lat·ckv + q_rope·krope == q_cat·latent), so chunk importance
        # reuses chunk_bounds_gqa_matmul with Hkv=1 unchanged.
        self.mla = cfg.mla is not None
        if ecfg.prefix_cache:
            bad = [k for k in cfg.layer_kinds() if not k.startswith("attn")]
            if bad:
                # recurrent blocks carry decode state OUTSIDE the KV store
                # (mamba/xlstm hidden state), which a by-reference prefix
                # adoption cannot reconstruct — warm resume would be wrong
                raise ValueError(
                    f"prefix_cache requires an attention-only stack; "
                    f"'{cfg.name}' has non-attention layers {sorted(set(bad))} "
                    f"whose recurrent decode state the shared-prefix cache "
                    f"cannot adopt by reference")
            C = ecfg.prefill_chunk_tokens
            if C % self.chunk or ecfg.max_len % C:
                raise ValueError(
                    f"prefix_cache admissions run chunked prefill: "
                    f"prefill_chunk_tokens={C} must be a multiple of the "
                    f"store chunk ({self.chunk}) and divide max_len "
                    f"({ecfg.max_len})")
        if self.mla:
            self.lat_dim = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            kv_heads, kv_dim = 1, self.lat_dim
        else:
            kv_heads, kv_dim = cfg.n_kv_heads, cfg.hd
        budget = (device_chunk_budget * len(self.attn_layers)
                  if device_chunk_budget is not None else None)
        self.store = TieredKVStore(
            len(self.attn_layers), self.n_chunks, self.chunk,
            kv_heads, kv_dim, n_seqs=max_seqs,
            transit_codec=ecfg.transit_codec, device_budget=budget,
            use_pool=ecfg.pooled, pool_slots=device_chunk_budget,
            real_codec=ecfg.real_codec, disk_sidecar=ecfg.disk_sidecar,
            sidecar_lossless=ecfg.sidecar_lossless, latent=self.mla,
            prefix_rows=(max(1, ecfg.prefix_arena_rows)
                         if ecfg.prefix_cache else 0),
            debug_sync=ecfg.debug_sync, checksums=ecfg.checksums,
            faults=ecfg.fault_plan, io_retries=ecfg.io_retries,
            io_backoff_s=ecfg.io_backoff_s,
            abstract_kind=("pq" if ecfg.pq_abstracts else "minmax"),
            pq_m=ecfg.pq_m, pq_centroids=ecfg.pq_centroids,
            pq_train_iters=ecfg.pq_train_iters)
        self.seqs: Dict[int, _SeqState] = {}
        self._free: List[int] = list(range(max_seqs - 1, -1, -1))
        # DTP state: prefetch executor, per-(seq, layer) previous-round
        # selections, per-layer abstract cache, per-layer measured costs
        self._executor = _prefetch_executor() if ecfg.pipeline else None
        self._ingest_exec = (_prefetch_executor() if ecfg.overlap_ingest
                             else None)
        self._pf_futs: Dict[int, Future] = {}
        self._abs_cache: Dict[int, Tuple] = {}
        self._prev_sels: Dict[Tuple[int, int], List[int]] = {}
        self._lcost: Dict[int, Dict[str, float]] = {}
        self.round_profiles: List[Dict[str, float]] = []
        self.admit_profiles: List[Dict[str, float]] = []
        self._prefill_cache: Dict[int, Any] = {}
        self._chunk_prefill_cache: Dict[int, Any] = {}
        self._round_idx = 0
        # fault domain: per-seq terminal failure reasons (scheduler pops
        # them after each round) + engine-level counters
        self.failed: Dict[int, str] = {}
        self.seqs_failed = 0
        self.ingest_errors = 0
        # overload control: preempted sequences park here ({sid:
        # _SeqState}); they keep their engine slot — the store row holds
        # their only full replica — but release every hot-tier resource
        self.suspended: Dict[int, _SeqState] = {}

    @property
    def free_slots(self) -> int:
        """Sequence slots available for admission (scheduler-facing)."""
        return len(self._free)

    # ------------------------------------------------------------------
    # Sequence lifecycle
    # ------------------------------------------------------------------
    @decode_thread_only
    def add_sequence(self, tokens: np.ndarray) -> Tuple[int, int]:
        """Prefill one request into a free store slot.

        tokens: (S,).  Runs model prefill; K/V moves into the shared tier
        store under this sequence's slot.  With ``overlap_ingest`` each
        attention layer's K/V is handed to the store as soon as it is
        forced off the device, and the layer's disk replica + abstract
        writes run write-behind on the shared prefetch executor, overlapped
        under the remaining layers' prefill compute; ``decode_round`` and
        ``release`` fence them before any read.  Returns (seq id, first
        token).
        """
        self._check_capacity()
        self._check_prompt(tokens)     # validate BEFORE taking the slot
        sid = self._free.pop()
        self.failed.pop(sid, None)     # the slot starts a fresh lifetime
        try:
            return self._admit(sid, tokens, pool_place=True)
        except BaseException:
            # a failed synchronous admission must not leak the slot —
            # drain whatever the partial prefill already queued and
            # recycle before re-raising to the caller
            self.abort_admission(sid)
            raise

    @decode_thread_only
    def add_sequence_async(self, tokens: np.ndarray) -> Future:
        """Admission under decode: reserve a slot NOW, run the prefill +
        ingest on the process-wide admission worker, overlapped with the
        active batch's decode rounds — only the store-mutation critical
        sections serialize (the store lock).  The admitted sequence skips
        initial device-pool placement (the pool slab is read by decode's
        attention gathers outside the lock; the first decode round promotes
        its chunks instead — residency-only, token streams are unchanged).
        Returns a Future resolving to (seq id, first token); the sequence
        may join a decode round only after it resolves."""
        self._check_capacity()
        self._check_prompt(tokens)     # validate BEFORE taking the slot
        sid = self._free.pop()
        self.failed.pop(sid, None)     # the slot starts a fresh lifetime
        return _admit_executor().submit(self._admit_guarded, sid, tokens,
                                        pool_place=False)

    def _check_capacity(self) -> None:
        """Admission-path guard (raises, never asserts: admission requests
        are external input, and ``python -O`` must not admit past
        capacity).  The scheduler checks ``free_slots`` first; a direct
        caller gets an actionable error instead of a slot-leak."""
        if not self._free:
            raise ValueError(
                f"engine is at max_seqs={self.max_seqs} capacity — release "
                f"a sequence first, or rebuild the engine with a larger "
                f"max_seqs (the scheduler gates on engine.free_slots)")

    def _check_prompt(self, tokens: np.ndarray) -> None:
        """Reject oversized prompts before a slot is reserved — raising
        after the ``_free.pop()`` would leak the slot."""
        S = len(tokens)
        if S >= self.ecfg.max_len:
            raise ValueError(
                f"prompt length {S} needs < max_len={self.ecfg.max_len} "
                f"(decode appends past the prompt); raise EngineCfg.max_len "
                f"or truncate the prompt")

    @worker_thread
    def _admit_guarded(self, sid: int, tokens: np.ndarray, *,
                       pool_place: bool) -> Tuple[int, int]:
        """Admission-worker wrapper: any failure surfaces as a typed
        :class:`AdmissionError` carrying the slot id, so the scheduler
        (decode thread) can reclaim exactly that slot via
        :meth:`abort_admission` — the worker itself must not mutate the
        free list (slot recycling is decode-thread-owned)."""
        try:
            return self._admit(sid, tokens, pool_place=pool_place)
        except BaseException as e:
            raise AdmissionError(sid, e) from e

    @worker_thread
    def _admit(self, sid: int, tokens: np.ndarray, *,
               pool_place: bool) -> Tuple[int, int]:
        if self.ecfg.prefix_cache:
            # content-addressable admission always runs the chunked-prefill
            # path: a warm prefix loads the shared span's KV straight into
            # the cache and prefill resumes at the cold suffix — whole-
            # prompt prefill has no way to skip the matched span
            adm = ChunkedAdmission(self, sid, np.asarray(tokens),
                                   self.ecfg.prefill_chunk_tokens,
                                   pool_place=pool_place)
            while not adm.done:
                adm._step_impl()
            return adm.result
        cfg, ecfg = self.cfg, self.ecfg
        S = len(tokens)
        t0 = time.perf_counter()
        logits, cache = self._prefill(np.asarray(tokens))

        placement = self._default_placement()
        prefill_s = ingest_s = 0.0
        if self._ingest_exec is None:
            # serial path (PR-2): force the whole prefill, then ingest and
            # write every layer's replicas inline — the A/B baseline the
            # fig13 TTFT breakdown measures the tier-write stall against
            cache = jax.tree.map(np.asarray, cache)
            prefill_s = time.perf_counter() - t0
            t1 = time.perf_counter()
            for li, layer in enumerate(self.attn_layers):
                k, v = self._layer_kv(cache, layer)
                self.store.ingest(li, k[0], v[0],
                                  self._layer_placement(layer, placement),
                                  seq=sid, pool_place=pool_place)
            ingest_s = time.perf_counter() - t1
        else:
            # layer-streamed: force each attention layer's K/V in layer
            # order and hand it off immediately — the hot placement is
            # synchronous, the replica/abstract writes go write-behind on
            # the shared executor while later layers still compute
            for li, layer in enumerate(self.attn_layers):
                k, v = self._layer_kv(cache, layer)
                t1 = time.perf_counter()
                self.store.ingest(li, k[0], v[0],
                                  self._layer_placement(layer, placement),
                                  seq=sid, executor=self._ingest_exec,
                                  pool_place=pool_place)
                ingest_s += time.perf_counter() - t1
            cache = jax.tree.map(np.asarray, cache)
            prefill_s = time.perf_counter() - t0 - ingest_s
        tok = int(np.argmax(np.asarray(logits)[0]))
        self.seqs[sid] = _SeqState(cache=cache, length=S,
                                   access=AccessTable(self.n_chunks),
                                   tokens=np.asarray(tokens), prompt_len=S)
        self.admit_profiles.append({
            "total_s": time.perf_counter() - t0, "prefill_s": prefill_s,
            "ingest_s": ingest_s,
            "overlapped": float(self._ingest_exec is not None)})
        return sid, tok

    def _default_placement(self) -> Dict[int, str]:
        """Admission tier placement by chunk index (device head, host
        middle, disk tail)."""
        ecfg = self.ecfg
        n_gpu = max(1, int(self.n_chunks * ecfg.gpu_chunk_frac))
        n_cpu = max(1, int(self.n_chunks * ecfg.cpu_chunk_frac))
        return {c: DEVICE if c < n_gpu else
                (HOST if c < n_gpu + n_cpu else DISK)
                for c in range(self.n_chunks)}

    def _bucket_len(self, S: int) -> int:
        """Smallest bucket >= S: powers of two from 16, or the configured
        ``prefill_buckets`` schedule, capped at max_len (the cache pad)."""
        sched = self.ecfg.prefill_buckets
        if sched:
            for b in sorted(sched):
                if b >= S:
                    return min(int(b), self.ecfg.max_len)
            return self.ecfg.max_len
        b = 16
        while b < S:
            b <<= 1
        return min(b, self.ecfg.max_len)

    @property
    def prefill_programs(self) -> int:
        """Distinct compiled prefill programs (bucketed whole-prompt +
        chunk-step).  With ``bucket_prefill`` this stays O(log max_len)
        under ANY prompt-length distribution — the mixed-length bench and
        the CI baseline gate watch this counter."""
        return len(self._prefill_cache) + len(self._chunk_prefill_cache)

    def _prefill(self, tokens: np.ndarray):
        """Model prefill, jit-compiled per LENGTH BUCKET: the prompt is
        right-padded to the bucket and the true length rides in as a traced
        scalar (logits row, cache zeroing and recurrent-state masking all
        honor it — token-identical to exact-length prefill, tested), so
        ceil(log2(max_len))-ish programs serve any public-traffic length
        mix instead of one compile per distinct length.  One XLA call
        replaces thousands of eager op dispatches: admission cost drops
        several-fold, and the GIL is free for the decode thread while an
        async admission's prefill executes."""
        S = len(tokens)
        if not self.ecfg.jit_prefill:
            batch = {"tokens": jnp.asarray(np.asarray(tokens)[None],
                                           jnp.int32)}
            return lm.prefill(self.params, self.cfg, batch,
                              max_len=self.ecfg.max_len)
        cfg, max_len = self.cfg, self.ecfg.max_len
        if self.ecfg.bucket_prefill:
            B = self._bucket_len(S)
            padded = np.zeros(B, np.int64)
            padded[:S] = np.asarray(tokens)
            batch = {"tokens": jnp.asarray(padded[None], jnp.int32),
                     "length": jnp.int32(S)}
            key = B
        else:
            batch = {"tokens": jnp.asarray(np.asarray(tokens)[None],
                                           jnp.int32)}
            key = S
        fn = self._prefill_cache.get(key)
        if fn is None:
            fn = jax.jit(lambda p, b: lm.prefill(p, cfg, b, max_len=max_len))
            self._prefill_cache[key] = fn
        return fn(self.params, batch)

    def _prefill_chunk(self, batch: Dict[str, Any], cache):
        """One jitted chunked-prefill step; compiled once per chunk size
        (the cache is donated so XLA updates it in place)."""
        C = batch["tokens"].shape[1]
        fn = self._chunk_prefill_cache.get(C)
        if fn is None:
            cfg, max_len = self.cfg, self.ecfg.max_len
            fn = jax.jit(
                lambda p, b, c: lm.prefill_chunk(p, cfg, b, c,
                                                 max_len=max_len),
                donate_argnums=(2,))
            self._chunk_prefill_cache[C] = fn
        return fn(self.params, batch, cache)

    @decode_thread_only
    def begin_admission(self, tokens: np.ndarray, *,
                        chunk_tokens: Optional[int] = None,
                        pool_place: bool = True) -> "ChunkedAdmission":
        """Start a CHUNKED admission: reserves the slot now and returns a
        resumable :class:`ChunkedAdmission` whose ``step()`` forces one
        fixed-size prefill chunk through the cache and streams its K/V into
        the tier store (write-behind cold half unchanged), yielding between
        chunks so the caller can run decode rounds in the gaps — a very
        long prompt no longer stalls the round loop for its whole prefill.
        Intended to be stepped on the decode thread (the scheduler's
        chunked-admission mode); ``pool_place=False`` defers device-pool
        placement exactly like ``add_sequence_async``.  Drives GQA and
        absorbed-MLA stacks alike (MLA chunks stream latent rows through
        the store's single-plane layout)."""
        C = chunk_tokens or self.ecfg.prefill_chunk_tokens
        if C % self.chunk or self.ecfg.max_len % C:
            raise ValueError(
                f"prefill chunk_tokens={C} must be a multiple of the store "
                f"chunk ({self.chunk}) and divide max_len "
                f"({self.ecfg.max_len}) so partial ingests stay "
                f"chunk-aligned")
        self._check_capacity()
        self._check_prompt(tokens)     # validate BEFORE taking the slot
        sid = self._free.pop()
        self.failed.pop(sid, None)     # the slot starts a fresh lifetime
        return ChunkedAdmission(self, sid, tokens, C, pool_place=pool_place)

    _KV_LEAVES = ("k", "v", "ckv", "krope")

    def _layer_cache(self, cache, layer: int) -> Dict[str, Any]:
        """The KV/latent leaves of one layer's attention cache (body
        layers sliced out of their stacked repeat axis; pyramid leaves are
        engine-unused, so they are not materialized)."""
        pro_n = len(cache["prologue"])
        if layer < pro_n:
            return cache["prologue"][layer]
        period = self.cfg.period()
        bi = (layer - pro_n) // period
        pi = (layer - pro_n) % period
        return {k: v[bi] for k, v in cache["body"][pi].items()
                if k in self._KV_LEAVES}

    def _layer_kv_slice(self, cache, layer: int, start: int, n: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Like :meth:`_layer_kv` but pulls only rows [start, start+n) to
        the host — the chunked-admission stream-out.  MLA layers return
        the latent rows (concat(ckv, krope), a single kv head) in both
        positions."""
        c = self._layer_cache(cache, layer)
        sl = lambda a: np.asarray(
            jax.lax.dynamic_slice_in_dim(a, start, n, axis=1))[0]
        if self.mla:
            lat = np.concatenate([sl(c["ckv"]), sl(c["krope"])],
                                 axis=-1)[:, None, :]
            return lat, lat
        return sl(c["k"]), sl(c["v"])

    def _layer_placement(self, layer: int,
                         placement: Dict[int, str]) -> Dict[int, str]:
        if layer < self.cfg.leoam.early_layers:
            # early layers never go to disk (§4.3)
            return {c: (DEVICE if placement[c] == DEVICE else HOST)
                    for c in placement}
        return dict(placement)

    @decode_thread_only
    def release(self, sid: int) -> None:
        """Retire a sequence and recycle its store slot.

        Drains every in-flight future that may still reference the slot —
        write-behind ingest writes (per-seq fence), the DTP prefetch
        worker's staged reads, and queued sidecar repacks — BEFORE clearing
        the store, so a slow replica write can never land in a recycled
        slot's fresh data (and a queued repack completes deterministically
        instead of being aborted by the slot's version bump).

        Exception-safe: a raised cold-ingest future (the fence drains ALL
        of the seq's futures before surfacing the first failure), a failed
        prefetch, or a failed repack is counted but swallowed — the
        sequence is being retired, so the store teardown and slot recycle
        ALWAYS run; the slot can never leak and the fence can never stay
        poisoned for the next admission."""
        self._drain_seq(sid)
        self._abs_cache.clear()
        self.store.clear_seq(sid)
        self.seqs.pop(sid, None)
        self.suspended.pop(sid, None)
        for key in [k for k in self._prev_sels if k[0] == sid]:
            self._prev_sels.pop(key, None)
        if sid not in self._free:
            self._free.append(sid)

    def _drain_seq(self, sid: int) -> None:
        """Best-effort drain of every in-flight future that may reference
        a slot (ingest fence, prefetch worker, repack queue).  Failures
        are counted, never raised: every teardown path (release /
        abort_admission / fail_sequence) must run to completion."""
        try:
            self.store.ingest_fence(sid)
        except BaseException:
            self.ingest_errors += 1
        for li in list(self._pf_futs):
            fut = self._pf_futs.pop(li, None)
            if fut is not None:
                try:
                    fut.result()
                except BaseException:
                    pass
        try:
            self.store.requant_fence()
        except BaseException:
            pass

    @decode_thread_only
    def abort_admission(self, sid: int) -> None:
        """Reclaim a slot whose admission failed or was cancelled
        mid-flight (the decode-thread half of :class:`AdmissionError`
        handling, and the teardown for a deadline-cancelled
        :class:`ChunkedAdmission`).

        Drains the slot's write-behind ingest futures (their failure is
        the reason we are here — swallowed), then releases everything the
        partial admission may hold: pool slots and deferred placements,
        prefix-arena refcounts including the unpublished registration
        plan, tier entries, and the per-slot traffic log — before
        recycling the slot.  Idempotent."""
        self._drain_seq(sid)
        self.store.clear_seq(sid)
        self.seqs.pop(sid, None)
        self.suspended.pop(sid, None)
        for key in [k for k in self._prev_sels if k[0] == sid]:
            self._prev_sels.pop(key, None)
        if sid not in self._free:
            self._free.append(sid)

    @decode_thread_only
    def fail_sequence(self, sid: int, reason: str) -> None:
        """Contain ONE sequence's failure as its terminal state.

        Tears the sequence down exactly like :meth:`release` (drain,
        clear, recycle) and records the reason in :attr:`failed` for the
        scheduler to surface — no other sequence's state is touched, so
        their decode streams stay token-identical (chaos-tested)."""
        self._drain_seq(sid)
        self._abs_cache.clear()
        self.store.clear_seq(sid)
        self.seqs.pop(sid, None)
        self.suspended.pop(sid, None)
        for key in [k for k in self._prev_sels if k[0] == sid]:
            self._prev_sels.pop(key, None)
        if sid not in self._free:
            self._free.append(sid)
        self.failed[sid] = reason
        self.seqs_failed += 1

    # ------------------------------------------------------------------
    # Whole-sequence preemption (overload control)
    # ------------------------------------------------------------------
    @decode_thread_only
    def suspend_sequence(self, sid: int) -> None:
        """Preempt ONE live sequence: fence its write-behind ingest, drop
        its speculative prefetch state, swap its entire hot working set
        down to the disk tier (pool slots, host copies and prefix-arena
        refs all released — :meth:`TieredKVStore.swap_out_seq`), and park
        its decode state in :attr:`suspended`.

        The engine slot stays reserved — the victim's only full replica
        lives in that store row — so preemption relieves pool slots, host
        bytes, and the scheduler's batch seat, never ``free_slots``.
        Transparency (I7): the host-side ``_SeqState`` (model cache,
        access counts, prompt tokens) is preserved untouched, the store's
        access/abstract/CRC state is NOT cleared, and the write-through
        replica already holds every appended row — so suspend + resume is
        the identity on the token stream (property-tested)."""
        if sid not in self.seqs:
            raise KeyError(f"suspend_sequence: seq {sid} is not live "
                           f"(live={sorted(self.seqs)})")
        self._drain_seq(sid)
        self._abs_cache.clear()
        for key in [k for k in self._prev_sels if k[0] == sid]:
            self._prev_sels.pop(key, None)
        st = self.seqs.pop(sid)
        self.store.swap_out_seq(sid)
        self.suspended[sid] = st

    @decode_thread_only
    def resume_sequence(self, sid: int) -> None:
        """Un-park a suspended sequence: re-stage its remembered host
        working set from the disk replicas (``swap_in_seq``; a chunk that
        fails verification degrades to the engine's usual disk-lost
        recovery on its next fetch) and rejoin the live set — the next
        decode round continues bitwise where the victim left off."""
        st = self.suspended.pop(sid, None)
        if st is None:
            raise KeyError(f"resume_sequence: seq {sid} is not suspended "
                           f"(suspended={sorted(self.suspended)})")
        self.store.swap_in_seq(sid)
        self.seqs[sid] = st

    def fault_stats(self) -> Dict[str, float]:
        """Engine + store fault-domain counters (scheduler/audit-facing)."""
        out = self.store.fault_stats()
        out["seqs_failed"] = float(self.seqs_failed)
        out["ingest_errors"] = float(self.ingest_errors)
        return out

    def pool_stats(self) -> Dict[str, float]:
        """Live device-pool occupancy/hit counters (scheduler-facing)."""
        return self.store.pool_stats()

    def admission_need_chunks(self, prompt_len: int, max_new: int) -> int:
        """Worst-case per-round device working set of one request, in pool
        slots per layer — what pool-aware admission charges a sequence
        (far below the analytic ``max_len``-chunks worst case)."""
        cfg, ecfg = self.cfg, self.ecfg
        L = min(prompt_len + max_new, ecfg.max_len)
        nv = -(-L // self.chunk)
        rate = max(cfg.leoam.importance_rate, cfg.leoam.early_rate)
        sel = -(-max(self.chunk, math.ceil(L * rate)) // self.chunk)
        forced = (cfg.leoam.sink_chunks + cfg.leoam.recent_chunks
                  + math.ceil(ecfg.hot_frac * nv))
        return min(nv, sel + forced)

    def _layer_kv(self, cache, layer: int) -> Tuple[np.ndarray, np.ndarray]:
        """Pull (k, v) (B, S, Hkv, hd) for a layer out of a model cache.
        MLA layers yield the latent rows (B, S, 1, r + rr) in both
        positions (the store keeps a single latent plane)."""
        c = self._layer_cache(cache, layer)
        if self.mla:
            lat = np.concatenate([np.asarray(c["ckv"]),
                                  np.asarray(c["krope"])],
                                 axis=-1)[:, :, None, :]
            return lat, lat
        return np.asarray(c["k"]), np.asarray(c["v"])

    # ------------------------------------------------------------------
    # DTP: measured-cost θ balance + speculative prefetch
    # ------------------------------------------------------------------
    def _theta(self, li: int) -> float:
        """Per-layer compressed fraction of the upload delta (§4.4): the
        smallest θ hiding the transfer under the measured compute window."""
        if not (self.ecfg.real_codec and self.ecfg.transit_codec):
            return 1.0
        lc = self._lcost.get(li)
        if lc is None:
            return 1.0                 # no measurement yet: compress all
        bw = dtp.TierBW(pcie=self.ecfg.pcie_bw, disk=self.ecfg.disk_bw,
                        kappa=self.ecfg.kappa,
                        delta=compression.codec_ratio(self.ecfg.transit_codec,
                                                      group=self.chunk))
        return dtp.theta_from_measured(lc["D"], lc["T0"], lc["Tc"], bw)

    def _update_costs(self, li: int, upload_bytes: float, disk_bytes: float,
                      compute_s: float) -> None:
        """EMA of the layer's measured round costs.  Without ``profile``
        the compute window is (round − host stages)/n_attn — an UPPER
        bound that also amortizes MLP/recurrent layers over the attention
        layers, so θ errs toward less compression; run with
        ``profile=True`` for the per-dispatch-blocked exact window."""
        lc = self._lcost.setdefault(li, {"D": upload_bytes, "T0": disk_bytes,
                                         "Tc": max(compute_s, 1e-7)})
        for k, v in (("D", upload_bytes), ("T0", disk_bytes),
                     ("Tc", max(compute_s, 1e-7))):
            lc[k] = 0.5 * lc[k] + 0.5 * v

    def _submit_prefetch(self, li: int, order: Sequence[int],
                         lengths: np.ndarray) -> None:
        """Overlap layer ``li``'s abstract reads + speculative disk staging
        under the previous layer's attention.  Predictions come from the
        previous round's selection for (seq, li), else the AccessTable hot
        set — residency-only, so a miss can never change outputs.

        The thread hop only pays for itself when there is disk latency to
        hide, so the submit is adaptive: once the predicted working set is
        fully above the disk tier (steady state on a warm pool) the layer
        is handled inline and the worker stays idle."""
        if self._executor is None or li >= len(self.attn_layers) \
                or li in self._pf_futs:
            return
        chunks_by_seq = {}
        pred = {}
        any_disk = False
        for i, sid in enumerate(order):
            nv = (int(lengths[i]) + self.chunk - 1) // self.chunk
            chunks_by_seq[sid] = list(range(nv))
            prev = self._prev_sels.get((sid, li))
            if prev is None:
                prev = [int(c) for c in
                        self.seqs[sid].access.hot_tokens(self.ecfg.hot_frac)]
            pred[sid] = [c for c in prev if c < nv]
            tiers = self.store.tier_view(sid, li) \
                if self.ecfg.prefix_cache else self.store.tier[sid, li]
            if not any_disk and any(tiers[c] == DISK for c in pred[sid]):
                any_disk = True
        if not any_disk:
            return
        key = tuple((sid, len(chunks_by_seq[sid])) for sid in order)

        @worker_thread
        def work():
            res = (self.store.read_abstracts_pq_batch(li, chunks_by_seq)
                   if self.ecfg.pq_abstracts
                   else self.store.read_abstracts_batch(li, chunks_by_seq))
            self._abs_cache[li] = (key, res)
            self.store.stage_host(li, pred)

        self._pf_futs[li] = self._executor.submit(work)

    # ------------------------------------------------------------------
    # Importance evaluation (batched LKA + per-sequence IAKM)
    # ------------------------------------------------------------------
    def _select_chunks_batched(self, li: int, layer: int, q: np.ndarray,
                               order: Sequence[int], lengths: np.ndarray
                               ) -> Tuple[Dict[int, List[int]],
                                          Dict[int, StepStats]]:
        """One bounds matmul over the stacked batch, then per-sequence
        chunk-level adaptive selection (tree/IAKM or flat) on the host.

        q: (B, H, d) PRE-SCALED queries, rows matching ``order`` — GQA
        passes q/sqrt(hd) against the per-head key boxes; MLA passes
        concat(q_lat, q_rope)·scale against the latent boxes (Hkv=1), for
        which the same positive/negative-split matmul bound is exact.
        """
        cfg = self.cfg
        chunk = self.chunk
        n_valid = {sid: (int(L) + chunk - 1) // chunk
                   for sid, L in zip(order, lengths)}
        chunks_by_seq = {sid: list(range(n_valid[sid])) for sid in order}
        use_pq = self.ecfg.pq_abstracts
        fut = self._pf_futs.pop(li, None)
        if fut is not None:
            fut.result()
        cached = self._abs_cache.pop(li, None)
        key = tuple((sid, n_valid[sid]) for sid in order)
        if cached is not None and cached[0] == key:
            res = cached[1]
        else:   # speculation miss (round composition changed): sync read.
                # The worker's read stays billed — two reads really
                # happened; that is the cost of a wrong speculation.
            res = (self.store.read_abstracts_pq_batch(li, chunks_by_seq)
                   if use_pq
                   else self.store.read_abstracts_batch(li, chunks_by_seq))
        if use_pq:
            km, kn, pq_codes, pq_valid, pq_cb, abs_billed = res
        else:
            km, kn, abs_billed = res
            pq_valid = None

        qj = jnp.asarray(q)                                  # (B, H, d)
        ub, _ = chunk_bounds_gqa_matmul(qj, jnp.asarray(km), jnp.asarray(kn))
        ub = np.asarray(ub)                                  # (B, Hkv, ncmax)
        adc = None
        if use_pq and pq_valid.any():
            # asymmetric-distance scores off the PQ codes: the exact-logit
            # analog of the bounds path's group sum — q summed per kv
            # group against decoded centroids, max over a chunk's live
            # tokens.  Only code-valid chunks use it; the rest keep the
            # min/max upper bound BITWISE (np.where below selects whole
            # values, never mixes them).
            B, H = q.shape[0], q.shape[1]
            Hkv = km.shape[2]
            q_sum = q.reshape(B, Hkv, H // Hkv, -1).sum(2)   # (B, Hkv, d)
            adc = adc_chunk_scores(q_sum, pq_cb, pq_codes,
                                   np.asarray(lengths))      # (B, Hkv, nc)

        rate = (cfg.leoam.early_rate if layer < cfg.leoam.early_layers
                else cfg.leoam.importance_rate)
        sels: Dict[int, List[int]] = {}
        stats: Dict[int, StepStats] = {}
        for i, sid in enumerate(order):
            st = StepStats(abstract_bytes=abs_billed[sid])
            nv = n_valid[sid]
            length = int(lengths[i])
            scores = ub[i].max(0)[:nv]                       # (nv,)
            if adc is not None:
                v = pq_valid[i, :nv]
                scores = np.where(v, adc[i].max(0)[:nv], scores)
            budget_tokens = max(chunk, int(math.ceil(length * rate)))
            # chunk-level fast path: equivalent to the per-token
            # repeat+select (tested) without the length-S allocation
            chunk_scores = scores / chunk
            if self.ecfg.selection == "tree":
                sel, st.evaluations = tree_select_chunks(
                    chunk_scores, length, budget_tokens, chunk)
            else:
                sel, st.evaluations = flat_select_chunks(
                    chunk_scores, length, budget_tokens, chunk)
            # sink + recent + hot chunks always included
            forced = set(range(cfg.leoam.sink_chunks))
            forced.update(range(max(0, nv - cfg.leoam.recent_chunks), nv))
            forced.update(
                int(c) for c in self.seqs[sid].access.hot_tokens(
                    self.ecfg.hot_frac) if c < nv)
            sels[sid] = sorted(set(sel) | forced)
            stats[sid] = st
        return sels, stats

    # ------------------------------------------------------------------
    # Decode round
    # ------------------------------------------------------------------
    # decode_round is allowed this many ChunkLostError recoveries before
    # giving up — each recovery either restores chunks or removes a
    # sequence, so a loop that reaches the bound indicates a live fault
    # injector scheduling pathological back-to-back losses
    _MAX_ROUND_RETRIES = 8

    @decode_thread_only
    def decode_round(self, tokens: Dict[int, int]) -> Dict[int, int]:
        """One token for every sequence in ``tokens`` ({seq id: last token}).

        Per attention layer: batched importance eval, one delta promotion
        into the device pool (or one legacy coalesced gather), one padded
        attention dispatch; with ``pipeline`` the next layer's reads run
        under this layer's attention.  Non-attention (recurrent / dense)
        layers keep their exact per-sequence decode path.  Returns
        {seq id: next token}.

        FAILURE CONTAINMENT (I6): a failure on one sequence never takes
        the batch down.  A raised cold-ingest fence fails just that
        sequence (terminal state in :attr:`failed`); a disk-lost chunk
        (:class:`ChunkLostError` from a checksum mismatch or exhausted
        retries) triggers recompute-from-prompt of exactly the affected
        span when it lies inside the prompt (bitwise-identical chunked
        prefill), else fails the owning sequence — and the round retries
        with the survivors, whose streams stay token-identical (batched
        attention is FP-exact w.r.t. batch composition).  Returns {} when
        every sequence failed; the scheduler pops :attr:`failed`.
        """
        if not tokens:
            raise ValueError(
                "decode_round needs at least one sequence: pass "
                "{seq id: last token} for every live sequence (admit one "
                "via add_sequence / add_sequence_async first)")
        live = dict(tokens)
        for sid in sorted(live):        # write-behind completion fence: no
            try:                        # read sees a half-written replica
                self.store.ingest_fence(sid)
            except BaseException as e:
                self.ingest_errors += 1
                self.fail_sequence(sid, f"cold ingest failed: {e!r}")
                live.pop(sid)
        for _ in range(self._MAX_ROUND_RETRIES):
            if not live:
                return {}
            snap = self._snapshot_round(live)
            try:
                return self._decode_round_impl(live)
            except ChunkLostError as e:
                self._restore_round(snap)
                self._recover_lost(e, live)
        raise RuntimeError(
            f"decode round failed to converge after "
            f"{self._MAX_ROUND_RETRIES} chunk-loss recoveries — the disk "
            f"is losing chunks faster than recompute restores them")

    def _snapshot_round(self, live: Dict[int, int]) -> Dict[str, Any]:
        """Capture the host-side state a partial round mutates before its
        first dispatch can raise, so a retry re-runs from a clean slate.
        Device/pool residency and store billing need no rollback: both
        are value-neutral (residency moves bytes, never values; a retried
        read honestly re-bills)."""
        return {
            "access": {sid: self.seqs[sid].access.counts.copy()
                       for sid in live},
            "prev_sels": dict(self._prev_sels),
        }

    def _restore_round(self, snap: Dict[str, Any]) -> None:
        """Roll back the selection state a failed round half-mutated and
        drop its speculative prefetch (the futures may hold stale layer
        predictions — and one may carry the same ChunkLostError)."""
        for sid, counts in snap["access"].items():
            if sid in self.seqs:
                self.seqs[sid].access.counts[:] = counts
        self._prev_sels.clear()
        self._prev_sels.update(snap["prev_sels"])
        for li in list(self._pf_futs):
            fut = self._pf_futs.pop(li, None)
            if fut is not None:
                try:
                    fut.result()
                except BaseException:
                    pass
        self._abs_cache.clear()

    def _recover_lost(self, e: ChunkLostError,
                      live: Dict[int, int]) -> None:
        """Handle one ChunkLostError: recompute every affected sequence
        whose lost chunks all lie inside its prompt span; fail the rest.

        Recompute covers ALL of a sequence's currently-lost chunks (the
        store's ``disk_lost_keys``), not just the ones this particular
        gather tripped on — one chunked-prefill replay restores the whole
        span."""
        by_seq: Dict[int, set] = {}
        for seq, _p, c in e.keys:
            by_seq.setdefault(seq, set()).add(c)
        lost_all = self.store.disk_lost_keys()
        for sid, cs in by_seq.items():
            if sid not in live:
                continue
            # fold in every OTHER chunk the store currently marks lost for
            # this sequence (a speculative prefetch may have found more):
            # one prefill replay restores the whole set
            cs = cs | {c for (p, _li, c) in lost_all
                       if self.store._phys(sid, c) == p}
            s = self.seqs.get(sid)
            recomputable = (
                s is not None and s.tokens is not None
                and all(min((c + 1) * self.chunk, s.length) <= s.prompt_len
                        for c in cs))
            if not recomputable:
                # the lost span includes decode appends (or the prompt is
                # gone): the KV exists nowhere else — terminal for this
                # sequence, invisible to every other one
                self.fail_sequence(
                    sid, f"disk-lost chunks {sorted(cs)} at layer "
                         f"{e.layer} not recomputable from prompt")
                live.pop(sid)
                continue
            self._recompute_chunks(sid, cs)

    def _recompute_chunks(self, sid: int, cs: List[int]) -> None:
        """Recompute-from-prompt for one sequence's disk-lost prompt-span
        chunks: replay the PR-4 chunked prefill (bitwise-identical to the
        original admission) through the last lost chunk and re-land every
        (layer, chunk) the store still marks lost via
        :meth:`TieredKVStore.restore_chunk` — replica, abstract and CRC
        rebuilt; the quarantined sidecar repacks lazily."""
        s = self.seqs[sid]
        toks = np.asarray(s.tokens)
        C = self.ecfg.prefill_chunk_tokens
        end = min(len(toks), (max(cs) + 1) * self.chunk)
        end = min(-(-end // C) * C, self.ecfg.max_len)
        cache = lm.init_decode_cache(self.cfg, 1, self.ecfg.max_len)
        pos = 0
        while pos < end:
            chunk_toks = np.zeros(C, np.int64)
            take = min(C, len(toks) - pos)
            if take > 0:
                chunk_toks[:take] = toks[pos:pos + take]
            batch = {"tokens": jnp.asarray(chunk_toks[None], jnp.int32),
                     "start": jnp.int32(pos),
                     "length": jnp.int32(len(toks))}
            _, cache = self._prefill_chunk(batch, cache)
            pos += C
        lost_now = self.store.disk_lost_keys()
        for li, layer in enumerate(self.attn_layers):
            for c in sorted(set(cs)):
                if (self.store._phys(sid, c), li, c) not in lost_now:
                    continue
                k, v = self._layer_kv_slice(cache, layer, c * self.chunk,
                                            self.chunk)
                self.store.restore_chunk(li, sid, c, k, v)

    @decode_thread_only
    def _decode_round_impl(self, tokens: Dict[int, int]) -> Dict[int, int]:
        """The round body (see :meth:`decode_round`); every sequence in
        ``tokens`` is live and fenced.  Raises :class:`ChunkLostError`
        for the wrapper's recovery loop."""
        cfg, ecfg = self.cfg, self.ecfg
        order = sorted(tokens)
        B = len(order)
        states = [self.seqs[sid] for sid in order]
        lengths = np.array([s.length for s in states], np.int64)
        x = jnp.asarray([[tokens[sid]] for sid in order], jnp.int32)
        params = self.params
        h = jnp.take(params["embed"], x, axis=0)             # (B, 1, d)

        prologue, period, repeats = lm._layer_plan(cfg)
        round_stats = {sid: StepStats() for sid in order}
        prof = {"eval_s": 0.0, "gather_s": 0.0, "upload_s": 0.0,
                "attend_s": 0.0}
        layer_io: List[Tuple[int, float, float]] = []  # (li, upB, diskB)
        t_round = time.perf_counter()
        li = 0
        new_caches = [{"prologue": list(s.cache["prologue"]),
                       "body": list(s.cache["body"])} for s in states]

        def run_attn(blk, kind, mlpk, h, layer_idx):
            nonlocal li
            hln = attn_mod.rms_norm(h, blk["ln1"], cfg.norm_eps)
            pos = jnp.asarray(lengths[:, None], jnp.int32)   # (B, 1)
            if self.mla:
                # absorbed MLA: the query lives in latent space (q_lat =
                # q_nope @ W_UK ‖ q_rope) and the new token's cache row is
                # ONE latent vector; both selection and attention run over
                # the store's single latent plane
                m = cfg.mla
                p = blk["core"]
                q_nope, q_rope = attn_mod._mla_q(p, cfg, hln, pos)
                scale = 1.0 / math.sqrt(m.qk_nope_head_dim
                                        + m.qk_rope_head_dim)
                q_lat = jnp.einsum("bhd,hrd->bhr", q_nope[:, 0],
                                   p["wk_b"]) * scale
                q_rope = q_rope[:, 0] * scale
                kv_a = (hln @ p["wkv_a"])[:, 0]
                ckv_new = attn_mod.rms_norm(kv_a[:, : m.kv_lora_rank],
                                            p["kv_norm"], cfg.norm_eps)
                krope_new = attn_mod.rotate(
                    cfg, kv_a[:, None, None, m.kv_lora_rank:], pos)[:, 0, 0]
                lat_new = jnp.concatenate([ckv_new, krope_new], axis=-1)
                qn = np.asarray(jnp.concatenate([q_lat, q_rope], axis=-1))
            else:
                q, k_new, v_new = attn_mod._qkv(blk["core"], cfg, hln, pos)
                qn = np.asarray(q[:, 0]) / math.sqrt(cfg.hd)  # (B, H, hd)
            t0 = time.perf_counter()
            sels, sel_stats = self._select_chunks_batched(
                li, layer_idx, qn, order, lengths)
            prof["eval_s"] += time.perf_counter() - t0

            nmax = max(len(s) for s in sels.values())
            pad = max(1, ecfg.sel_pad)
            nmax = -(-nmax // pad) * pad

            for i, sid in enumerate(order):
                st = round_stats[sid]
                st.evaluations += sel_stats[sid].evaluations
                st.fetched_chunks += len(sels[sid])
                st.abstract_bytes += sel_stats[sid].abstract_bytes
                self.seqs[sid].access.record(np.asarray(sels[sid]))
                self._prev_sels[(sid, li)] = sels[sid]

            if ecfg.pooled:
                slots, _, fst = self.store.fetch_chunks_pooled(
                    li, sels, pad_to=nmax, theta=self._theta(li))
                prof["gather_s"] += fst.gather_s
                prof["upload_s"] += fst.upload_s
                layer_io.append((li, fst.uploads * self.store.chunk_bytes,
                                 fst.disk_bytes))
                for sid in order:
                    round_stats[sid].fetched_bytes += fst.upload_bytes / B
                # overlap: next layer's reads under this layer's attention
                self._submit_prefetch(li + 1, order, lengths)
                chunk_ids = np.full((B, nmax), -1, np.int32)
                for i, sid in enumerate(order):
                    chunk_ids[i, :len(sels[sid])] = sels[sid]
                pool = self.store.pools[li]
                t1 = time.perf_counter()
                if self.mla:
                    y = _attend_pooled_mla(
                        q_lat, q_rope, pool.kv, jnp.asarray(slots),
                        jnp.asarray(chunk_ids),
                        jnp.asarray(lengths.astype(np.int32)),
                        lat_new, blk["core"]["wv_b"], blk["core"]["wo"])
                else:
                    y = _attend_pooled(q, pool.kv, jnp.asarray(slots),
                                       jnp.asarray(chunk_ids),
                                       jnp.asarray(lengths.astype(np.int32)),
                                       k_new, v_new, blk["core"]["wo"],
                                       attn_softcap=cfg.attn_softcap)
                if ecfg.profile:
                    jax.block_until_ready(y)
                    prof["attend_s"] += time.perf_counter() - t1
            else:
                # positions per padded slot; sentinel pads fail pos < len.
                # Strict mask: the store holds tokens 0..length-1 here
                # (this round's token rides in k_new/v_new), so pos ==
                # length is an unwritten/stale row, never attended.
                S = nmax * self.chunk + 1
                pos_np = np.full((B, S), np.iinfo(np.int64).max, np.int64)
                for i, sid in enumerate(order):
                    sel = np.asarray(sels[sid])
                    p = (sel[:, None] * self.chunk
                         + np.arange(self.chunk)[None]).reshape(-1)
                    pos_np[i, :len(p)] = p
                valid_np = pos_np < lengths[:, None]
                valid_np[:, -1] = True           # the new token's column
                valid = jnp.asarray(valid_np)[:, None, None]
                t1 = time.perf_counter()
                kg, vg, _ = self.store.fetch_chunks_batch(li, sels,
                                                          pad_to=nmax)
                prof["gather_s"] += time.perf_counter() - t1
                t1 = time.perf_counter()
                kgj = jnp.asarray(kg)
                vgj = kgj if self.mla else jnp.asarray(vg)
                prof["upload_s"] += time.perf_counter() - t1
                t1 = time.perf_counter()
                if self.mla:
                    y = _attend_workingset_mla(q_lat, q_rope, kgj, lat_new,
                                               valid, blk["core"]["wv_b"],
                                               blk["core"]["wo"])
                else:
                    y = _attend_workingset(q, kgj, vgj, k_new, v_new, valid,
                                           blk["core"]["wo"],
                                           attn_softcap=cfg.attn_softcap)
                if ecfg.profile:
                    jax.block_until_ready(y)
                    prof["attend_s"] += time.perf_counter() - t1
            if self.mla:
                lat_np = np.asarray(lat_new)[:, None, :]     # (B, 1, D)
                self.store.append_tokens_batch(li, lengths, lat_np, None,
                                               seqs=order)
            else:
                kn_np = np.asarray(k_new[:, 0])
                vn_np = np.asarray(v_new[:, 0])
                self.store.append_tokens_batch(li, lengths, kn_np, vn_np,
                                               seqs=order)
            li += 1
            h = h + y
            h, _ = lm._apply_mlp(blk, cfg, mlpk, h, None, no_drop=True)
            return h

        def run_other(blk, kind, mlpk, h, layer_idx, cache_slices):
            """Recurrent/dense layers: exact per-sequence standard decode."""
            rows, new_slices = [], []
            for i, cs in enumerate(cache_slices):
                hi, c2, _ = lm._block_decode(blk, cfg, kind, mlpk, h[i:i + 1],
                                             cs, jnp.int32(int(lengths[i])),
                                             layer_idx=layer_idx,
                                             ctx=attn_mod.LOCAL_CTX)
                rows.append(hi)
                new_slices.append(c2)
            return jnp.concatenate(rows, axis=0), new_slices

        for pi, (idx, kind, mlpk) in enumerate(prologue):
            blk = params["prologue"][pi]
            if kind.startswith("attn"):
                h = run_attn(blk, kind, mlpk, h, idx)
            else:
                slices = [s.cache["prologue"][pi] for s in states]
                h, new_slices = run_other(blk, kind, mlpk, h, idx, slices)
                for i in range(B):
                    new_caches[i]["prologue"][pi] = new_slices[i]
        for r in range(repeats):
            for pi, (kind, mlpk) in enumerate(period):
                blk = jax.tree.map(lambda a: a[r], params["body"][pi])
                if kind.startswith("attn"):
                    h = run_attn(blk, kind, mlpk, h, 10 ** 6)
                    continue
                slices = [jax.tree.map(lambda a: a[r], s.cache["body"][pi])
                          for s in states]
                h, new_slices = run_other(blk, kind, mlpk, h, 10 ** 6, slices)
                for i in range(B):
                    def put(a, b):
                        a = np.asarray(a)
                        a[r] = np.asarray(b)
                        return a
                    new_caches[i]["body"][pi] = jax.tree.map(
                        put, new_caches[i]["body"][pi], new_slices[i])

        logits = np.asarray(lm._logits(params, cfg, h)[:, 0])  # (B, V)
        total_s = time.perf_counter() - t_round
        prof["total_s"] = total_s
        if not ecfg.profile:
            prof["attend_s"] = max(0.0, total_s - prof["eval_s"]
                                   - prof["gather_s"] - prof["upload_s"])
        self.round_profiles.append(prof)
        # feed measured per-layer costs back into the θ balance
        n_attn = max(1, len(self.attn_layers))
        tc = prof["attend_s"] / n_attn
        for lid, up_b, disk_b in layer_io:
            self._update_costs(lid, up_b, disk_b, tc)
        out: Dict[int, int] = {}
        for i, sid in enumerate(order):
            s = self.seqs[sid]
            s.cache = new_caches[i]
            s.length += 1
            s.stats.append(round_stats[sid])
            out[sid] = int(np.argmax(logits[i]))
        self._round_idx += 1
        if ecfg.sidecar_requant and (ecfg.disk_sidecar or ecfg.pq_abstracts):
            # background repack of append-dirtied sidecars and/or PQ
            # re-encode of append-dirtied codes (chunks quiet for a full
            # round): long-running sequences regain packed disk->host
            # promotions / ADC scoring instead of fp16/min-max forever
            self.store.requant_sweep(executor=_prefetch_executor())
        return out


class ChunkedAdmission:
    """Resumable chunked prefill of ONE request (vLLM-style).

    Produced by :meth:`BatchedLeoAMEngine.begin_admission`; each
    :meth:`step` forces one fixed-size prefill chunk through the model
    cache (one compiled program for every chunk of every prompt), streams
    the chunk's K/V into the tier store — hot placement synchronous, cold
    replica/abstract writes write-behind exactly as whole-prompt admission
    — and returns control to the caller, so decode rounds interleave with a
    long prompt's admission instead of stalling behind it.  After the final
    prompt chunk the remaining cache rows (zeros) are ingested too, so tier
    coverage, abstracts and the slot-scrub invariant match whole-prompt
    admission chunk for chunk; the resulting sequence is token-identical to
    an ``add_sequence`` admission (tested).  ``result`` resolves to
    (seq id, first token) when ``done``.
    """

    def __init__(self, engine: BatchedLeoAMEngine, sid: int,
                 tokens: np.ndarray, chunk_tokens: int, *,
                 pool_place: bool = True):
        self.engine = engine
        self.sid = sid
        self.tokens = np.asarray(tokens)
        self.S = len(self.tokens)
        self.C = int(chunk_tokens)
        self.pool_place = pool_place
        self.pos = 0
        self.cache = lm.init_decode_cache(engine.cfg, 1, engine.ecfg.max_len)
        self.placement = engine._default_placement()
        self.result: Optional[Tuple[int, int]] = None
        self.cancelled = False
        self.n_steps = 0
        self._t0 = time.perf_counter()
        self._prefill_s = 0.0
        self._ingest_s = 0.0
        self._hit_tokens = 0
        if engine.ecfg.prefix_cache:
            # content-addressable fast path: adopt the matched chunk span
            # by reference, replay its fidelity rows into the cache, and
            # resume prefill at the cold suffix.  The last prompt chunk is
            # ALWAYS recomputed — the first token's logits need a forward
            # pass — and its recomputed KV is dropped by ingest for
            # adopted chunks, never shadowing the shared bytes.
            hit = engine.store.prefix_admit(sid, self.tokens)
            self._hit_tokens = int(hit)
            resume = min((hit // self.C) * self.C,
                         ((self.S - 1) // self.C) * self.C)
            if resume > 0:
                rows = engine.store.prefix_fill_rows(sid, resume)
                self.cache = lm.load_prefix_rows(engine.cfg, self.cache,
                                                 rows, resume)
                self.pos = resume

    @property
    def done(self) -> bool:
        return self.result is not None

    @property
    def remaining(self) -> int:
        """Prompt tokens still to prefill."""
        return max(0, self.S - self.pos)

    def _ingest_rows(self, li: int, layer: int, k: np.ndarray,
                     v: np.ndarray, start: int) -> None:
        eng = self.engine
        eng.store.ingest(li, k, v,
                         eng._layer_placement(layer, self.placement),
                         seq=self.sid, executor=eng._ingest_exec,
                         pool_place=self.pool_place, start=start)

    @decode_thread_only
    def step(self) -> int:
        """Advance one chunk; returns prompt tokens consumed (0 if done).
        Thin decode-thread wrapper over :meth:`_step_impl` — the prefix-
        cache admission worker drives ``_step_impl`` directly (the store
        calls it makes are all lock-protected ``@any_thread``/worker
        paths, and ``pool_place=False`` defers pool mutation)."""
        return self._step_impl()

    def cancel(self) -> None:
        """Abandon a partially-admitted request (deadline expiry or
        client cancellation).  Drains the write-behind futures of the
        chunks already streamed and releases every resource the partial
        admission holds — pool slots, deferred placements, prefix-arena
        refcounts including the unpublished registration plan — via
        :meth:`BatchedLeoAMEngine.abort_admission`; the slot recycles
        immediately.  After cancel, :meth:`step` is a no-op.  Must run on
        the decode thread (like ``step``)."""
        if self.done or self.cancelled:
            return
        self.cancelled = True
        self.engine.abort_admission(self.sid)

    def _step_impl(self) -> int:
        if self.done or self.cancelled:
            return 0
        eng, C = self.engine, self.C
        take = min(C, self.S - self.pos)
        t0 = time.perf_counter()
        chunk_toks = np.zeros(C, np.int64)
        chunk_toks[:take] = self.tokens[self.pos:self.pos + take]
        batch = {"tokens": jnp.asarray(chunk_toks[None], jnp.int32),
                 "start": jnp.int32(self.pos),
                 "length": jnp.int32(self.S)}
        logits, self.cache = eng._prefill_chunk(batch, self.cache)
        t1 = time.perf_counter()
        self._prefill_s += t1 - t0
        for li, layer in enumerate(eng.attn_layers):
            k, v = eng._layer_kv_slice(self.cache, layer, self.pos, C)
            self._ingest_rows(li, layer, k, v, self.pos)
        self._ingest_s += time.perf_counter() - t1
        self.pos += take
        self.n_steps += 1
        if self.pos >= self.S:
            self._finish(logits)
        return take

    def _finish(self, logits) -> None:
        eng = self.engine
        end = -(-self.S // self.C) * self.C      # rows ingested so far
        tail = eng.ecfg.max_len - end
        if tail > 0:
            # zero-fill the uncovered tail chunks: whole-prompt admission
            # ingests the full max_len cache, and parity of tier labels /
            # abstracts / the reused-slot scrub depends on matching it
            t1 = time.perf_counter()
            zk = np.zeros((tail, eng.store.kv_heads, eng.store.head_dim),
                          eng.store.dtype)
            for li, layer in enumerate(eng.attn_layers):
                self._ingest_rows(li, layer, zk, zk, end)
            self._ingest_s += time.perf_counter() - t1
        tok = int(np.argmax(np.asarray(logits)[0]))
        cache_np = jax.tree.map(np.asarray, self.cache)
        eng.seqs[self.sid] = _SeqState(cache=cache_np, length=self.S,
                                       access=AccessTable(eng.n_chunks),
                                       tokens=np.asarray(self.tokens),
                                       prompt_len=self.S)
        if eng.ecfg.prefix_cache:
            # publish the chunks this admission registered ONLY after the
            # write-behind cold writes land: adopters read the arena row's
            # disk replica, so publish-before-fence would expose
            # half-written bytes
            eng.store.ingest_fence(self.sid)
            eng.store.finish_admission(self.sid)
        eng.admit_profiles.append({
            "total_s": time.perf_counter() - self._t0,
            "prefill_s": self._prefill_s, "ingest_s": self._ingest_s,
            "overlapped": float(eng._ingest_exec is not None),
            "chunked": 1.0, "chunks": float(self.n_steps),
            "prefix_hit_tokens": float(self._hit_tokens)})
        self.result = (self.sid, tok)

    def drain(self) -> Tuple[int, int]:
        """Run every remaining chunk back to back (no interleaving)."""
        while not self.done:
            self.step()
        return self.result


class LeoAMEngine:
    """Single-sequence view: a B=1 wrapper over the batched engine,
    preserving the original prefill / decode_step / generate API."""

    def __init__(self, cfg: ArchConfig, params, ecfg: EngineCfg):
        self._engine = BatchedLeoAMEngine(cfg, params, ecfg, max_seqs=1)
        self._sid: Optional[int] = None

    # passthroughs used by benchmarks / scheduler / examples
    @property
    def cfg(self):
        return self._engine.cfg

    @property
    def ecfg(self):
        return self._engine.ecfg

    @property
    def chunk(self):
        return self._engine.chunk

    @property
    def n_chunks(self):
        return self._engine.n_chunks

    @property
    def attn_layers(self):
        return self._engine.attn_layers

    @property
    def store(self):
        return self._engine.store

    @property
    def round_profiles(self):
        return self._engine.round_profiles

    @property
    def admit_profiles(self):
        return self._engine.admit_profiles

    @property
    def length(self) -> int:
        return self._engine.seqs[self._sid].length if self._sid is not None \
            else 0

    @property
    def access(self):
        return self._engine.seqs[self._sid].access

    @property
    def stats(self) -> List[StepStats]:
        if self._sid is None:
            return []
        return self._engine.seqs[self._sid].stats

    # ------------------------------------------------------------------
    def prefill(self, tokens: np.ndarray) -> int:
        if self._sid is not None:        # re-prefill resets, as the old
            self._engine.release(self._sid)  # per-request engine did
        self._sid, tok = self._engine.add_sequence(tokens)
        return tok

    def decode_step(self, token: int) -> int:
        if self._sid is None:
            raise ValueError(
                "decode_step before prefill: call prefill(prompt) (or "
                "generate) to admit the sequence before decoding")
        return self._engine.decode_round({self._sid: token})[self._sid]

    def generate(self, prompt: np.ndarray, n_tokens: int) -> List[int]:
        tok = self.prefill(prompt)
        out = [tok]
        for _ in range(n_tokens - 1):
            tok = self.decode_step(tok)
            out.append(tok)
        return out
