"""LeoAM serving engine: real batched tiered decoding on a live model.

The engine exercises every paper mechanism with genuine data movement:
prefill populates the three-tier store (full replicas + abstracts on disk),
each decode round evaluates chunk importance on the host from abstracts
(IAKM tree or flat selection), fetches ONLY the selected chunks through the
transit codec, attends over the assembled working set on device, and appends
the new token's KV + abstract update.  An access-frequency table pins hot
chunks above the disk tier.  Traffic is audited by the TieredKVStore log —
benchmarks assert the LKA ratio r = α + 2/n' on it.

Batched decode round (the paper's large-batch speedup regime):

``BatchedLeoAMEngine`` decodes a whole batch of sequences per round against
ONE shared multi-sequence :class:`TieredKVStore` keyed by (seq, layer,
chunk).  Per layer the round issues

1. one ``chunk_bounds_gqa_matmul`` over the stacked per-request queries and
   (padded) abstracts — importance evaluation amortizes across the batch;
2. one batch-coalesced store gather (``fetch_chunks_batch``) so all disk
   promotion I/O of the round is a single fancy-indexed read per layer;
3. one jitted padded-working-set attention dispatch — ragged per-sequence
   selections are padded to the round's (bucketed) max and masked, which is
   FP-exact: padded keys score -inf, contribute exp(-inf)=0, and adding
   zeros never perturbs the f32 accumulators.

``LeoAMEngine`` is the single-sequence view: a thin wrapper over a B=1
batched engine preserving the original prefill/decode_step/generate API.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.adaptive import tree_select, flat_chunk_select
from repro.core.bounds import chunk_bounds_gqa_matmul
from repro.core.tiers import AccessTable
from repro.models import lm
from repro.models import attention as attn_mod
from repro.serving.offload import DEVICE, DISK, HOST, TieredKVStore


@dataclass
class EngineCfg:
    max_len: int = 1024
    gpu_chunk_frac: float = 0.15     # device-resident fraction
    cpu_chunk_frac: float = 0.45     # host tier fraction (rest -> disk)
    selection: str = "tree"          # tree | flat
    hot_frac: float = 0.05
    transit_codec: Optional[str] = "int4"
    sel_pad: int = 4                 # pad round working sets to a multiple
                                     # of this many chunks (bounds jit
                                     # recompiles; masking keeps it exact)


@dataclass
class StepStats:
    evaluations: int = 0
    fetched_chunks: int = 0
    fetched_bytes: float = 0.0
    abstract_bytes: float = 0.0


@dataclass
class _SeqState:
    """Host-side per-sequence decode state (model cache + bookkeeping)."""
    cache: Any                       # non-attention state + dense caches
    length: int
    access: AccessTable
    stats: List[StepStats] = field(default_factory=list)


@functools.partial(jax.jit, static_argnames=("attn_softcap",))
def _attend_workingset(q, kg, vg, k_new, v_new, valid, wo, *,
                       attn_softcap: Optional[float]):
    """One padded-working-set attention dispatch for the whole round.

    q: (B, 1, H, hd) model dtype; kg/vg: (B, nmax, chunk, Hkv, hd) store
    dtype; k_new/v_new: (B, 1, Hkv, hd); valid: (B, 1, 1, S) bool with
    S = nmax*chunk + 1; wo: (H*hd, d).  Padded / beyond-length positions are
    masked to -inf before the softmax partials, so ragged per-sequence
    selections cost nothing numerically.
    """
    from repro.core import sparse_attention as sa
    B, _, H, hd = q.shape
    _, n, c, Hkv, _ = kg.shape
    G = H // Hkv
    kg = kg.reshape(B, n * c, Hkv, hd)
    vg = vg.reshape(B, n * c, Hkv, hd)
    kg = jnp.concatenate([kg.astype(q.dtype), k_new.astype(q.dtype)], axis=1)
    vg = jnp.concatenate([vg.astype(q.dtype), v_new.astype(q.dtype)], axis=1)
    qs = q[:, 0] * (1.0 / math.sqrt(hd))
    kt = jnp.swapaxes(kg, 1, 2)
    vt = jnp.swapaxes(vg, 1, 2)
    scores = jnp.einsum("bkgd,bksd->bkgs",
                        qs.reshape(B, Hkv, G, hd).astype(jnp.float32),
                        kt.astype(jnp.float32))
    if attn_softcap is not None:
        scores = attn_softcap * jnp.tanh(scores / attn_softcap)
    part = sa._masked_softmax_partials(scores, vt, valid)
    out = sa._finish(part).astype(q.dtype).reshape(B, 1, H * hd)
    return out @ wo


class BatchedLeoAMEngine:
    """Batched tiered-decoding engine over a decoder-only model.

    Sequences join via :meth:`add_sequence` (per-request prefill, as in
    continuous batching), decode together via :meth:`decode_round`, and
    leave via :meth:`release` — the scheduler drives exactly this API.
    """

    def __init__(self, cfg: ArchConfig, params, ecfg: EngineCfg, *,
                 max_seqs: int = 1,
                 device_chunk_budget: Optional[int] = None):
        assert not cfg.is_encdec, "engine drives decoder-only models"
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.chunk = cfg.leoam.chunk_size
        self.n_chunks = ecfg.max_len // self.chunk
        self.max_seqs = max_seqs
        self.attn_layers = [i for i, k in enumerate(cfg.layer_kinds())
                            if k.startswith("attn")]
        budget = (device_chunk_budget * len(self.attn_layers)
                  if device_chunk_budget is not None else None)
        self.store = TieredKVStore(
            len(self.attn_layers), self.n_chunks, self.chunk,
            cfg.n_kv_heads, cfg.hd, n_seqs=max_seqs,
            transit_codec=ecfg.transit_codec, device_budget=budget)
        self.seqs: Dict[int, _SeqState] = {}
        self._free: List[int] = list(range(max_seqs - 1, -1, -1))

    @property
    def free_slots(self) -> int:
        """Sequence slots available for admission (scheduler-facing)."""
        return len(self._free)

    # ------------------------------------------------------------------
    # Sequence lifecycle
    # ------------------------------------------------------------------
    def add_sequence(self, tokens: np.ndarray) -> Tuple[int, int]:
        """Prefill one request into a free store slot.

        tokens: (S,).  Runs model prefill; K/V moves into the shared tier
        store under this sequence's slot.  Returns (seq id, first token).
        """
        assert self._free, "engine is at max_seqs capacity"
        cfg, ecfg = self.cfg, self.ecfg
        S = len(tokens)
        assert S < ecfg.max_len, (
            f"prompt length {S} needs < max_len={ecfg.max_len} "
            f"(decode appends past the prompt)")
        sid = self._free.pop()
        batch = {"tokens": jnp.asarray(np.asarray(tokens)[None], jnp.int32)}
        logits, cache = lm.prefill(self.params, cfg, batch,
                                   max_len=ecfg.max_len)
        cache = jax.tree.map(np.asarray, cache)

        n_gpu = max(1, int(self.n_chunks * ecfg.gpu_chunk_frac))
        n_cpu = max(1, int(self.n_chunks * ecfg.cpu_chunk_frac))
        placement = {}
        for c in range(self.n_chunks):
            placement[c] = DEVICE if c < n_gpu else (
                HOST if c < n_gpu + n_cpu else DISK)
        for li, layer in enumerate(self.attn_layers):
            k, v = self._layer_kv(cache, layer)
            early = layer < cfg.leoam.early_layers
            pl = dict(placement)
            if early:                   # early layers never go to disk (§4.3)
                pl = {c: (DEVICE if placement[c] == DEVICE else HOST)
                      for c in placement}
            self.store.ingest(li, k[0], v[0], pl, seq=sid)
        self.seqs[sid] = _SeqState(cache=cache, length=S,
                                   access=AccessTable(self.n_chunks))
        return sid, int(np.argmax(np.asarray(logits)[0]))

    def release(self, sid: int) -> None:
        """Retire a sequence and recycle its store slot."""
        self.store.clear_seq(sid)
        self.seqs.pop(sid, None)
        self._free.append(sid)

    def _layer_kv(self, cache, layer: int) -> Tuple[np.ndarray, np.ndarray]:
        """Pull (k, v) (B, S, Hkv, hd) for a layer out of a model cache."""
        pro_n = len(cache["prologue"])
        if layer < pro_n:
            c = cache["prologue"][layer]
            return np.asarray(c["k"]), np.asarray(c["v"])
        period = self.cfg.period()
        bi = (layer - pro_n) // period
        pi = (layer - pro_n) % period
        c = cache["body"][pi]
        return np.asarray(c["k"][bi]), np.asarray(c["v"][bi])

    # ------------------------------------------------------------------
    # Importance evaluation (batched LKA + per-sequence IAKM)
    # ------------------------------------------------------------------
    def _select_chunks_batched(self, li: int, layer: int, q: np.ndarray,
                               order: Sequence[int], lengths: np.ndarray
                               ) -> Tuple[Dict[int, List[int]],
                                          Dict[int, StepStats]]:
        """One bounds matmul over the stacked batch, then per-sequence
        adaptive selection (tree/IAKM or flat) on the host.

        q: (B, H, hd) un-scaled queries, rows matching ``order``.
        """
        cfg = self.cfg
        chunk = self.chunk
        n_valid = {sid: (int(L) + chunk - 1) // chunk
                   for sid, L in zip(order, lengths)}
        chunks_by_seq = {sid: list(range(n_valid[sid])) for sid in order}
        km, kn, abs_billed = self.store.read_abstracts_batch(li, chunks_by_seq)

        qj = jnp.asarray(q / math.sqrt(cfg.hd))              # (B, H, hd)
        ub, _ = chunk_bounds_gqa_matmul(qj, jnp.asarray(km), jnp.asarray(kn))
        ub = np.asarray(ub)                                  # (B, Hkv, ncmax)

        rate = (cfg.leoam.early_rate if layer < cfg.leoam.early_layers
                else cfg.leoam.importance_rate)
        sels: Dict[int, List[int]] = {}
        stats: Dict[int, StepStats] = {}
        for i, sid in enumerate(order):
            st = StepStats(abstract_bytes=abs_billed[sid])
            nv = n_valid[sid]
            length = int(lengths[i])
            scores = ub[i].max(0)[:nv]                       # (nv,)
            budget_tokens = max(chunk, int(math.ceil(length * rate)))
            per_tok = np.repeat(scores / chunk, chunk)[:length]
            if self.ecfg.selection == "tree":
                res = tree_select(per_tok, budget_tokens, chunk)
            else:
                res = flat_chunk_select(per_tok, budget_tokens, chunk)
            st.evaluations = res.evaluations
            sel = sorted({int(t) // chunk for t in res.selected})
            # sink + recent + hot chunks always included
            forced = set(range(cfg.leoam.sink_chunks))
            forced.update(range(max(0, nv - cfg.leoam.recent_chunks), nv))
            forced.update(
                int(c) for c in self.seqs[sid].access.hot_tokens(
                    self.ecfg.hot_frac) if c < nv)
            sels[sid] = sorted(set(sel) | forced)
            stats[sid] = st
        return sels, stats

    # ------------------------------------------------------------------
    # Decode round
    # ------------------------------------------------------------------
    def decode_round(self, tokens: Dict[int, int]) -> Dict[int, int]:
        """One token for every sequence in ``tokens`` ({seq id: last token}).

        Per attention layer: batched importance eval, one coalesced store
        gather, one padded attention dispatch.  Non-attention (recurrent /
        dense) layers keep their exact per-sequence decode path.  Returns
        {seq id: next token}.
        """
        cfg = self.cfg
        order = sorted(tokens)
        B = len(order)
        assert B > 0, "decode_round needs at least one sequence"
        states = [self.seqs[sid] for sid in order]
        lengths = np.array([s.length for s in states], np.int64)
        x = jnp.asarray([[tokens[sid]] for sid in order], jnp.int32)
        params = self.params
        h = jnp.take(params["embed"], x, axis=0)             # (B, 1, d)

        prologue, period, repeats = lm._layer_plan(cfg)
        round_stats = {sid: StepStats() for sid in order}
        li = 0
        new_caches = [{"prologue": list(s.cache["prologue"]),
                       "body": list(s.cache["body"])} for s in states]

        def run_attn(blk, kind, mlpk, h, layer_idx):
            nonlocal li
            hln = attn_mod.rms_norm(h, blk["ln1"], cfg.norm_eps)
            pos = jnp.asarray(lengths[:, None], jnp.int32)   # (B, 1)
            q, k_new, v_new = attn_mod._qkv(blk["core"], cfg, hln, pos)
            qn = np.asarray(q[:, 0])                         # (B, H, hd)
            sels, sel_stats = self._select_chunks_batched(
                li, layer_idx, qn, order, lengths)

            nmax = max(len(s) for s in sels.values())
            pad = max(1, self.ecfg.sel_pad)
            nmax = -(-nmax // pad) * pad
            kg, vg, _ = self.store.fetch_chunks_batch(li, sels, pad_to=nmax)

            # positions per padded slot; sentinel pads fail pos <= length
            S = nmax * self.chunk + 1
            pos_np = np.full((B, S), np.iinfo(np.int64).max, np.int64)
            for i, sid in enumerate(order):
                sel = np.asarray(sels[sid])
                p = (sel[:, None] * self.chunk
                     + np.arange(self.chunk)[None]).reshape(-1)
                pos_np[i, :len(p)] = p
                pos_np[i, -1] = lengths[i]
                st = round_stats[sid]
                st.evaluations += sel_stats[sid].evaluations
                st.fetched_chunks += len(sels[sid])
                st.abstract_bytes += sel_stats[sid].abstract_bytes
                self.seqs[sid].access.record(sel)
            valid = jnp.asarray(pos_np <= lengths[:, None])[:, None, None]

            y = _attend_workingset(q, jnp.asarray(kg), jnp.asarray(vg),
                                   k_new, v_new, valid, blk["core"]["wo"],
                                   attn_softcap=cfg.attn_softcap)
            kn_np = np.asarray(k_new[:, 0])
            vn_np = np.asarray(v_new[:, 0])
            for i, sid in enumerate(order):
                self.store.append_token(li, int(lengths[i]), kn_np[i],
                                        vn_np[i], seq=sid)
            li += 1
            h = h + y
            h, _ = lm._apply_mlp(blk, cfg, mlpk, h, None)
            return h

        def run_other(blk, kind, mlpk, h, layer_idx, cache_slices):
            """Recurrent/dense layers: exact per-sequence standard decode."""
            rows, new_slices = [], []
            for i, cs in enumerate(cache_slices):
                hi, c2, _ = lm._block_decode(blk, cfg, kind, mlpk, h[i:i + 1],
                                             cs, jnp.int32(int(lengths[i])),
                                             layer_idx=layer_idx,
                                             ctx=attn_mod.LOCAL_CTX)
                rows.append(hi)
                new_slices.append(c2)
            return jnp.concatenate(rows, axis=0), new_slices

        for pi, (idx, kind, mlpk) in enumerate(prologue):
            blk = params["prologue"][pi]
            if kind.startswith("attn"):
                h = run_attn(blk, kind, mlpk, h, idx)
            else:
                slices = [s.cache["prologue"][pi] for s in states]
                h, new_slices = run_other(blk, kind, mlpk, h, idx, slices)
                for i in range(B):
                    new_caches[i]["prologue"][pi] = new_slices[i]
        for r in range(repeats):
            for pi, (kind, mlpk) in enumerate(period):
                blk = jax.tree.map(lambda a: a[r], params["body"][pi])
                if kind.startswith("attn"):
                    h = run_attn(blk, kind, mlpk, h, 10 ** 6)
                    continue
                slices = [jax.tree.map(lambda a: a[r], s.cache["body"][pi])
                          for s in states]
                h, new_slices = run_other(blk, kind, mlpk, h, 10 ** 6, slices)
                for i in range(B):
                    def put(a, b):
                        a = np.asarray(a)
                        a[r] = np.asarray(b)
                        return a
                    new_caches[i]["body"][pi] = jax.tree.map(
                        put, new_caches[i]["body"][pi], new_slices[i])

        logits = np.asarray(lm._logits(params, cfg, h)[:, 0])  # (B, V)
        out: Dict[int, int] = {}
        for i, sid in enumerate(order):
            s = self.seqs[sid]
            s.cache = new_caches[i]
            s.length += 1
            s.stats.append(round_stats[sid])
            out[sid] = int(np.argmax(logits[i]))
        return out


class LeoAMEngine:
    """Single-sequence view: a B=1 wrapper over the batched engine,
    preserving the original prefill / decode_step / generate API."""

    def __init__(self, cfg: ArchConfig, params, ecfg: EngineCfg):
        self._engine = BatchedLeoAMEngine(cfg, params, ecfg, max_seqs=1)
        self._sid: Optional[int] = None

    # passthroughs used by benchmarks / scheduler / examples
    @property
    def cfg(self):
        return self._engine.cfg

    @property
    def ecfg(self):
        return self._engine.ecfg

    @property
    def chunk(self):
        return self._engine.chunk

    @property
    def n_chunks(self):
        return self._engine.n_chunks

    @property
    def attn_layers(self):
        return self._engine.attn_layers

    @property
    def store(self):
        return self._engine.store

    @property
    def length(self) -> int:
        return self._engine.seqs[self._sid].length if self._sid is not None \
            else 0

    @property
    def access(self):
        return self._engine.seqs[self._sid].access

    @property
    def stats(self) -> List[StepStats]:
        if self._sid is None:
            return []
        return self._engine.seqs[self._sid].stats

    # ------------------------------------------------------------------
    def prefill(self, tokens: np.ndarray) -> int:
        if self._sid is not None:        # re-prefill resets, as the old
            self._engine.release(self._sid)  # per-request engine did
        self._sid, tok = self._engine.add_sequence(tokens)
        return tok

    def decode_step(self, token: int) -> int:
        assert self._sid is not None, "prefill first"
        return self._engine.decode_round({self._sid: token})[self._sid]

    def generate(self, prompt: np.ndarray, n_tokens: int) -> List[int]:
        tok = self.prefill(prompt)
        out = [tok]
        for _ in range(n_tokens - 1):
            tok = self.decode_step(tok)
            out.append(tok)
        return out
