"""LeoAM serving engine: real tiered decoding on a live (CPU-sized) model.

The engine exercises every paper mechanism with genuine data movement:
prefill populates the three-tier store (full replicas + abstracts on disk),
each decode step evaluates chunk importance on the host from abstracts
(IAKM tree or flat selection), fetches ONLY the selected chunks through the
transit codec, attends over the assembled working set on device, and appends
the new token's KV + abstract update.  An access-frequency table pins hot
chunks above the disk tier.  Traffic is audited by the TieredKVStore log —
benchmarks assert the LKA ratio r = α + 2/n' on it.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.adaptive import tree_select, flat_chunk_select
from repro.core.bounds import chunk_bounds_gqa_matmul
from repro.core.tiers import AccessTable
from repro.models import lm
from repro.models import attention as attn_mod
from repro.serving.offload import DEVICE, DISK, HOST, TieredKVStore


@dataclass
class EngineCfg:
    max_len: int = 1024
    gpu_chunk_frac: float = 0.15     # device-resident fraction
    cpu_chunk_frac: float = 0.45     # host tier fraction (rest -> disk)
    selection: str = "tree"          # tree | flat
    hot_frac: float = 0.05
    transit_codec: Optional[str] = "int4"


@dataclass
class StepStats:
    evaluations: int = 0
    fetched_chunks: int = 0
    fetched_bytes: float = 0.0
    abstract_bytes: float = 0.0


class LeoAMEngine:
    """Single-sequence engine over a decoder-only smoke-size model."""

    def __init__(self, cfg: ArchConfig, params, ecfg: EngineCfg):
        assert not cfg.is_encdec, "engine drives decoder-only models"
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.chunk = cfg.leoam.chunk_size
        self.n_chunks = ecfg.max_len // self.chunk
        self.attn_layers = [i for i, k in enumerate(cfg.layer_kinds())
                            if k.startswith("attn")]
        self.store: Optional[TieredKVStore] = None
        self.cache = None               # non-attention state + dense caches
        self.length = 0
        self.access = AccessTable(self.n_chunks)
        self.stats: List[StepStats] = []
        self._decode_jit = jax.jit(
            lambda p, c, b, l: lm.decode_step(p, cfg, c, b, l))

    # ------------------------------------------------------------------
    def prefill(self, tokens: np.ndarray) -> int:
        """tokens: (S,).  Runs model prefill; K/V moves into the tier store."""
        cfg, ecfg = self.cfg, self.ecfg
        S = len(tokens)
        batch = {"tokens": jnp.asarray(tokens[None], jnp.int32)}
        logits, cache = lm.prefill(self.params, cfg, batch, max_len=ecfg.max_len)
        self.cache = jax.tree.map(np.asarray, cache)
        self.length = S

        self.store = TieredKVStore(
            len(self.attn_layers), self.n_chunks, self.chunk,
            cfg.n_kv_heads, cfg.hd, transit_codec=ecfg.transit_codec)
        n_gpu = max(1, int(self.n_chunks * ecfg.gpu_chunk_frac))
        n_cpu = max(1, int(self.n_chunks * ecfg.cpu_chunk_frac))
        placement = {}
        for c in range(self.n_chunks):
            placement[c] = DEVICE if c < n_gpu else (
                HOST if c < n_gpu + n_cpu else DISK)
        for li, layer in enumerate(self.attn_layers):
            k, v = self._layer_kv(layer)
            early = layer < cfg.leoam.early_layers
            pl = dict(placement)
            if early:                   # early layers never go to disk (§4.3)
                pl = {c: (DEVICE if placement[c] == DEVICE else HOST)
                      for c in placement}
            self.store.ingest(li, k[0], v[0], pl)
        return int(np.argmax(np.asarray(logits)[0]))

    def _layer_kv(self, layer: int) -> Tuple[np.ndarray, np.ndarray]:
        """Pull (k, v) (B, S, Hkv, hd) for a layer out of the model cache."""
        pro_n = len(self.cache["prologue"])
        if layer < pro_n:
            c = self.cache["prologue"][layer]
            return np.asarray(c["k"]), np.asarray(c["v"])
        period = self.cfg.period()
        bi = (layer - pro_n) // period
        pi = (layer - pro_n) % period
        c = self.cache["body"][pi]
        return np.asarray(c["k"][bi]), np.asarray(c["v"][bi])

    # ------------------------------------------------------------------
    def _select_chunks(self, li: int, layer: int, q: np.ndarray
                       ) -> Tuple[List[int], StepStats]:
        """Host-side importance evaluation from abstracts (LKA + IAKM)."""
        cfg = self.cfg
        st = StepStats()
        n_valid = (self.length + self.chunk - 1) // self.chunk
        chunks = list(range(n_valid))
        log0 = self.store.log.total(kind="abstract")
        kmax, kmin = self.store.read_abstracts(li, chunks)   # (n, Hkv, hd)
        st.abstract_bytes = self.store.log.total(kind="abstract") - log0

        qj = jnp.asarray(q[None] / math.sqrt(cfg.hd))        # (1, H, hd)
        ub, _ = chunk_bounds_gqa_matmul(
            qj, jnp.asarray(kmax[None]), jnp.asarray(kmin[None]))
        scores = np.asarray(ub).max(1)[0]                    # (n_chunks,)

        rate = (cfg.leoam.early_rate if layer < cfg.leoam.early_layers
                else cfg.leoam.importance_rate)
        budget_tokens = max(self.chunk,
                            int(math.ceil(self.length * rate)))
        per_tok = np.repeat(scores / self.chunk, self.chunk)[: self.length]
        if self.ecfg.selection == "tree":
            res = tree_select(per_tok, budget_tokens, self.chunk)
        else:
            res = flat_chunk_select(per_tok, budget_tokens, self.chunk)
        st.evaluations = res.evaluations
        sel = sorted({int(t) // self.chunk for t in res.selected})
        # sink + recent + hot chunks always included
        forced = set(range(cfg.leoam.sink_chunks))
        forced.update(range(max(0, n_valid - cfg.leoam.recent_chunks), n_valid))
        forced.update(int(c) for c in self.access.hot_tokens(self.ecfg.hot_frac)
                      if c < n_valid)
        sel = sorted(set(sel) | forced)
        return sel, st

    def decode_step(self, token: int) -> int:
        """One token: select → fetch → attend on the working set."""
        cfg = self.cfg
        x = jnp.asarray([[token]], jnp.int32)
        # embed + per-layer manual pass mirroring lm.decode_step, but with
        # attention served from the tier store's working set
        params = self.params
        h = jnp.take(params["embed"], x, axis=0)
        aux_len = jnp.int32(self.length)

        prologue, period, repeats = lm._layer_plan(cfg)
        stats_this = StepStats()
        li = 0
        new_states = {"prologue": list(self.cache["prologue"]),
                      "body": list(self.cache["body"])}

        def run_block(blk, kind, mlpk, h, layer_idx, cache_slice):
            nonlocal li, stats_this
            if kind.startswith("attn"):
                hln = attn_mod.rms_norm(h, blk["ln1"], cfg.norm_eps)
                q, k_new, v_new = attn_mod._qkv(
                    blk["core"], cfg, hln,
                    jnp.full((1, 1), self.length, jnp.int32))
                qn = np.asarray(q[0, 0])                       # (H, hd)
                sel, st = self._select_chunks(li, layer_idx, qn)
                kg, vg = self.store.fetch_chunks(li, sel)      # (n, c, Hkv, hd)
                stats_this.evaluations += st.evaluations
                stats_this.fetched_chunks += len(sel)
                stats_this.abstract_bytes += st.abstract_bytes
                self.access.record(np.asarray(sel))
                y = self._attend(blk, cfg, kind, h, q, kg, vg, sel,
                                 k_new, v_new)
                self.store.append_token(li, self.length,
                                        np.asarray(k_new[0, 0]),
                                        np.asarray(v_new[0, 0]))
                li += 1
                h = h + y
                h, _ = lm._apply_mlp(blk, cfg, mlpk, h, None)
                return h, cache_slice
            # recurrent/dense layers go through the standard decode path
            h, c2, _ = lm._block_decode(blk, cfg, kind, mlpk, h,
                                        cache_slice, aux_len,
                                        layer_idx=layer_idx,
                                        ctx=attn_mod.LOCAL_CTX)
            return h, c2

        for i, (idx, kind, mlpk) in enumerate(prologue):
            h, c2 = run_block(params["prologue"][i], kind, mlpk, h, idx,
                              self.cache["prologue"][i])
            new_states["prologue"][i] = c2
        for r in range(repeats):
            for pi, (kind, mlpk) in enumerate(period):
                blk = jax.tree.map(lambda a: a[r], params["body"][pi])
                cs = jax.tree.map(lambda a: a[r], self.cache["body"][pi])
                h, c2 = run_block(blk, kind, mlpk, h, 10**6, cs)
                if c2 is not cs:
                    def put(a, b):
                        a = np.asarray(a)
                        a[r] = np.asarray(b)
                        return a
                    new_states["body"][pi] = jax.tree.map(
                        put, new_states["body"][pi], c2)

        logits = lm._logits(params, cfg, h)[:, 0]
        self.cache = new_states
        self.length += 1
        self.stats.append(stats_this)
        return int(np.argmax(np.asarray(logits)[0]))

    def _attend(self, blk, cfg, kind, h, q, kg, vg, sel, k_new, v_new):
        """Attention over the fetched working set + the new token."""
        n, c, Hkv, hd = kg.shape
        kg = jnp.asarray(kg.reshape(1, n * c, Hkv, hd), h.dtype)
        vg = jnp.asarray(vg.reshape(1, n * c, Hkv, hd), h.dtype)
        kg = jnp.concatenate([kg, k_new.astype(h.dtype)], axis=1)
        vg = jnp.concatenate([vg, v_new.astype(h.dtype)], axis=1)
        pos = np.concatenate([
            (np.asarray(sel)[:, None] * self.chunk
             + np.arange(self.chunk)[None]).reshape(-1),
            [self.length]])
        valid = jnp.asarray(pos <= self.length)[None, None, None]
        from repro.core import sparse_attention as sa
        B, _, H, _ = q.shape
        qs = q[:, 0] * (1.0 / math.sqrt(hd))
        G = H // Hkv
        kt = jnp.swapaxes(kg, 1, 2)
        vt = jnp.swapaxes(vg, 1, 2)
        scores = jnp.einsum("bkgd,bksd->bkgs",
                            qs.reshape(B, Hkv, G, hd).astype(jnp.float32),
                            kt.astype(jnp.float32))
        if cfg.attn_softcap is not None:
            scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)
        part = sa._masked_softmax_partials(scores, vt, valid)
        out = sa._finish(part).astype(h.dtype).reshape(B, 1, H * hd)
        return out @ blk["core"]["wo"]

    # ------------------------------------------------------------------
    def generate(self, prompt: np.ndarray, n_tokens: int) -> List[int]:
        tok = self.prefill(prompt)
        out = [tok]
        for _ in range(n_tokens - 1):
            tok = self.decode_step(tok)
            out.append(tok)
        return out
