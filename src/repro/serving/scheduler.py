"""Continuous-batching request scheduler for the LeoAM serving engine.

Admission is KV-budget-aware across the three tiers: a request is admitted
when its max_len worth of chunks fits the configured device+host budget
(disk replicas are assumed plentiful, per the paper).  Decode proceeds in
rounds over all active requests; finished requests retire immediately and
the queue backfills — the standard continuous-batching loop.

Two drive modes:

* **batched** (pass ``engine=BatchedLeoAMEngine(...)``): every round is ONE
  ``decode_round`` over all active sequences against the shared multi-tier
  store — importance evaluation, promotion I/O and the working-set
  attention dispatch amortize across the batch (the paper's large-batch
  speedup regime).
* **legacy** (pass ``make_engine=...``): one single-sequence engine per
  request, stepped in a Python loop — kept for A/B benchmarking and
  backward compatibility.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    eos_id: Optional[int] = None
    out: List[int] = field(default_factory=list)
    t_submit: float = field(default_factory=time.perf_counter)
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def done(self) -> bool:
        if self.out and self.eos_id is not None and self.out[-1] == self.eos_id:
            return True
        return len(self.out) >= self.max_new


@dataclass
class SchedulerCfg:
    max_active: int = 4
    device_chunk_budget: int = 512     # total device-resident chunks
    chunk: int = 64


class ContinuousBatcher:
    """Continuous batching over LeoAM engines.

    ``active`` maps rid -> (request, handle, last token); ``handle`` is the
    per-request engine in legacy mode or the shared engine's sequence id in
    batched mode.
    """

    def __init__(self, make_engine: Optional[Callable[[], "object"]] = None,
                 cfg: Optional[SchedulerCfg] = None, *, engine=None):
        assert (make_engine is None) != (engine is None), \
            "pass exactly one of make_engine (legacy) or engine (batched)"
        self.make_engine = make_engine
        self.engine = engine
        self.cfg = cfg or SchedulerCfg()
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, tuple] = {}
        self.finished: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _chunks_needed(self, req: Request) -> int:
        return (len(req.prompt) + req.max_new + self.cfg.chunk - 1) \
            // self.cfg.chunk

    def _device_chunks_used(self) -> int:
        return sum(self._chunks_needed(r) for r, _, _ in self.active.values())

    def _can_admit(self) -> bool:
        if not self.queue or len(self.active) >= self.cfg.max_active:
            return False
        if (self._device_chunks_used() + self._chunks_needed(self.queue[0])
                > self.cfg.device_chunk_budget):
            return False
        return self.engine is None or self.engine.free_slots > 0

    def _admit(self) -> None:
        while self._can_admit():
            req = self.queue.popleft()
            if self.engine is not None:
                handle, tok = self.engine.add_sequence(req.prompt)
            else:
                handle = self.make_engine()
                tok = handle.prefill(req.prompt)
            req.t_first = time.perf_counter()
            req.out.append(tok)
            self.active[req.rid] = (req, handle, tok)

    def _retire(self, rids: List[int]) -> None:
        for rid in rids:
            req, handle, _ = self.active.pop(rid)
            req.t_done = time.perf_counter()
            self.finished.append(req)
            if self.engine is not None:
                self.engine.release(handle)
            elif hasattr(handle, "store") and handle.store is not None:
                handle.store.close()

    def step(self) -> int:
        """One decode round over all active requests; returns #active."""
        self._admit()
        retired = [rid for rid, (req, _, _) in self.active.items() if req.done]
        live = {rid: v for rid, v in self.active.items()
                if rid not in retired}
        if self.engine is not None and live:
            # ONE batched decode round for every live sequence
            toks = self.engine.decode_round(
                {sid: tok for (_, sid, tok) in live.values()})
            for rid, (req, sid, _) in live.items():
                tok = toks[sid]
                req.out.append(tok)
                self.active[rid] = (req, sid, tok)
                if req.done:
                    retired.append(rid)
        else:
            for rid, (req, eng, tok) in list(live.items()):
                tok = eng.decode_step(tok)
                req.out.append(tok)
                self.active[rid] = (req, eng, tok)
                if req.done:
                    retired.append(rid)
        self._retire(retired)
        self._admit()
        return len(self.active)

    def run(self, max_rounds: int = 10_000) -> List[Request]:
        rounds = 0
        while (self.queue or self.active) and rounds < max_rounds:
            self.step()
            rounds += 1
        return self.finished

    def stats(self) -> Dict[str, float]:
        if not self.finished:
            return {}
        ttft = [r.t_first - r.t_submit for r in self.finished]
        lat = [r.t_done - r.t_submit for r in self.finished]
        toks = sum(len(r.out) for r in self.finished)
        span = max(r.t_done for r in self.finished) - min(
            r.t_submit for r in self.finished)
        return {"requests": len(self.finished),
                "mean_ttft_s": float(np.mean(ttft)),
                "mean_latency_s": float(np.mean(lat)),
                "throughput_tok_s": toks / max(span, 1e-9)}
