"""Continuous-batching request scheduler for the LeoAM serving engine.

Admission is KV-budget-aware across the three tiers.  Two admission
policies:

* **analytic** (legacy / non-pooled engines): a request is admitted when
  its max_len worth of chunks fits the configured device budget — the
  worst-case estimate, which leaves most of the device slab idle;
* **pool-aware** (batched engine with a device chunk pool): admission is
  driven off the engine's LIVE ``pool_stats()`` — a request is charged its
  worst-case per-ROUND working set (``engine.admission_need_chunks``,
  selection budget + forced sink/recent/hot chunks per layer) against the
  actual pool slot count, optionally gated on the pool hit rate so a
  thrashing pool pauses admission.  Per-round working sets are far below
  max_len chunk counts, so the same device budget serves more concurrent
  sequences.

Decode proceeds in rounds over all active requests; finished requests
retire immediately and the queue backfills — the standard continuous-
batching loop.  With ``overlap_admission=True`` (batched mode) admission
runs UNDER decode: queued requests prefill on the engine's admission
worker while the active batch keeps decoding, and join the next round
after their prefill future resolves — TTFT for queued requests drops by
roughly the decode time they no longer wait out.

With ``chunked_admission=True`` admission instead runs CHUNKED on the
decode thread: the engine's resumable chunked prefill advances by at most
``prefill_round_tokens`` prompt tokens between consecutive decode rounds,
so the decode-latency spike a very long prompt causes while admitting is
bounded by the budget instead of its whole prefill.  With
``adaptive_prefill_budget=True`` that budget is re-derived every round
from the measured decode-round and chunk-step EWMAs through
``pipeline.chunked_admission_model`` — the largest budget whose predicted
round gap stays within ``target_stall_frac`` of an idle round — so the
stall bound tracks batch composition; the derived figure is exported by
:meth:`ContinuousBatcher.stats` as ``prefill_round_tokens``.  Either overlap mode
can be paced (``pace_admission=True``): the scheduler EWMAs decode round
time, keeps an idle baseline from rounds with no admission in flight, and
holds admission work while the running EWMA exceeds the baseline by more
than ``max_round_inflation`` — overlap only spends host cycles when the
host has headroom.  The gate state is exported by :meth:`stats`.

Two drive modes:

* **batched** (pass ``engine=BatchedLeoAMEngine(...)``): every round is ONE
  ``decode_round`` over all active sequences against the shared multi-tier
  store — importance evaluation, promotion I/O and the working-set
  attention dispatch amortize across the batch (the paper's large-batch
  speedup regime).
* **legacy** (pass ``make_engine=...``): one single-sequence engine per
  request, stepped in a Python loop — kept for A/B benchmarking and
  backward compatibility.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pipeline import chunked_admission_model
from repro.serving.faults import AdmissionError, RejectedOverload
from repro.serving.sanitizer import any_thread, decode_thread_only

# pressure watermark states (mirrored by serving.overload — the monitor
# lives there; the string values are the contract, so the scheduler never
# imports overload.py and LoadHarness can import the scheduler freely)
_GREEN, _YELLOW, _RED = "green", "yellow", "red"


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    eos_id: Optional[int] = None
    deadline_s: Optional[float] = None  # wall-clock budget from submit; an
                                       # expired request is cancelled at
                                       # whatever lifecycle stage it is in
                                       # (queued / mid-admission / decoding)
    out: List[int] = field(default_factory=list)
    t_submit: float = field(default_factory=time.perf_counter)
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    error: Optional[str] = None        # terminal failure/cancellation
                                       # reason (None = completed normally)
    degraded: bool = False             # served with degraded numerics (a
                                       # corrupt sidecar fell back to the
                                       # lossless fp16 replica)
    sid: Optional[int] = None          # engine slot the request decoded in
                                       # (observability: lets audits map
                                       # store/fault events back to the
                                       # request; slots are reused)
    priority: int = 0                  # scheduling class (higher = more
                                       # important): overload preemption
                                       # picks victims lowest-class-first
                                       # and red-pressure shedding drops
                                       # lowest-class-newest-first
    t_admit: Optional[float] = None    # when the request left the queue
                                       # (queue wait = t_admit - t_submit)
    t_suspend: Optional[float] = None  # set while preempted (suspended)
    suspended_s: float = 0.0           # total time spent suspended so far
    rejected_overload: Optional[RejectedOverload] = None
                                       # structured shed result (red
                                       # pressure); error carries the text

    @property
    def done(self) -> bool:
        if self.out and self.eos_id is not None and self.out[-1] == self.eos_id:
            return True
        return len(self.out) >= self.max_new

    @property
    def paused_s(self) -> float:
        """Wall time this request has spent preempted (suspended) — its
        deadline clock stops while swapped out (I7: preemption must not
        silently consume the victim's latency budget)."""
        p = self.suspended_s
        if self.t_suspend is not None:
            p += time.perf_counter() - self.t_suspend
        return p

    @property
    def expired(self) -> bool:
        return (self.deadline_s is not None
                and time.perf_counter() - self.t_submit - self.paused_s
                > self.deadline_s)


@dataclass
class SchedulerCfg:
    max_active: int = 4
    device_chunk_budget: int = 512     # total device-resident chunks
    chunk: int = 64
    overlap_admission: bool = False    # admit under decode: prefill queued
                                       # requests on the engine's admission
                                       # worker while rounds run
    prefill_ahead: int = 1             # async admissions may run this far
                                       # ahead of a free decode slot (the
                                       # engine needs max_active +
                                       # prefill_ahead sequence slots); a
                                       # retired slot is backfilled by an
                                       # ALREADY-PREFILLED request, so the
                                       # batch never starves while a
                                       # prefill runs
    pool_aware: bool = True            # drive admission off live
                                       # engine.pool_stats() when the
                                       # engine has a device chunk pool
    min_pool_hit_rate: float = 0.0     # hold admission while the warm pool
                                       # hit rate sits below this (0 = off)
    hit_rate_warmup: int = 64          # pool lookups before the gate arms
    chunked_admission: bool = False    # admit via the engine's resumable
                                       # chunked prefill: chunk steps run
                                       # BETWEEN decode rounds under a
                                       # per-round token budget, so a long
                                       # prompt never stalls the round
                                       # loop for its whole prefill
    prefill_round_tokens: int = 64     # chunked mode: max prompt tokens
                                       # advanced between two decode rounds
                                       # (the decode-stall bound); lifted
                                       # when nothing is decoding
    adaptive_prefill_budget: bool = False
                                       # derive the per-round prefill token
                                       # budget each round from the
                                       # measured decode-round EWMA and the
                                       # measured chunk-step time, via
                                       # pipeline.chunked_admission_model:
                                       # the largest budget whose predicted
                                       # max round gap stays within
                                       # target_stall_frac of an idle
                                       # round — so the stall bound holds
                                       # as batch composition changes
                                       # instead of being a static guess
    target_stall_frac: float = 0.5     # adaptive mode: tolerated round-gap
                                       # inflation (gap <= idle_round *
                                       # (1 + frac)) the derived budget
                                       # must respect
    pace_admission: bool = False       # contention-aware pacing: hold
                                       # admission work (async prefills /
                                       # chunk steps) while the decode
                                       # round EWMA sits above the idle
                                       # baseline by max_round_inflation
    max_round_inflation: float = 0.5   # tolerated round-time inflation
                                       # before the pacing gate closes
    ewma_alpha: float = 0.25           # round-time EWMA smoothing
    max_queue: int = 0                 # bounded admission-queue
                                       # backpressure: submit() rejects
                                       # (returns False, req.error set)
                                       # once this many requests wait;
                                       # 0 = unbounded (legacy behavior)
    aging_s: float = 5.0               # anti-starvation clock: a suspended
                                       # request gains one effective
                                       # priority class per aging_s
                                       # seconds preempted; once it
                                       # out-ranks the weakest active
                                       # victim it swaps back in even
                                       # under sustained yellow pressure
                                       # (0 disables aging)
    credit_prefix: bool = True         # when the engine runs the shared-
                                       # prefix cache, credit a request's
                                       # predicted warm span (chunks whose
                                       # device-pool slot already exists)
                                       # against its device-chunk charge —
                                       # warm requests don't re-buy slots
                                       # their prefix already owns


class ContinuousBatcher:
    """Continuous batching over LeoAM engines.

    ``active`` maps rid -> (request, handle, last token); ``handle`` is the
    per-request engine in legacy mode or the shared engine's sequence id in
    batched mode.  ``_pending`` holds (request, future) pairs admitted
    asynchronously whose prefill has not resolved yet; ``_ready`` holds
    resolved admissions waiting for a free decode slot (their first token
    already exists — TTFT stops there).  Both own engine slots and count
    against every admission budget.
    """

    def __init__(self, make_engine: Optional[Callable[[], "object"]] = None,
                 cfg: Optional[SchedulerCfg] = None, *, engine=None,
                 monitor=None):
        if (make_engine is None) == (engine is None):
            raise ValueError(
                "pass exactly one of make_engine= (legacy per-request "
                "engines) or engine= (shared batched engine) — got "
                f"make_engine={make_engine!r}, engine={engine!r}")
        self.make_engine = make_engine
        self.engine = engine
        self.cfg = cfg or SchedulerCfg()
        # optional resource-pressure monitor (serving.overload): any object
        # with sample(queue_depth) -> (state, reasons) where state is
        # "green" / "yellow" / "red".  None = no overload control (legacy)
        self.monitor = monitor
        if monitor is not None and engine is None:
            raise ValueError(
                "overload control (monitor=) needs the shared batched "
                "engine: legacy per-request engines have no "
                "suspend/resume surface")
        if self.cfg.chunked_admission and self.cfg.overlap_admission:
            raise ValueError(
                "SchedulerCfg(chunked_admission=True, "
                "overlap_admission=True): chunked and overlapped "
                "admission are exclusive modes — chunked admission "
                "already interleaves prefill chunks with decode rounds "
                "on the decode thread; pick one")
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, tuple] = {}
        self._pending: List[Tuple[Request, "object"]] = []
        self._ready: List[Tuple[Request, "object", int]] = []
        # in-flight chunked admissions (own an engine slot; advanced
        # between decode rounds under the per-round token budget)
        self._chunked: List[Tuple[Request, "object"]] = []
        self.finished: List[Request] = []
        # contention-aware admission pacing state (EWMA of decode round
        # time vs the idle baseline measured with no admission in flight)
        self._round_ewma: Optional[float] = None
        self._idle_ewma: Optional[float] = None
        self._gate_open = True
        self._gated_rounds = 0
        # adaptive prefill budget state: EWMA of one chunk step's wall
        # time + the tokens it advanced, and the budget derived last round.
        # The very first chunk step is discarded (jit-compile time, seconds
        # vs ~ms steady-state — seeding the EWMA with it would pin the
        # derived budget at one chunk for tens of rounds after a cold start)
        self._chunk_ewma: Optional[float] = None
        self._chunk_steps = 0
        self._chunk_tokens: Optional[int] = None
        self._derived_budget: Optional[int] = None
        # per-rid predicted warm-prefix device-chunk credit, frozen at
        # first sight so a request's charge stays stable across rounds
        # even as the shared-prefix index churns underneath it
        self._prefix_credit: Dict[int, int] = {}
        # fault-domain request accounting: rejected submissions (bounded
        # queue) and cancelled requests (deadline expiry) — surfaced
        # through stats() next to the engine/store fault counters
        self.rejected: List[Request] = []
        self._requests_rejected = 0
        self._requests_cancelled = 0
        # overload-control state: preempted requests parked with their
        # engine slot ({rid: (req, sid, last tok)}); the admission pause
        # flag (resource yellow/red closes it); watermark observability
        self._suspended: Dict[int, Tuple[Request, "object", int]] = {}
        self._admission_paused = False
        self._pressure_state = _GREEN
        self._pressure_rounds = {_GREEN: 0, _YELLOW: 0, _RED: 0}
        self._requests_submitted = 0
        self._suspensions = 0
        self._resumes = 0

    @any_thread
    def submit(self, req: Request) -> bool:
        """Enqueue a request; returns False (with ``req.error`` set) when
        the bounded queue is full — structured backpressure instead of an
        unbounded deque under overload.  The length check and append are
        not atomic together, so the bound is approximate by at most the
        number of concurrent producers (each submit adds one)."""
        self._requests_submitted += 1
        if self.cfg.max_queue > 0 and len(self.queue) >= self.cfg.max_queue:
            req.error = (f"rejected: admission queue at "
                         f"max_queue={self.cfg.max_queue}")
            req.t_done = time.perf_counter()
            self.rejected.append(req)
            self._requests_rejected += 1
            return False
        # deque.append is atomic; any producer thread may enqueue
        self.queue.append(req)
        return True

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------
    def _pool_mode(self) -> bool:
        return (self.cfg.pool_aware and self.engine is not None
                and getattr(getattr(self.engine, "store", None),
                            "use_pool", False)
                and hasattr(self.engine, "pool_stats"))

    def _chunks_needed(self, req: Request) -> int:
        return (len(req.prompt) + req.max_new + self.cfg.chunk - 1) \
            // self.cfg.chunk

    def _need(self, req: Request) -> int:
        """Device chunks a request is charged at admission: its per-round
        working set in pool mode, its analytic max_len worst case else.
        With the shared-prefix cache on, chunks whose device slot the
        warm prefix already holds are credited back (floor of 1 chunk —
        even a full hit recomputes its last prompt chunk)."""
        if self._pool_mode():
            need = self.engine.admission_need_chunks(len(req.prompt),
                                                     req.max_new)
            need -= self._device_prefix_credit(req, need)
            return need
        return self._chunks_needed(req)

    def _device_prefix_credit(self, req: Request, need: int) -> int:
        """Predicted warm-span device chunks, memoized per rid."""
        store = getattr(self.engine, "store", None)
        if (not self.cfg.credit_prefix or store is None
                or getattr(store, "_prefix", None) is None):
            return 0
        if req.rid not in self._prefix_credit:
            probe = store.prefix_probe(req.prompt)
            self._prefix_credit[req.rid] = int(probe["device_hits"])
        return min(self._prefix_credit[req.rid], max(need - 1, 0))

    def _device_chunks_used(self) -> int:
        reqs = [r for r, _, _ in self.active.values()] \
            + [r for r, _ in self._pending] \
            + [r for r, _ in self._chunked] \
            + [r for r, _, _ in self._ready]
        return sum(self._need(r) for r in reqs)

    def _overlap(self) -> bool:
        return (self.cfg.overlap_admission and self.engine is not None
                and hasattr(self.engine, "add_sequence_async"))

    def _chunked_mode(self) -> bool:
        return (self.cfg.chunked_admission and self.engine is not None
                and hasattr(self.engine, "begin_admission"))

    def _can_admit(self) -> bool:
        if self._admission_paused:
            return False               # resource pressure: hold admission
        # async/chunked admissions may run prefill_ahead past the decode
        # slots: the ready queue backfills a retiring slot with zero
        # prefill stall
        ahead = self._overlap() or self._chunked_mode()
        cap = self.cfg.max_active + (self.cfg.prefill_ahead if ahead else 0)
        if not self.queue or \
                len(self.active) + len(self._pending) + len(self._chunked) \
                + len(self._ready) >= cap:
            return False
        if self._pool_mode():
            ps = self.engine.pool_stats()
            budget = ps["slots"] or self.cfg.device_chunk_budget
            looks = ps["hits"] + ps["misses"]
            if (self.cfg.min_pool_hit_rate > 0.0 and self.active
                    and looks >= self.cfg.hit_rate_warmup
                    and ps["hit_rate"] < self.cfg.min_pool_hit_rate):
                return False           # pool is thrashing: hold admission
        else:
            budget = self.cfg.device_chunk_budget
        if self._device_chunks_used() + self._need(self.queue[0]) > budget:
            return False
        return self.engine is None or self.engine.free_slots > 0

    def _admit(self) -> None:
        overlap = self._overlap()
        chunked = self._chunked_mode()
        while self._can_admit():
            if (self.cfg.pace_admission and not self._gate_open
                    and self.active and (overlap or chunked)):
                break                  # host has no headroom: hold overlap
            req = self.queue.popleft()
            req.t_admit = time.perf_counter()
            if chunked:
                adm = self.engine.begin_admission(req.prompt)
                self._chunked.append((req, adm))
                continue
            if overlap:
                fut = self.engine.add_sequence_async(req.prompt)
                self._pending.append((req, fut))
                continue
            if self.engine is not None:
                handle, tok = self.engine.add_sequence(req.prompt)
                req.sid = handle
            else:
                handle = self.make_engine()
                tok = handle.prefill(req.prompt)
            req.t_first = time.perf_counter()
            req.out.append(tok)
            self.active[req.rid] = (req, handle, tok)

    def _activate_ready(self) -> None:
        while self._ready and len(self.active) < self.cfg.max_active:
            req, sid, tok = self._ready.pop(0)
            self.active[req.rid] = (req, sid, tok)

    def _collect_admitted(self, block: bool = False) -> None:
        """Resolve async admissions (TTFT stops when the prefill future
        lands) and activate ready requests as decode slots allow.
        ``block`` waits for at least the first pending future — used when
        nothing is decoding, so the loop always makes progress."""
        still = []
        for i, (req, fut) in enumerate(self._pending):
            if fut.done() or (block and i == 0 and not self._ready):
                try:
                    sid, tok = fut.result()
                except AdmissionError as e:
                    # the admission worker failed mid-prefill: reclaim
                    # exactly that slot (drain its write-behind futures,
                    # release pool/arena holds) and fail just this request
                    self.engine.abort_admission(e.sid)
                    req.error = f"admission failed: {e.cause!r}"
                    req.t_done = time.perf_counter()
                    self._prefix_credit.pop(req.rid, None)
                    self.finished.append(req)
                    continue
                req.sid = sid
                req.t_first = time.perf_counter()
                req.out.append(tok)
                self._ready.append((req, sid, tok))
            else:
                still.append((req, fut))
        self._pending = still
        self._activate_ready()

    def _prefill_budget(self) -> int:
        """Per-round prefill token budget.  Static by default; with
        ``adaptive_prefill_budget`` it is re-derived EVERY round from the
        measured chunk-step and idle-round EWMAs through
        :func:`pipeline.chunked_admission_model`: the largest
        chunks-per-round whose predicted max round gap (idle round + k
        chunk steps) stays within ``target_stall_frac`` of an idle round —
        the stall bound then holds as batch composition (and therefore
        round time) changes, instead of trusting a static token guess."""
        cfg = self.cfg
        if not cfg.adaptive_prefill_budget:
            self._derived_budget = cfg.prefill_round_tokens
            return cfg.prefill_round_tokens
        base = self._idle_ewma if self._idle_ewma is not None \
            else self._round_ewma
        if base is None or self._chunk_ewma is None or not self._chunk_tokens:
            # no measurements yet (first admission / first rounds): fall
            # back to the configured static budget until EWMAs exist
            self._derived_budget = cfg.prefill_round_tokens
            return cfg.prefill_round_tokens
        chunk_s = max(self._chunk_ewma, 1e-9)
        k = max(1, int(cfg.target_stall_frac * base / chunk_s))
        while k > 1 and chunked_admission_model(
                chunk_s, k, base, k)["max_round_gap_chunked_s"] \
                > base * (1.0 + cfg.target_stall_frac):
            k -= 1
        self._derived_budget = k * self._chunk_tokens
        return self._derived_budget

    def _advance_chunked(self) -> None:
        """Advance in-flight chunked admissions under the per-round prefill
        token budget — decode rounds run between chunk steps, so the max
        decode stall a long prompt causes is bounded by the budget.  With
        no active decode the budget lifts (nothing to stall) but only one
        admission drains, so arrivals keep joining in order."""
        if not self._chunked:
            return
        if self.cfg.pace_admission and not self._gate_open and self.active:
            self._gated_rounds += 1
            return
        budget = self._prefill_budget() if self.active else None
        while self._chunked:
            if budget is not None and budget <= 0:
                break
            req, adm = self._chunked[0]
            t0 = time.perf_counter()
            did = adm.step()
            if did:
                dt = time.perf_counter() - t0
                self._chunk_steps += 1
                if self._chunk_steps > 1:      # step 1 is the jit compile
                    a = self.cfg.ewma_alpha
                    self._chunk_ewma = dt if self._chunk_ewma is None else \
                        (1 - a) * self._chunk_ewma + a * dt
                # full chunk size (the final chunk of a prompt is shorter)
                self._chunk_tokens = max(self._chunk_tokens or 0, did)
            if budget is not None:
                budget -= did
            if adm.done:
                self._chunked.pop(0)
                sid, tok = adm.result
                req.sid = sid
                req.t_first = time.perf_counter()
                req.out.append(tok)
                self._ready.append((req, sid, tok))
                if budget is None:
                    break              # drained one admission; that's
                                       # enough progress for an idle loop
        self._activate_ready()

    def _note_round(self, dt: float, admission_active: bool) -> None:
        """Feed one decode round's wall time into the pacing EWMAs and
        update the gate: rounds with no admission in flight refresh the
        idle baseline; the gate closes while the running EWMA exceeds the
        baseline by more than ``max_round_inflation``."""
        a = self.cfg.ewma_alpha
        self._round_ewma = dt if self._round_ewma is None else \
            (1 - a) * self._round_ewma + a * dt
        if not admission_active:
            self._idle_ewma = dt if self._idle_ewma is None else \
                (1 - a) * self._idle_ewma + a * dt
        if self.cfg.pace_admission:
            if self._idle_ewma is None:
                self._gate_open = True
            else:
                self._gate_open = (
                    self._round_ewma
                    <= self._idle_ewma * (1.0 + self.cfg.max_round_inflation))

    # ------------------------------------------------------------------
    # Overload control: watermark policy, preemption, shedding
    # ------------------------------------------------------------------
    def _eff_priority(self, req: Request, now: float) -> float:
        """Effective scheduling class: the static priority plus one class
        per ``aging_s`` seconds spent suspended — the anti-starvation
        clock that guarantees every preempted request eventually
        out-ranks a sustained-yellow victim and swaps back in."""
        if req.t_suspend is None or self.cfg.aging_s <= 0:
            return float(req.priority)
        return req.priority + (now - req.t_suspend) / self.cfg.aging_s

    def _victim_rid(self) -> Optional[int]:
        """Preemption victim among active requests: lowest priority class
        first, longest remaining decode (max_new - produced) as the
        tie-break — the request whose eviction frees capacity for the
        longest time at the smallest class cost."""
        if not self.active:
            return None
        return min(self.active,
                   key=lambda rid: (self.active[rid][0].priority,
                                    -(self.active[rid][0].max_new
                                      - len(self.active[rid][0].out))))

    def _suspend(self, rid: int) -> None:
        """Preempt one active request: the engine swaps its whole working
        set down-tier (slot retained), the request parks in
        ``_suspended`` and its deadline clock stops."""
        req, sid, tok = self.active.pop(rid)
        self.engine.suspend_sequence(sid)
        req.t_suspend = time.perf_counter()
        self._suspended[rid] = (req, sid, tok)
        self._suspensions += 1

    def _resume(self, rid: int) -> None:
        """Un-park one suspended request: re-stage its working set and
        restart its deadline clock; it rejoins the next decode round."""
        req, sid, tok = self._suspended.pop(rid)
        self.engine.resume_sequence(sid)
        req.suspended_s += time.perf_counter() - req.t_suspend
        req.t_suspend = None
        self.active[rid] = (req, sid, tok)
        self._resumes += 1

    def _shed_queue(self, reasons) -> None:
        """Red pressure: shed queued requests — lowest priority class
        first, newest arrival first within a class — down to the
        monitor's yellow queue watermark, each with a structured
        :class:`RejectedOverload` terminal result."""
        floor = getattr(getattr(self.monitor, "cfg", None),
                        "queue_yellow", 0)
        while len(self.queue) > max(0, floor):
            victim = min(self.queue,
                         key=lambda r: (r.priority, -r.t_submit))
            try:
                self.queue.remove(victim)
            except ValueError:
                break                  # raced a producer; try next round
            exc = RejectedOverload(victim.rid, tuple(sorted(reasons)))
            victim.rejected_overload = exc
            victim.error = str(exc)
            victim.t_done = time.perf_counter()
            self.rejected.append(victim)
            self._requests_rejected += 1

    def _apply_pressure(self) -> None:
        """One watermark-policy step (runs at the top of every round):

        * **green** — resume suspended requests (highest effective class
          first) into free decode seats before fresh admissions backfill.
        * **yellow from queue depth only** — capacity is fine but demand
          is piling up: priority preemption.  While the best queued
          request strictly out-ranks the weakest active victim and no
          seat is free, suspend the victim and move that request to the
          queue head; admission stays open so it backfills immediately.
        * **yellow from resources** (pool/host/disk) — pause admission
          and suspend the weakest victim (keeping at least one active)
          so the tier store stops thrashing.
        * **red** — shed the queue down to the yellow watermark with
          structured ``RejectedOverload`` results, plus the yellow
          actions.

        Anti-starvation: under sustained yellow a suspended request's
        effective class grows (``aging_s``); once it out-ranks the
        weakest active victim by a full class it swaps back in.  And
        whenever nothing is active or mid-admission, one suspended
        request force-resumes regardless of pressure — the loop always
        makes progress (no-starvation half of I7)."""
        if self.monitor is None:
            return
        state, reasons = self.monitor.sample(len(self.queue))
        self._pressure_state = state
        self._pressure_rounds[state] = \
            self._pressure_rounds.get(state, 0) + 1
        now = time.perf_counter()
        resource = bool(set(reasons) - {"queue"})
        self._admission_paused = state == _RED or (state == _YELLOW
                                                   and resource)
        if state == _RED:
            self._shed_queue(reasons)
        if state == _GREEN:
            while self._suspended and len(self.active) < self.cfg.max_active:
                rid = max(self._suspended,
                          key=lambda r: self._eff_priority(
                              self._suspended[r][0], now))
                self._resume(rid)
        elif resource:
            # resource pressure: drain the batch one victim per round,
            # never below a single active sequence (forward progress)
            if len(self.active) > 1:
                victim = self._victim_rid()
                if victim is not None:
                    self._suspend(victim)
        elif self.queue:
            # queue-only yellow: priority preemption.  Suspending frees a
            # decode seat (not an engine slot), so it only helps when
            # seats are the constraint and a slot exists for the admit.
            while (self.queue and self.active
                   and len(self.active) >= self.cfg.max_active
                   and self.engine.free_slots > 0):
                best = max(self.queue,
                           key=lambda r: (r.priority, -r.t_submit))
                victim = self._victim_rid()
                if victim is None or \
                        best.priority <= self.active[victim][0].priority:
                    break
                self._suspend(victim)
                try:
                    self.queue.remove(best)
                    self.queue.appendleft(best)
                except ValueError:
                    pass               # raced a producer; order stands
        if state == _YELLOW and self._suspended and self.active \
                and self.cfg.aging_s > 0:
            # aged swap: the most-starved suspended request trades places
            # with the weakest victim once a full class ahead of it
            rid_s = max(self._suspended,
                        key=lambda r: self._eff_priority(
                            self._suspended[r][0], now))
            victim = self._victim_rid()
            if victim is not None and \
                    self._eff_priority(self._suspended[rid_s][0], now) \
                    > self.active[victim][0].priority + 1.0:
                self._suspend(victim)
                self._resume(rid_s)
        if self._suspended and not self.active and not self._pending \
                and not self._ready and not self._chunked \
                and (not self.queue or self._admission_paused):
            # termination safety: nothing else can make progress — an
            # open queue is about to backfill via _admit, but with it
            # empty (or admission paused) one suspended request resumes
            # even under red pressure, so the loop never stalls
            rid = max(self._suspended,
                      key=lambda r: self._eff_priority(
                          self._suspended[r][0], now))
            self._resume(rid)

    def _cancel(self, req: Request, reason: str) -> None:
        """Terminal cancellation bookkeeping shared by every deadline
        path — the caller has already released whatever the request
        held."""
        req.error = reason
        req.t_done = time.perf_counter()
        self._prefix_credit.pop(req.rid, None)
        self.finished.append(req)
        self._requests_cancelled += 1

    def _sweep_deadlines(self) -> None:
        """Cancel every expired request at whatever lifecycle stage it
        reached: queued requests just drop; mid-admission requests drain
        their ingest/prefetch futures and release pool slots + prefix-
        arena refcounts (``abort_admission`` / ``ChunkedAdmission.cancel``
        — I1–I5 hold throughout); active/ready ones release normally.  A
        pending async admission is only reclaimed once its future has
        resolved — the slot is worker-owned until then (checked again
        next round)."""
        if not any(r.expired for r in
                   list(self.queue)
                   + [r for r, *_ in self._pending + self._ready
                      + self._chunked]
                   + [r for r, _, _ in self.active.values()]
                   + [r for r, _, _ in self._suspended.values()]):
            return
        for r in list(self.queue):      # remove in place: submit() may be
            if r.expired:               # appending from another thread
                try:
                    self.queue.remove(r)
                except ValueError:
                    continue
                self._cancel(r, "deadline expired while queued")
        still_p = []
        for req, fut in self._pending:
            if req.expired and fut.done():
                try:
                    sid, _tok = fut.result()
                    self.engine.release(sid)
                except AdmissionError as e:
                    self.engine.abort_admission(e.sid)
                self._cancel(req, "deadline expired during admission")
            else:
                still_p.append((req, fut))
        self._pending = still_p
        still_r = []
        for req, sid, tok in self._ready:
            if req.expired:
                self.engine.release(sid)
                self._cancel(req, "deadline expired before first round")
            else:
                still_r.append((req, sid, tok))
        self._ready = still_r
        still_c = []
        for req, adm in self._chunked:
            if req.expired:
                adm.cancel()
                self._cancel(req, "deadline expired mid-admission")
            else:
                still_c.append((req, adm))
        self._chunked = still_c
        for rid in [rid for rid, (req, _, _) in self.active.items()
                    if req.expired]:
            req, handle, _ = self.active.pop(rid)
            if self.engine is not None:
                self.engine.release(handle)
            elif hasattr(handle, "store") and handle.store is not None:
                handle.store.close()
            self._cancel(req, "deadline expired while decoding")
        # a suspended request's deadline clock is paused (paused_s), so
        # this only fires when the budget was already spent pre-suspend;
        # engine.release also un-parks the suspended slot
        for rid in [rid for rid, (req, _, _) in self._suspended.items()
                    if req.expired]:
            req, sid, _ = self._suspended.pop(rid)
            req.suspended_s += time.perf_counter() - req.t_suspend
            req.t_suspend = None
            self.engine.release(sid)
            self._cancel(req, "deadline expired while preempted")

    def _retire(self, rids: List[int]) -> None:
        store = getattr(self.engine, "store", None) \
            if self.engine is not None else None
        for rid in rids:
            req, handle, _ = self.active.pop(rid)
            req.t_done = time.perf_counter()
            self._prefix_credit.pop(rid, None)
            # degraded-numerics flag must be read BEFORE release: the
            # store clears per-slot fault state when the slot recycles
            if store is not None and hasattr(store, "degraded_seqs"):
                req.degraded = handle in store.degraded_seqs
            self.finished.append(req)
            if self.engine is not None:
                self.engine.release(handle)
            elif hasattr(handle, "store") and handle.store is not None:
                handle.store.close()

    @property
    def pending_work(self) -> bool:
        """True while any request is queued, decoding, or mid-admission —
        the loop condition :meth:`run` uses (public, so external drivers
        don't reach into the admission queues)."""
        return bool(self.queue or self.active or self._pending
                    or self._ready or self._chunked or self._suspended)

    @decode_thread_only
    def step(self) -> int:
        """One decode round over all active requests; returns #active."""
        self._sweep_deadlines()
        self._apply_pressure()
        self._admit()
        self._collect_admitted(block=not self.active and bool(self._pending))
        retired = [rid for rid, (req, _, _) in self.active.items() if req.done]
        live = {rid: v for rid, v in self.active.items()
                if rid not in retired}
        admission_active = bool(self._pending) or bool(self._chunked)
        if self.engine is not None and live:
            # ONE batched decode round for every live sequence; async
            # admissions prefill underneath it on the admission worker
            t0 = time.perf_counter()
            toks = self.engine.decode_round(
                {sid: tok for (_, sid, tok) in live.values()})
            self._note_round(time.perf_counter() - t0, admission_active)
            for rid, (req, sid, _) in live.items():
                if sid not in toks:
                    # the engine contained this sequence's failure
                    # (fail_sequence already drained and recycled the
                    # slot — releasing again would double-free); surface
                    # the terminal state on just this request
                    req.error = self.engine.failed.pop(
                        sid, "sequence failed")
                    req.t_done = time.perf_counter()
                    self._prefix_credit.pop(rid, None)
                    self.active.pop(rid)
                    self.finished.append(req)
                    continue
                tok = toks[sid]
                req.out.append(tok)
                self.active[rid] = (req, sid, tok)
                if req.done:
                    retired.append(rid)
        else:
            for rid, (req, eng, tok) in list(live.items()):
                tok = eng.decode_step(tok)
                req.out.append(tok)
                self.active[rid] = (req, eng, tok)
                if req.done:
                    retired.append(rid)
        self._retire(retired)
        # chunked admissions advance HERE, between decode rounds, under
        # the per-round prefill token budget
        self._advance_chunked()
        self._admit()
        self._collect_admitted(block=not self.active and bool(self._pending))
        return len(self.active)

    def run(self, max_rounds: int = 10_000) -> List[Request]:
        rounds = 0
        while self.pending_work and rounds < max_rounds:
            self.step()
            rounds += 1
        return self.finished

    def stats(self) -> Dict[str, float]:
        """Fleet metrics over finished requests: p50/p95 TTFT and
        per-request decode tok/s alongside the means.  Requests may finish
        out of submit order (continuous batching retires early finishers
        first), so the makespan is guarded to stay positive and every
        per-request rate divides by a clamped span."""
        pacing = {"admission_gate_open": float(self._gate_open),
                  "gated_rounds": float(self._gated_rounds)}
        if self._round_ewma is not None:
            pacing["round_ewma_s"] = float(self._round_ewma)
        if self._idle_ewma is not None:
            pacing["idle_round_ewma_s"] = float(self._idle_ewma)
        # the per-round prefill budget actually in force (static, or the
        # last adaptively derived figure) + the chunk-step EWMA behind it
        if self._derived_budget is not None:
            pacing["prefill_round_tokens"] = float(self._derived_budget)
        if self._chunk_ewma is not None:
            pacing["chunk_step_ewma_s"] = float(self._chunk_ewma)
        store = getattr(self.engine, "store", None)
        if store is not None and hasattr(store, "prefix_stats"):
            pacing.update(store.prefix_stats())
        if self.engine is not None and hasattr(self.engine, "fault_stats"):
            pacing.update(self.engine.fault_stats())
        pacing["requests_cancelled"] = float(self._requests_cancelled)
        pacing["requests_rejected"] = float(self._requests_rejected)
        # terminal accounting: every submitted request must land in
        # exactly one of {completed, shed, failed}; at quiescence
        # (pending_work False) unaccounted is ZERO — the overload bench
        # gates on it
        completed = sum(1 for r in self.finished if r.error is None)
        failed = sum(1 for r in self.finished if r.error is not None)
        shed = len(self.rejected)
        pacing["requests_submitted"] = float(self._requests_submitted)
        pacing["requests_completed"] = float(completed)
        pacing["requests_failed"] = float(failed)
        pacing["requests_shed"] = float(shed)
        pacing["requests_unaccounted"] = float(
            self._requests_submitted - completed - failed - shed)
        # overload-control observability (stats() is Dict[str, float]:
        # the state exports as its watermark level, 0/1/2)
        pacing["pressure_level"] = float(
            {_GREEN: 0, _YELLOW: 1, _RED: 2}.get(self._pressure_state, 0))
        for st, n in self._pressure_rounds.items():
            pacing[f"pressure_rounds_{st}"] = float(n)
        pacing["suspensions"] = float(self._suspensions)
        pacing["resumes"] = float(self._resumes)
        pacing["suspended_now"] = float(len(self._suspended))
        waited = np.array([r.t_admit - r.t_submit for r in self.finished
                           if r.t_admit is not None])
        if len(waited):
            pacing["p50_queue_wait_s"] = float(np.percentile(waited, 50))
            pacing["p95_queue_wait_s"] = float(np.percentile(waited, 95))
            pacing["p99_queue_wait_s"] = float(np.percentile(waited, 99))
        done = [r for r in self.finished
                if r.t_first is not None and r.t_done is not None]
        if not done:
            return pacing
        ttft = np.array([r.t_first - r.t_submit for r in done])
        lat = np.array([r.t_done - r.t_submit for r in done])
        # per-request decode rate: tokens after the first, over the decode
        # span (first-token to done); 1-token requests never decoded
        dec = np.array([(len(r.out) - 1) / max(r.t_done - r.t_first, 1e-9)
                        for r in done if len(r.out) > 1])
        toks = sum(len(r.out) for r in done)
        span = max(max(r.t_done for r in done)
                   - min(r.t_submit for r in done), 1e-9)
        out = {**pacing,
               "requests": len(done),
               "mean_ttft_s": float(ttft.mean()),
               "p50_ttft_s": float(np.percentile(ttft, 50)),
               "p95_ttft_s": float(np.percentile(ttft, 95)),
               "p99_ttft_s": float(np.percentile(ttft, 99)),
               "mean_latency_s": float(lat.mean()),
               "p95_latency_s": float(np.percentile(lat, 95)),
               "p99_latency_s": float(np.percentile(lat, 99)),
               "throughput_tok_s": toks / span}
        if len(dec):
            out.update({"mean_decode_tok_s": float(dec.mean()),
                        "p50_decode_tok_s": float(np.percentile(dec, 50)),
                        "p95_decode_tok_s": float(np.percentile(dec, 95)),
                        "p05_decode_tok_s": float(np.percentile(dec, 5))})
        return out
