"""Continuous-batching request scheduler for the LeoAM serving engine.

Admission is KV-budget-aware across the three tiers: a request is admitted
when its max_len worth of chunks fits the configured device+host budget
(disk replicas are assumed plentiful, per the paper).  Decode proceeds in
rounds over all active requests; finished requests retire immediately and
the queue backfills — the standard continuous-batching loop, driven here by
per-request LeoAM engines (production decode batches inside one jitted
``decode_step``; see launch/steps.make_jitted_decode).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    eos_id: Optional[int] = None
    out: List[int] = field(default_factory=list)
    t_submit: float = field(default_factory=time.perf_counter)
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def done(self) -> bool:
        if self.out and self.eos_id is not None and self.out[-1] == self.eos_id:
            return True
        return len(self.out) >= self.max_new


@dataclass
class SchedulerCfg:
    max_active: int = 4
    device_chunk_budget: int = 512     # total device-resident chunks
    chunk: int = 64


class ContinuousBatcher:
    """Round-robin continuous batching over engine-backed sequences."""

    def __init__(self, make_engine: Callable[[], "object"],
                 cfg: SchedulerCfg):
        self.make_engine = make_engine
        self.cfg = cfg
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, tuple] = {}     # rid -> (request, engine, tok)
        self.finished: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _chunks_needed(self, req: Request) -> int:
        return (len(req.prompt) + req.max_new + self.cfg.chunk - 1) \
            // self.cfg.chunk

    def _device_chunks_used(self) -> int:
        return sum(self._chunks_needed(r) for r, _, _ in self.active.values())

    def _admit(self) -> None:
        while (self.queue and len(self.active) < self.cfg.max_active
               and (self._device_chunks_used()
                    + self._chunks_needed(self.queue[0]))
               <= self.cfg.device_chunk_budget):
            req = self.queue.popleft()
            eng = self.make_engine()
            tok = eng.prefill(req.prompt)
            req.t_first = time.perf_counter()
            req.out.append(tok)
            self.active[req.rid] = (req, eng, tok)

    def step(self) -> int:
        """One decode round over all active requests; returns #active."""
        self._admit()
        retired = []
        for rid, (req, eng, tok) in list(self.active.items()):
            if req.done:
                retired.append(rid)
                continue
            tok = eng.decode_step(tok)
            req.out.append(tok)
            self.active[rid] = (req, eng, tok)
            if req.done:
                retired.append(rid)
        for rid in retired:
            req, eng, _ = self.active.pop(rid)
            req.t_done = time.perf_counter()
            self.finished.append(req)
            if hasattr(eng, "store") and eng.store is not None:
                eng.store.close()
        self._admit()
        return len(self.active)

    def run(self, max_rounds: int = 10_000) -> List[Request]:
        rounds = 0
        while (self.queue or self.active) and rounds < max_rounds:
            self.step()
            rounds += 1
        return self.finished

    def stats(self) -> Dict[str, float]:
        if not self.finished:
            return {}
        ttft = [r.t_first - r.t_submit for r in self.finished]
        lat = [r.t_done - r.t_submit for r in self.finished]
        toks = sum(len(r.out) for r in self.finished)
        span = max(r.t_done for r in self.finished) - min(
            r.t_submit for r in self.finished)
        return {"requests": len(self.finished),
                "mean_ttft_s": float(np.mean(ttft)),
                "mean_latency_s": float(np.mean(lat)),
                "throughput_tok_s": toks / max(span, 1e-9)}
