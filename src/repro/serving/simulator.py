"""Discrete-event latency simulator — the paper's evaluation harness
(Figs. 6, 13, 15–19) rebuilt from first-principles bytes/flops/overlap.

No wall-clock measurements are taken: every number derives from the hardware
constants below (calibrated to the paper's testbed: RTX-4090-class GPU,
PCIe 4.0, ~7 GB/s NVMe) and the byte/evaluation counts implied by each
policy.  Policies:

  full          — move every token's KV every step (offloading lower bound)
  h2o           — token-level importance eval; all disk KV transits for
                  evaluation (paper's H2O-like baseline)
  h2o_chunked   — fixed-chunk eval (Quest-like): fewer evals, over-fetch
                  from imprecise chunks, still full-disk transit for eval
  prefetch      — h2o + layer-pipelined prefetch (InfiniGen-like)
  leoam_lka     — +LKA: only abstracts transit from disk for evaluation
  leoam_iakm    — +IAKM: adaptive tree evaluation counts + exact-size fetch
  leoam_all     — +DTP: three-tier pipeline + dynamic INT4 compression
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import pipeline as dtp
from repro.core.desert import eval_cost
from repro.core.tiers import lka_transfer_ratio


@dataclass(frozen=True)
class HWCfg:
    """Paper-testbed constants (§6.1: RTX-4090, i7-14700K, PCIe 4.0,
    800 GB NVMe with ~7 GB/s peak read)."""
    gpu_flops: float = 83e12        # RTX-4090 bf16/fp16 dense
    gpu_hbm_bw: float = 1.0e12
    pcie_bw: float = 16e9           # PCIe 4.0 x16 effective
    disk_bw: float = 7.0e9          # the paper's measured SSD read rate
    cpu_eval_flops: float = 100e9   # CPU importance-evaluation throughput
    decompress_kappa: float = 1.0 / 80e9   # s/byte GPU dequant
    int4_ratio: float = 0.25 + 4 / 128
    # FlexGen-style weight placement (§6.1 "store model weights across both
    # the CPU and GPU"): the CPU-resident fraction streams over PCIe every
    # layer and is the compute-side floor every policy shares.
    weight_gpu_frac: float = 0.70
    weight_dtype_bytes: int = 2


@dataclass(frozen=True)
class ServeCfg:
    batch: int = 1
    prompt: int = 8192
    output: int = 128
    importance_rate: float = 0.1
    chunk: int = 64
    kv_dtype_bytes: int = 2
    gpu_frac: float = 0.10          # fraction of KV resident on GPU
    cpu_frac: float = 0.50          # fraction on CPU (rest on disk)
    rho: float = 0.12               # important-token density (tree model)


@dataclass
class StepBreakdown:
    eval_s: float = 0.0
    transfer_s: float = 0.0
    compute_s: float = 0.0
    total_s: float = 0.0


def _layer_geometry(cfg: ArchConfig, scfg: ServeCfg) -> Dict[str, float]:
    hd, Hkv = cfg.hd, cfg.n_kv_heads
    S = scfg.prompt
    kv_bytes_tok = 2 * Hkv * hd * scfg.kv_dtype_bytes       # K+V, one layer
    n_attn = sum(1 for k in cfg.layer_kinds() if k.startswith("attn"))
    params_layer = cfg.n_active_params() / max(cfg.n_layers, 1)
    return {"kv_bytes_tok": kv_bytes_tok, "n_attn": n_attn,
            "params_layer": params_layer, "S": S}


def decode_step_costs(cfg: ArchConfig, scfg: ServeCfg, hw: HWCfg,
                      policy: str) -> List[dtp.LayerCost]:
    """Per-layer costs for ONE decode step under a policy."""
    g = _layer_geometry(cfg, scfg)
    B, S = scfg.batch, g["S"]
    kv_tok = g["kv_bytes_tok"]
    n_sel = max(1, int(S * scfg.importance_rate))
    disk_frac = max(0.0, 1.0 - scfg.gpu_frac - scfg.cpu_frac)
    n_chunks = S // scfg.chunk

    # GPU compute: dense matmuls are bounded below by HBM weight streaming
    # AND by PCIe streaming of the CPU-resident weight fraction (FlexGen
    # placement) — the common floor all policies share.
    w_bytes = g["params_layer"] * hw.weight_dtype_bytes
    t_dense = max(2 * g["params_layer"] * B / hw.gpu_flops,
                  w_bytes / hw.gpu_hbm_bw,
                  w_bytes * (1.0 - hw.weight_gpu_frac) / hw.pcie_bw)
    t_attn = (n_sel * kv_tok * B) / hw.gpu_hbm_bw          # bandwidth-bound
    compute = t_dense + t_attn

    # evaluation cost + transit bytes by policy
    over_fetch = 1.0
    if policy == "full":
        evals = 0
        eval_flops = 0.0
        abstract_bytes = 0.0
        sel_tokens = S                                      # everything moves
    elif policy in ("h2o", "prefetch"):
        evals = S
        eval_flops = evals * cfg.hd * cfg.n_heads * 2 * B
        # all disk-resident KV must transit up for evaluation (paper §3.4)
        abstract_bytes = disk_frac * S * kv_tok * B
        sel_tokens = n_sel
    elif policy == "h2o_chunked":
        evals = n_chunks
        eval_flops = evals * cfg.hd * cfg.n_heads * 2 * B
        abstract_bytes = disk_frac * S * kv_tok * B
        over_fetch = 1.0 / 0.625                            # paper Fig. 5/10
        sel_tokens = n_sel
    elif policy in ("leoam_lka", "leoam_iakm", "leoam_all"):
        if policy == "leoam_lka":
            evals = n_chunks
            over_fetch = 1.0 / 0.625
        else:
            evals = eval_cost(S, optimal_m(S, scfg.rho), scfg.rho)
            over_fetch = 1.0                                # exact-size chunks
        eval_flops = evals * cfg.hd * cfg.n_heads * 2 * B
        # LKA: only abstracts transit from disk (r = alpha + 2/n')
        abstract_bytes = (disk_frac * S * kv_tok * B) * (2.0 / scfg.chunk)
        sel_tokens = n_sel
    else:
        raise ValueError(policy)

    eval_cpu = eval_flops / hw.cpu_eval_flops
    moved = sel_tokens * over_fetch * kv_tok * B
    kv_disk = moved * disk_frac
    kv_cpu = moved * (1.0 - scfg.gpu_frac) - kv_disk
    kv_cpu = max(kv_cpu, 0.0)

    costs = []
    for kind in cfg.layer_kinds():
        if not kind.startswith("attn"):
            costs.append(dtp.LayerCost(compute=t_dense, eval_cpu=0.0,
                                       abstract_bytes=0.0, kv_bytes_cpu=0.0,
                                       kv_bytes_disk=0.0))
        else:
            costs.append(dtp.LayerCost(compute=compute, eval_cpu=eval_cpu,
                                       abstract_bytes=abstract_bytes,
                                       kv_bytes_cpu=kv_cpu,
                                       kv_bytes_disk=kv_disk))
    return costs


def optimal_m(n: int, rho: float) -> int:
    from repro.core.desert import optimal_chunk_count
    return optimal_chunk_count(n, rho)


def simulate_decode(cfg: ArchConfig, scfg: ServeCfg, hw: HWCfg,
                    policy: str) -> StepBreakdown:
    """One decode step's latency under the policy's overlap model."""
    layers = decode_step_costs(cfg, scfg, hw, policy)
    bw = dtp.TierBW(pcie=hw.pcie_bw, disk=hw.disk_bw,
                    kappa=hw.decompress_kappa, delta=hw.int4_ratio)
    pipelined = policy in ("prefetch", "leoam_all")
    dyn = policy == "leoam_all"
    tl = dtp.schedule(layers, bw, pipelined=pipelined,
                      dynamic_compression=dyn)
    out = StepBreakdown(
        eval_s=sum(e - s for s, e in tl.evaluate),
        transfer_s=sum(e - s for s, e in tl.transfer),
        compute_s=sum(e - s for s, e in tl.compute),
        total_s=tl.makespan)
    return out


def prefill_time(cfg: ArchConfig, scfg: ServeCfg, hw: HWCfg) -> float:
    """Compute-bound prefill + KV write-out to tiers."""
    flops = 2 * cfg.n_active_params() * scfg.prompt * scfg.batch
    g = _layer_geometry(cfg, scfg)
    kv_total = g["kv_bytes_tok"] * scfg.prompt * scfg.batch * g["n_attn"]
    disk_frac = max(0.0, 1.0 - scfg.gpu_frac - scfg.cpu_frac)
    t_write = kv_total * disk_frac / hw.disk_bw + kv_total * (
        1 - scfg.gpu_frac) / hw.pcie_bw
    return flops / hw.gpu_flops + t_write


def prefill_time_prefix(cfg: ArchConfig, scfg: ServeCfg, hw: HWCfg,
                        hit_frac: float) -> float:
    """Prefill time with a warm shared prefix covering ``hit_frac`` of the
    prompt (the live engine's content-addressable admission path).

    The warm span pays no prefill FLOPs and writes no tier bytes — its KV
    is adopted by reference — but the GPU-resident share of the adopted
    span must still be promoted over PCIe into the device pool.  At
    ``hit_frac == 0`` this is exactly :func:`prefill_time`.
    """
    assert 0.0 <= hit_frac <= 1.0, hit_frac
    cold = 1.0 - hit_frac
    flops = 2 * cfg.n_active_params() * scfg.prompt * scfg.batch * cold
    g = _layer_geometry(cfg, scfg)
    kv_total = g["kv_bytes_tok"] * scfg.prompt * scfg.batch * g["n_attn"]
    disk_frac = max(0.0, 1.0 - scfg.gpu_frac - scfg.cpu_frac)
    t_write = cold * (kv_total * disk_frac / hw.disk_bw
                      + kv_total * (1 - scfg.gpu_frac) / hw.pcie_bw)
    t_promote = hit_frac * kv_total * scfg.gpu_frac / hw.pcie_bw
    return flops / hw.gpu_flops + t_write + t_promote


def simulate_request(cfg: ArchConfig, scfg: ServeCfg, hw: HWCfg,
                     policy: str) -> Dict[str, float]:
    step = simulate_decode(cfg, scfg, hw, policy)
    pre = prefill_time(cfg, scfg, hw)
    total = pre + step.total_s * scfg.output
    return {
        "prefill_s": pre,
        "decode_step_s": step.total_s,
        "decode_eval_s": step.eval_s,
        "decode_transfer_s": step.transfer_s,
        "decode_compute_s": step.compute_s,
        "total_s": total,
        "tokens_per_s": scfg.output * scfg.batch / max(total - pre, 1e-9),
    }


POLICIES = ("full", "h2o", "h2o_chunked", "prefetch",
            "leoam_lka", "leoam_iakm", "leoam_all")


def compare_policies(cfg: ArchConfig, scfg: ServeCfg,
                     hw: Optional[HWCfg] = None) -> Dict[str, Dict[str, float]]:
    hw = hw or HWCfg()
    return {p: simulate_request(cfg, scfg, hw, p) for p in POLICIES}


def simulate_trace_goodput(cfg: ArchConfig, scfg: ServeCfg, hw: HWCfg,
                           arrivals, policy: str = "leoam_all",
                           servers: int = 1) -> Dict[str, float]:
    """Analytic goodput over an arrival trace (the simulator half of the
    fig15 simulator-vs-measured comparison).

    Replays the trace through a ``servers``-way FCFS queue where each
    request's service time comes from the cost model at ITS OWN prompt
    length (``prefill + max_new * decode_step``); goodput is the fraction
    of arrivals whose sojourn (wait + service) lands within their
    deadline — deadline-free arrivals always count.  ``arrivals`` is any
    iterable with ``t`` / ``prompt_len`` / ``max_new`` / ``deadline_s``
    fields (:class:`repro.serving.trace.Arrival`).  Per-length service
    times are memoized — a zipfian trace repeats lengths heavily."""
    free = [0.0] * max(1, int(servers))
    svc_cache: Dict[int, Dict[str, float]] = {}
    ok = n = 0
    lat_sum = 0.0
    for a in sorted(arrivals, key=lambda a: a.t):
        plen = int(a.prompt_len)
        r = svc_cache.get(plen)
        if r is None:
            r = simulate_request(cfg, replace(scfg, prompt=plen), hw, policy)
            svc_cache[plen] = r
        service = r["prefill_s"] + a.max_new * r["decode_step_s"]
        k = min(range(len(free)), key=free.__getitem__)
        start = max(a.t, free[k])
        free[k] = start + service
        sojourn = free[k] - a.t
        lat_sum += sojourn
        n += 1
        if a.deadline_s is None or sojourn <= a.deadline_s:
            ok += 1
    return {"goodput": ok / max(1, n), "requests": float(n),
            "mean_latency_s": lat_sum / max(1, n),
            "makespan_s": max(free) if n else 0.0}
