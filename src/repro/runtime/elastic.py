"""Elastic scaling: rebuild the mesh from the live device set and reshard.

When a pod drops out (or joins), the controller calls ``remesh`` with the
surviving device list; parameters/optimizer state are re-laid-out onto the
new mesh from host buffers or the latest checkpoint.  Works with any device
count whose factorization supports a (data, model) grid.
"""

from __future__ import annotations

import math
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.partition import spec_for


def choose_grid(n_devices: int, *, prefer_model: int = 16
                ) -> Tuple[int, int]:
    """(data, model) factorization: keep model parallelism near the target
    width, give the rest to data."""
    model = math.gcd(n_devices, prefer_model)
    while model > 1 and n_devices % model:
        model //= 2
    return n_devices // max(model, 1), max(model, 1)


def make_mesh_from_devices(devices: Optional[Sequence] = None,
                           *, prefer_model: int = 16) -> Mesh:
    devices = list(devices if devices is not None else jax.devices())
    data, model = choose_grid(len(devices), prefer_model=prefer_model)
    arr = np.asarray(devices[: data * model]).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def reshard_tree(tree: Any, axes_tree: Any, new_mesh: Mesh) -> Any:
    """Host-round-trip reshard of an arbitrary state tree onto a new mesh."""
    def one(leaf, axes):
        host = np.asarray(leaf)
        spec = spec_for(tuple(host.shape), axes, new_mesh)
        return jax.device_put(host, NamedSharding(new_mesh, spec))
    return jax.tree.map(one, tree, axes_tree,
                        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))


def shrink_batch_for(global_batch: int, new_mesh: Mesh) -> int:
    """Largest batch <= global_batch divisible by the new data extent."""
    d = new_mesh.shape.get("data", 1)
    return max(d, (global_batch // d) * d)
