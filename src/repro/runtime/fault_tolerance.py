"""Fault-tolerant training driver: retry-with-restore, straggler telemetry.

``run_with_restarts`` wraps a step loop so transient worker failures restart
from the latest checkpoint instead of killing the job — the behaviour a
1000-node deployment needs from its controller.  Failure injection hooks are
exercised by tests/test_fault_tolerance.py.
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

log = logging.getLogger("repro.runtime")


@dataclass
class StragglerMonitor:
    """EMA step-time tracker; flags steps slower than ``threshold``x EMA.

    On a real pod this feeds the controller's slow-host eviction; here it is
    the telemetry layer (per-step timing is also what §Perf iterations read).
    """
    alpha: float = 0.1
    threshold: float = 2.0
    ema: Optional[float] = None
    flagged: List[Tuple[int, float]] = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        slow = self.ema is not None and dt > self.threshold * self.ema
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        if slow:
            self.flagged.append((step, dt))
            log.warning("straggler step %d: %.3fs (ema %.3fs)", step, dt, self.ema)
        return slow


@dataclass
class RestartStats:
    restarts: int = 0
    failed_steps: List[int] = field(default_factory=list)


def run_with_restarts(step_fn: Callable[[int, Any], Any], state: Any, *,
                      n_steps: int, checkpointer, save_every: int,
                      restore_fn: Callable[[Any], Tuple[Any, int]],
                      max_restarts: int = 3,
                      monitor: Optional[StragglerMonitor] = None,
                      start_step: int = 0) -> Tuple[Any, RestartStats]:
    """Run ``step_fn(step, state) -> state`` with checkpoint/restart.

    On an exception the state is rolled back to the latest checkpoint via
    ``restore_fn`` and execution resumes from that step.  ``step_fn`` owns
    the device work; everything here is host control flow.
    """
    stats = RestartStats()
    step = start_step
    while step < n_steps:
        try:
            t0 = time.perf_counter()
            state = step_fn(step, state)
            dt = time.perf_counter() - t0
            if monitor is not None:
                monitor.record(step, dt)
            step += 1
            if save_every and step % save_every == 0:
                checkpointer.save(step, state)
        except Exception as e:  # noqa: BLE001 — controller-level catch
            stats.restarts += 1
            stats.failed_steps.append(step)
            if stats.restarts > max_restarts:
                log.error("exceeded max_restarts=%d; giving up", max_restarts)
                raise
            log.warning("step %d failed (%s); restoring latest checkpoint",
                        step, type(e).__name__)
            checkpointer.wait()
            state, restored_step = restore_fn(state)
            step = restored_step
    checkpointer.wait()
    return state, stats
