"""Post-optimization HLO text analysis with while-loop trip-count scaling.

``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE (verified
empirically — a scanned 8x matmul reports 1/8 the flops of its unrolled
twin), which would silently undercount every scan-over-layers model by its
depth.  This parser rebuilds the cost bottom-up from ``compiled.as_text()``:

  cost(computation) = Σ instruction costs
                      + cost(while body+cond) × known_trip_count
                      + cost(called fusions/calls)

and extracts per-collective byte counts (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute) with replica-group sizes,
which cost_analysis does not expose at all.  All numbers are *per device*
(the input is the SPMD-partitioned module).
"""

from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1, "s4": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-\$]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-\$]+)")
_TRIP_RE = re.compile(r"known_trip_count\D*(\d+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "exponential",
    "log", "tanh", "rsqrt", "sqrt", "power", "negate", "abs", "floor", "compare",
    "select", "and", "or", "xor", "not", "sign", "cosine", "sine", "atan2",
    "exponential-minus-one", "log-plus-one", "logistic", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "clamp",
}


def _parse_shape_bytes_elems(type_str: str) -> Tuple[int, int]:
    """Total (bytes, elements) of a possibly-tuple HLO type string."""
    total_b = total_e = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_b += elems * _DTYPE_BYTES[dt]
        total_e += elems
    return total_b, total_e


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str

    @property
    def out_bytes(self) -> int:
        return _parse_shape_bytes_elems(self.type_str)[0]

    @property
    def out_elems(self) -> int:
        return _parse_shape_bytes_elems(self.type_str)[1]


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0                      # operand+result traffic
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + int(v * mult)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def to_json(self) -> Dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "collective_bytes": dict(self.collective_bytes),
                "collective_counts": dict(self.collective_counts),
                "total_collective_bytes": self.total_collective_bytes}


def _is_comp_header(line: str) -> Optional[str]:
    """Computation headers are top-level lines ending in '{' with '->'.

    Parameter lists may contain arbitrarily nested tuple types, so we only
    key on the leading name token rather than parsing the signature.
    """
    s = line.rstrip()
    if not s.endswith("{") or "->" not in s or line[:1].isspace():
        return None
    m = _COMP_NAME_RE.match(s)
    return m.group(1) if m else None


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            name = _is_comp_header(line)
            if name:
                cur = name
                comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    return Instr(m.group(1), m.group(2), m.group(3), m.group(4))


def _operands(rest: str) -> List[str]:
    """Operand instruction names from the call-paren contents."""
    depth, out, cur = 0, [], []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    names = []
    for tok in out:
        mm = re.search(r"%([\w\.\-]+)", tok)
        if mm:
            names.append(mm.group(1))
    return names


def _called(rest: str) -> List[str]:
    out = []
    for key in ("body=", "condition=", "calls=", "to_apply="):
        for m in re.finditer(re.escape(key) + r"\{?%?([\w\.\-]+)", rest):
            val = m.group(1)
            out.append((key[:-1], val))
    return out


def _dot_flops(inst: Instr, symtab: Dict[str, str]) -> float:
    ops = _operands(inst.rest)
    _, out_elems = _parse_shape_bytes_elems(inst.type_str)
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.rest)
    if not ops or cdims is None:
        return 2.0 * out_elems  # degenerate
    lhs_type = symtab.get(ops[0], "")
    m = _SHAPE_RE.search(lhs_type)
    if not m:
        return 2.0 * out_elems
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    contract = 1
    for i in (int(x) for x in cdims.group(1).split(",") if x):
        if i < len(dims):
            contract *= dims[i]
    return 2.0 * out_elems * contract


def _group_size(rest: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(rest)              # e.g. [32,16]<=[512]
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)         # e.g. {{0,1},{2,3}}
    if m:
        first = m.group(1).split("}")[0].strip("{ ")
        if first:
            return len(first.split(","))
    return n_devices


class HloCost:
    """Whole-module cost with while-trip scaling; all values per-device."""

    def __init__(self, hlo_text: str, n_devices: int = 1):
        self.n_devices = n_devices
        self.comps = _split_computations(hlo_text)
        self.entry = self._find_entry(hlo_text)
        self._memo: Dict[str, Cost] = {}

    # ------------------------------------------------------------------
    def collective_detail(self) -> List[Tuple[float, float, str, str, str]]:
        """(wire_bytes_total, multiplier, op, shape, comp) per collective,
        with while-loop multipliers applied.  Sorted descending."""
        mults: Dict[str, float] = {self.entry: 1.0}
        order = [self.entry]
        seen = {self.entry}
        i = 0
        while i < len(order):
            comp = order[i]
            i += 1
            for line in self.comps.get(comp, ()):
                inst = _parse_instr(line)
                if inst is None:
                    continue
                mult = mults[comp]
                if inst.op == "while":
                    m = _TRIP_RE.search(inst.rest)
                    trip = int(m.group(1)) if m else 1
                    mult = mult * trip
                for kind, name in _called(inst.rest):
                    if name in self.comps:
                        mults[name] = mults.get(name, 0.0) + (
                            mult if inst.op != "while" or kind in
                            ("body", "condition") else mults[comp])
                        if name not in seen:
                            seen.add(name)
                            order.append(name)
        out = []
        for comp, lines in self.comps.items():
            if comp not in mults:
                continue
            symtab: Dict[str, str] = {}
            for line in lines:
                inst = _parse_instr(line)
                if inst is None:
                    continue
                symtab[inst.name] = inst.type_str
                if inst.op in COLLECTIVES:
                    c = self._instr_cost(inst, symtab)
                    wire = c.total_collective_bytes
                    out.append((wire * mults[comp], mults[comp], inst.op,
                                inst.type_str[:60], comp[:40]))
        out.sort(reverse=True)
        return out

    def _find_entry(self, hlo: str) -> str:
        for line in hlo.splitlines():
            if line.startswith("ENTRY"):
                name = _is_comp_header(line)
                if name:
                    return name
        return next(iter(self.comps))

    # ------------------------------------------------------------------
    def _effective_param_bytes(self, comp: str
                               ) -> Tuple[Dict[int, int], Optional[int]]:
        """(per-parameter effective reads, effective output bytes) for a
        fused computation.

        * a parameter consumed ONLY by dynamic-slice/gather reads just the
          slice (stacked layer weights / scan xs would otherwise be charged
          fully per loop iteration);
        * a parameter that is the in-place TARGET of a root
          dynamic-update-slice costs no read, and the fusion's output is
          the written slice, not the full buffer (scan ys accumulation).
        """
        if not hasattr(self, "_eff_memo"):
            self._eff_memo: Dict[str, Tuple[Dict[int, int], Optional[int]]] = {}
        if comp in self._eff_memo:
            return self._eff_memo[comp]
        PASS = ("bitcast", "convert", "copy", "transpose", "reshape")
        params: Dict[str, int] = {}
        insts: List[Instr] = []
        by_name: Dict[str, Instr] = {}
        root = None
        for line in self.comps.get(comp, ()):
            inst = _parse_instr(line)
            if inst is None:
                continue
            if inst.op == "parameter":
                m = re.match(r"\s*(\d+)\)", inst.rest)
                if m:
                    params[inst.name] = int(m.group(1))
                continue
            insts.append(inst)
            by_name[inst.name] = inst
            if "ROOT" in line:
                root = inst

        consumers: Dict[str, List[Instr]] = {}
        for i2 in insts:
            for o in _operands(i2.rest):
                consumers.setdefault(o, []).append(i2)

        def peel_root(r: Optional[Instr]) -> Optional[Instr]:
            seen = 0
            while r is not None and r.op in PASS and seen < 8:
                ops_ = _operands(r.rest)
                r = by_name.get(ops_[0]) if ops_ else None
                seen += 1
            return r

        out_override: Optional[int] = None
        dus_roots: List[Instr] = []
        true_root = peel_root(root)
        if true_root is not None and true_root.op == "dynamic-update-slice":
            dus_roots.append(true_root)
            ops_ = _operands(true_root.rest)
            upd = by_name.get(ops_[1]) if len(ops_) > 1 else None
            out_override = (upd.out_bytes if upd is not None
                            else true_root.out_bytes)

        def classify(name: str, depth: int = 0) -> Optional[int]:
            """Effective read bytes for a value consumed downstream, or
            None if it is read in full by some consumer."""
            if depth > 8:
                return None
            total = 0
            for u in consumers.get(name, ()):
                if u.op in ("dynamic-slice", "gather"):
                    total += u.out_bytes
                elif u.op == "dynamic-update-slice":
                    ops_ = _operands(u.rest)
                    if ops_ and ops_[0] == name:
                        total += 0          # in-place target: no read
                    else:
                        total += u.out_bytes
                elif u.op in PASS:
                    sub = classify(u.name, depth + 1)
                    if sub is None:
                        return None
                    total += sub
                else:
                    return None
            return total

        out: Dict[int, int] = {}
        for pname, idx in params.items():
            eff = classify(pname)
            if eff is not None:
                out[idx] = eff
        self._eff_memo[comp] = (out, out_override)
        return out, out_override

    def cost(self, comp: Optional[str] = None) -> Cost:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()            # cycle guard
        total = Cost()
        symtab: Dict[str, str] = {}
        for line in self.comps.get(comp, ()):
            inst = _parse_instr(line)
            if inst is None:
                continue
            symtab[inst.name] = inst.type_str
            total.add(self._instr_cost(inst, symtab))
        self._memo[comp] = total
        return total

    def _instr_cost(self, inst: Instr, symtab: Dict[str, str]) -> Cost:
        c = Cost()
        op = inst.op
        if op == "while":
            trip = 1
            m = _TRIP_RE.search(inst.rest)
            if m:
                trip = int(m.group(1))
            for kind, name in _called(inst.rest):
                if kind in ("body", "condition") and name in self.comps:
                    c.add(self.cost(name), trip)
            return c
        if op in ("fusion", "call"):
            inner = Cost()
            called_names = []
            for kind, name in _called(inst.rest):
                if kind == "calls" and name in self.comps:
                    inner.add(self.cost(name))
                    called_names.append(name)
            # fusion traffic = operands + result ONLY; ops inside the fused
            # computation are VMEM/register-local — counting their operand
            # bytes (as cost() does for top-level ops) would overstate HBM
            # traffic by the fusion's internal op count
            c.flops += inner.flops
            for k, v in inner.collective_bytes.items():
                c.collective_bytes[k] = c.collective_bytes.get(k, 0.0) + v
            for k, v in inner.collective_counts.items():
                c.collective_counts[k] = c.collective_counts.get(k, 0) + v
            eff, out_override = (self._effective_param_bytes(called_names[0])
                                 if called_names else ({}, None))
            b = inst.out_bytes if out_override is None else out_override
            for i, o in enumerate(_operands(inst.rest)):
                full = _parse_shape_bytes_elems(symtab.get(o, ""))[0]
                b += min(full, eff.get(i, full))
            c.bytes += b
            return c
        if op == "conditional":
            branches = [self.cost(n) for _, n in _called(inst.rest)
                        if n in self.comps]
            if branches:
                worst = max(branches, key=lambda x: x.flops + x.bytes)
                c.add(worst)
            return c
        if op in COLLECTIVES:
            b = 0
            for o in _operands(inst.rest):
                b += _parse_shape_bytes_elems(symtab.get(o, ""))[0]
            b = max(b, inst.out_bytes if op == "all-gather" else 0)
            g = _group_size(inst.rest, self.n_devices)
            # ring wire-traffic factor per participant
            if op == "all-reduce":
                wire = 2.0 * b * (g - 1) / max(g, 1)
            elif op in ("all-gather", "reduce-scatter"):
                wire = 1.0 * max(b, inst.out_bytes) * (g - 1) / max(g, 1)
            elif op == "all-to-all":
                wire = b * (g - 1) / max(g, 1)
            else:  # collective-permute
                wire = b
            c.collective_bytes[op] = c.collective_bytes.get(op, 0.0) + wire
            c.collective_counts[op] = c.collective_counts.get(op, 0) + 1
            c.bytes += b + inst.out_bytes
            return c
        if op == "dynamic-update-slice":
            # in-place: traffic is the written slice (read+write), not the
            # full buffer — crucial for per-layer KV-cache updates in loops
            ops_ = _operands(inst.rest)
            upd = _parse_shape_bytes_elems(symtab.get(ops_[1], ""))[0] \
                if len(ops_) > 1 else inst.out_bytes
            c.bytes += 2 * upd
            return c
        if op in ("dynamic-slice", "gather"):
            c.bytes += 2 * inst.out_bytes
            return c
        if op == "scatter":
            ops_ = _operands(inst.rest)
            upd = _parse_shape_bytes_elems(symtab.get(ops_[-1], ""))[0] \
                if ops_ else inst.out_bytes
            c.bytes += 2 * upd
            return c
        if op == "dot":
            c.flops += _dot_flops(inst, symtab)
        elif op == "convolution":
            # rough: 2 * out_elems * (kernel elems) — kernels are rare here
            ops = _operands(inst.rest)
            kb = _parse_shape_bytes_elems(symtab.get(ops[1], ""))[1] if len(ops) > 1 else 1
            c.flops += 2.0 * inst.out_elems * max(kb, 1)
        elif op in _ELEMENTWISE or op.startswith("reduce"):
            c.flops += float(inst.out_elems)
            if op.startswith("reduce"):
                for o in _operands(inst.rest):
                    c.flops += _parse_shape_bytes_elems(symtab.get(o, ""))[1]
        # memory traffic: result + operands.  `copy` is excluded: the CPU
        # backend sinks layout copies of loop-invariant tensors INTO while
        # bodies (observed: ~60 full-sequence copies per xLSTM time step),
        # an artifact absent from TPU codegen — counting them would swamp
        # the memory term with backend noise.
        b = inst.out_bytes
        for o in _operands(inst.rest):
            b += _parse_shape_bytes_elems(symtab.get(o, ""))[0]
        if op not in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "copy"):
            c.bytes += b
        return c


def analyze(hlo_text: str, n_devices: int = 1) -> Dict:
    hc = HloCost(hlo_text, n_devices)
    return hc.cost().to_json()
