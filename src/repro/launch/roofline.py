"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell:
    compute term    = HLO_FLOPs / (chips × 197e12)
    memory term     = HLO_bytes / (chips × 819e9)
    collective term = collective_bytes / (chips × 50e9)

HLO_FLOPs / HLO_bytes / collective_bytes come from the trip-count-scaled
parse of the compiled SPMD module (repro.launch.hlo_costing) and are
PER-DEVICE, so the "chips ×" denominators cancel against the per-chip
numerators — terms are reported as per-chip seconds.  MODEL_FLOPS uses
6·N·D (train) / 2·N_active·D (inference).  A bf16-correction halves
collective bytes measured on f32 tensors where the model dtype is bf16
(the CPU backend upcasts bf16 dots before the partitioner places
collectives; on TPU those transfers are bf16).

Usage: python -m repro.launch.roofline [--dir results/dryrun] [--csv out]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config, get_shape

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per link (ICI)

F32_COLLECTIVE_CORRECTION = 0.5   # CPU-backend f32 upcast -> bf16 on TPU


def model_flops(arch: str, shape_name: str, n_devices: int) -> float:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        total = 2.0 * n * tokens
    else:
        total = 2.0 * n * shape.global_batch
    return total / n_devices


def analyze_cell(rec: Dict) -> Dict:
    n_dev = rec["n_devices"]
    hc = rec["hlo_cost"]
    flops = hc["flops"]
    mem_bytes = hc["bytes"]
    coll = hc["total_collective_bytes"] * F32_COLLECTIVE_CORRECTION
    t_c = flops / PEAK_FLOPS
    t_m = mem_bytes / HBM_BW
    t_x = coll / LINK_BW
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops(rec["arch"], rec["shape"], n_dev)
    bound = max(t_c, t_m, t_x)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / max(flops, 1.0),
        "roofline_fraction": (mf / PEAK_FLOPS) / max(bound, 1e-30),
        "peak_gib": rec["memory"]["peak_bytes_per_device"] / 2**30,
        "collective_bytes": coll,
    }


def suggestion(row: Dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("defer/batch gradient reductions; remove per-loop weight "
                "gathers; overlap collectives with compute")
    if d == "memory":
        return ("fuse attention pipeline (Pallas flash/sparse kernels); "
                "raise arithmetic intensity via larger per-step tiles")
    return ("cut non-useful FLOPs: causal-skip attention blocks, lighter "
            "remat policy, avoid recompute of cheap ops")


def load(dir_: str, mesh: Optional[str] = None) -> List[Dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        rec = json.load(open(fn))
        if mesh and rec["mesh"] != mesh:
            continue
        rows.append(analyze_cell(rec))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    rows = load(args.dir, args.mesh)
    hdr = (f"{'arch':24s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'dom':>10s} {'useful':>7s} {'roofl%':>7s} "
           f"{'GiB/dev':>8s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:9.3g} "
              f"{r['memory_s']:9.3g} {r['collective_s']:9.3g} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
              f"{100 * r['roofline_fraction']:7.1f} {r['peak_gib']:8.2f}")
    if args.csv:
        import csv
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
        print(f"wrote {args.csv}")


if __name__ == "__main__":
    main()
