"""Training driver: data pipeline → sharded train loop → checkpoints, with
fault tolerance and straggler telemetry.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt /tmp/ckpt

On a real pod, run one process per host with the production mesh; on this
container it runs the same code single-device (or multi-device under
XLA_FLAGS=--xla_force_host_platform_device_count=N).
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.synthetic import DataCfg, ShardedLoader
from repro.launch import steps as stp
from repro.models import lm
from repro.optim import adamw
from repro.runtime.fault_tolerance import StragglerMonitor, run_with_restarts

log = logging.getLogger("repro.train")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch, smoke=args.smoke)
    tcfg = stp.TrainCfg(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                        total_steps=args.steps)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    state = {"params": params,
             "opt": adamw.init_opt_state(params, tcfg.adam)}
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    log.info("arch=%s params=%.2fM steps=%d", cfg.name, n_params / 1e6,
             args.steps)

    step_fn = jax.jit(stp.make_train_step(cfg, tcfg))
    loader = ShardedLoader(DataCfg(vocab_size=cfg.vocab_size,
                                   seq_len=args.seq,
                                   global_batch=args.batch))
    ck = Checkpointer(args.ckpt, keep=3) if args.ckpt else None
    start = 0
    if ck and args.resume and ck.latest_step() is not None:
        tpl = jax.tree.map(np.asarray, state)
        state, start = ck.restore(tpl)
        state = jax.tree.map(jnp.asarray, state)
        log.info("resumed from step %d", start)

    metrics_hist = []

    def one_step(i, s):
        batch = next(loader)
        s, m = step_fn(s, {k: jnp.asarray(v) for k, v in batch.items()})
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(m["loss"])
            metrics_hist.append((i, loss))
            log.info("step %5d loss=%.4f acc=%.3f lr=%.2e gnorm=%.2f",
                     i, loss, float(m["accuracy"]), float(m["lr"]),
                     float(m.get("grad_norm", 0.0)))
        return s

    mon = StragglerMonitor()
    if ck:
        state, stats = run_with_restarts(
            one_step, state, n_steps=args.steps, checkpointer=ck,
            save_every=args.save_every, monitor=mon, start_step=start,
            restore_fn=lambda s: tuple(
                (jax.tree.map(jnp.asarray, r), at)
                for r, at in [ck.restore(jax.tree.map(np.asarray, s))])[0])
        log.info("done; restarts=%d stragglers=%d", stats.restarts,
                 len(mon.flagged))
    else:
        for i in range(start, args.steps):
            t0 = time.perf_counter()
            state = one_step(i, state)
            mon.record(i, time.perf_counter() - t0)
    loader.close()
    if len(metrics_hist) >= 2:
        first, last = metrics_hist[0][1], metrics_hist[-1][1]
        log.info("loss %.4f -> %.4f (delta %.4f)", first, last, first - last)


if __name__ == "__main__":
    main()
