"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  Single pod: a
(16, 16) = 256-chip (data, model) grid; multi-pod: (2, 16, 16) = 512 chips
with a leading "pod" axis that composes with "data" for batch/FSDP sharding
(cross-pod traffic is the cheap DP all-reduce; TP collectives stay
intra-pod).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.6 takes explicit axis types; the pinned 0.4.x does not
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - exercised on the pinned JAX
    AxisType = None


def set_mesh(mesh: Mesh):
    """Context manager activating ``mesh`` for tracing.

    ``jax.set_mesh`` on new JAX; on the pinned 0.4.x a ``Mesh`` is itself a
    context manager with the equivalent thread-local effect.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def _mesh(shape, axes) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2) -> Mesh:
    """Small mesh for CPU multi-device tests (run under
    XLA_FLAGS=--xla_force_host_platform_device_count=N)."""
    return _mesh((data, model), ("data", "model"))
