"""Re-run HLO cost analysis over saved dry-run artifacts (no recompilation).

    PYTHONPATH=src python -m repro.launch.reanalyze --dir results/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch.hlo_costing import analyze


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    n = 0
    for fn in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        rec = json.load(open(fn))
        hlo_file = rec.get("hlo_file")
        if not hlo_file or not os.path.exists(hlo_file):
            continue
        rec["hlo_cost"] = analyze(open(hlo_file).read(), rec["n_devices"])
        with open(fn, "w") as f:
            json.dump(rec, f, indent=2)
        n += 1
    print(f"re-analyzed {n} cells")


if __name__ == "__main__":
    main()
