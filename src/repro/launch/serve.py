"""Serving driver: LeoAM three-tier engine over a (smoke) model.

    PYTHONPATH=src python -m repro.launch.serve --arch longchat-7b-32k \
        --prompt-len 200 --gen 16

Prints generated tokens plus the tier-traffic audit (the live analogue of
the paper's Fig. 11/16 numbers).  Production decode on the pod mesh uses
``launch.steps.make_jitted_decode`` (see dryrun.py / EXPERIMENTS.md).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving.engine import EngineCfg, LeoAMEngine
from repro.serving.offload import DISK, HOST


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="longchat-7b-32k")
    ap.add_argument("--prompt-len", type=int, default=200)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--rate", type=float, default=0.2)
    ap.add_argument("--selection", default="tree", choices=["tree", "flat"])
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=args.rate,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    eng = LeoAMEngine(cfg, params,
                      EngineCfg(max_len=args.max_len,
                                selection=args.selection))
    rng = np.random.RandomState(0)
    prompt = rng.randint(2, cfg.vocab_size, args.prompt_len)
    t0 = time.perf_counter()
    toks = eng.generate(prompt, args.gen)
    dt = time.perf_counter() - t0
    print(f"generated {len(toks)} tokens in {dt:.2f}s: {toks}")
    log = eng.store.log
    print("tier traffic (MiB):")
    for (src, dst, kind), b in sorted(log.bytes.items()):
        print(f"  {src:>6s} -> {dst:6s} [{kind:10s}] {b / 2**20:8.3f}")
    ev = np.mean([s.evaluations for s in eng.stats]) if eng.stats else 0
    print(f"mean evaluations/step: {ev:.0f} "
          f"(token-level would be {eng.length * len(eng.attn_layers)})")
    eng.store.close()


if __name__ == "__main__":
    main()
