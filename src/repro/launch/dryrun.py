import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell the right step (train_step / prefill / serve decode_step) is
``.lower().compile()``-ed against ShapeDtypeStruct inputs on the production
mesh; we print ``memory_analysis`` (fits-per-device proof) and
``cost_analysis``, and persist a JSON record with the trip-count-scaled HLO
costs (repro.launch.hlo_costing) for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod] [--both]
  python -m repro.launch.dryrun ... --out results/dryrun

The XLA_FLAGS line above must run before ANY other import (jax locks the
device count on first init) — hence its position.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ASSIGNED, SHAPES, get_config, get_shape
from repro.configs.base import ArchConfig, ShapeCfg
from repro.launch import steps as stp
from repro.launch.hlo_costing import analyze
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.models import lm


def runtime_overrides(cfg: ArchConfig, shape: ShapeCfg, mesh) -> ArchConfig:
    """Per-cell execution knobs: grad-accumulation depth targets ~2
    sequences per device per microbatch (activation-memory bound)."""
    rt = cfg.runtime
    if shape.kind == "train":
        from repro.sharding.partition import fsdp_axes, mesh_extent
        gb = shape.global_batch
        per_dev = gb // mesh_extent(mesh, fsdp_axes(mesh))
        # explicit config microbatches win; otherwise target ~2 seqs/device
        nm = rt.microbatches if rt.microbatches > 1 else max(per_dev // 2, 1)
        nm = min(nm, gb)
        while gb % nm:
            nm -= 1
        rt = dataclasses.replace(rt, microbatches=nm)
    return dataclasses.replace(cfg, runtime=rt)


def lower_cell(arch: str, shape_name: str, mesh, *, save_hlo: Optional[str]
               ) -> Dict:
    shape = get_shape(shape_name)
    cfg = runtime_overrides(get_config(arch), shape, mesh)
    n_dev = mesh.devices.size
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "x".join(map(str, mesh.devices.shape)),
                 "n_devices": int(n_dev), "kind": shape.kind}
    t0 = time.time()
    with set_mesh(mesh):
        if shape.kind == "train":
            jitted, ss, bspec = stp.make_jitted_train_step(
                cfg, mesh, stp.TrainCfg(), shape)
            state = stp.abstract_state(cfg, stp.TrainCfg())
            batch = stp.input_specs(cfg, shape)["batch"]
            lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            jitted = stp.make_jitted_prefill(cfg, mesh, shape)
            params = lm.abstract_params(cfg)
            batch = stp.input_specs(cfg, shape)["batch"]
            lowered = jitted.lower(params, batch)
        else:  # decode
            jitted = stp.make_jitted_decode(cfg, mesh, shape)
            params = lm.abstract_params(cfg)
            spec = stp.input_specs(cfg, shape)
            lowered = jitted.lower(params, spec["cache"], spec["batch"],
                                   spec["length"])
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes_per_device": int(ma.argument_size_in_bytes
                                     + ma.output_size_in_bytes
                                     + ma.temp_size_in_bytes
                                     - ma.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    rec["xla_cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    hlo = compiled.as_text()
    rec["hlo_cost"] = analyze(hlo, n_devices=n_dev)
    if save_hlo:
        os.makedirs(save_hlo, exist_ok=True)
        fn = os.path.join(save_hlo, f"{arch}__{shape_name}__{rec['mesh']}.hlo")
        with open(fn, "w") as f:
            f.write(hlo)
        rec["hlo_file"] = fn
    print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: "
          f"peak={rec['memory']['peak_bytes_per_device']/2**30:.2f} GiB/dev "
          f"xla_flops={rec['xla_cost']['flops']:.3e} "
          f"hlo_flops={rec['hlo_cost']['flops']:.3e} "
          f"coll={rec['hlo_cost']['total_collective_bytes']/2**20:.1f} MiB "
          f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)")
    print("  memory_analysis:", ma)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run single-pod AND multi-pod meshes")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = []
    if args.both:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for mesh in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}__{'x'.join(map(str, mesh.devices.shape))}"
                try:
                    rec = lower_cell(arch, shape, mesh, save_hlo=args.save_hlo)
                    with open(os.path.join(args.out, tag + ".json"), "w") as f:
                        json.dump(rec, f, indent=2)
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    traceback.print_exc()
                    if args.fail_fast:
                        raise
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err[:200])
        raise SystemExit(1)
    print("\n[dryrun] all cells compiled OK")


if __name__ == "__main__":
    main()
