"""Step builders: jit-able train / prefill / decode steps with full sharding
specs, plus ``input_specs`` (ShapeDtypeStruct stand-ins) for every
(arch × shape) dry-run cell.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCfg
from repro.models import lm
from repro.models.attention import DecodeCtx
from repro.models.params import axes_tree
from repro.optim import adamw
from repro.optim.schedule import SCHEDULES
from repro.sharding import partition as pt
from repro.sharding.ctx import sharding_ctx


@dataclass(frozen=True)
class TrainCfg:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "warmup_cosine"
    adam: adamw.AdamWCfg = dataclasses.field(default_factory=adamw.AdamWCfg)


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------


def param_specs(cfg: ArchConfig, mesh: Mesh):
    defs = lm.param_defs(cfg)
    shapes = lm.abstract_params(cfg)
    return pt.spec_tree(axes_tree(defs), shapes, mesh, pt.rules_for(cfg))


def param_shardings(cfg: ArchConfig, mesh: Mesh):
    return jax.tree.map(lambda p: NamedSharding(mesh, p),
                        param_specs(cfg, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def state_specs(cfg: ArchConfig, mesh: Mesh, tcfg: TrainCfg):
    ps = param_specs(cfg, mesh)
    return {"params": ps, "opt": {"m": ps, "v": ps, "step": P()}}


def decode_rules(cfg: ArchConfig, mesh: Mesh, shape: ShapeCfg) -> Dict:
    seq_axes = pt.seq_shard_axes(mesh, shape.global_batch)
    batch_axes = pt.decode_batch_axes(mesh, shape.global_batch)
    return pt.rules_for(cfg, {"kv_seq": seq_axes, "batch": batch_axes})


def cache_specs(cfg: ArchConfig, mesh: Mesh, shape: ShapeCfg):
    rules = decode_rules(cfg, mesh, shape)
    defs = lm.cache_defs(cfg, shape.global_batch, shape.seq_len)
    shapes = lm.abstract_cache(cfg, shape.global_batch, shape.seq_len)
    return pt.spec_tree(axes_tree(defs), shapes, mesh, rules)


def decode_ctx(cfg: ArchConfig, mesh: Optional[Mesh], shape: ShapeCfg) -> DecodeCtx:
    if mesh is None:
        return DecodeCtx()
    return DecodeCtx(mesh=mesh,
                     seq_axes=pt.seq_shard_axes(mesh, shape.global_batch),
                     batch_axes=pt.decode_batch_axes(mesh, shape.global_batch))


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins; weak-type-correct, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeCfg) -> Dict[str, Any]:
    """ShapeDtypeStructs for one (arch, shape) cell's step inputs."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    if shape.kind == "train":
        batch: Dict[str, Any] = {}
        if cfg.embed_inputs and not cfg.is_encdec:
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
            if cfg.rope == "mrope":
                batch["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.is_encdec:
            enc_len = lm.encoder_len(cfg, S)
            batch["embeds"] = jax.ShapeDtypeStruct((B, enc_len, cfg.d_model), dt)
        batch["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {}
        if cfg.embed_inputs and not cfg.is_encdec:
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
            if cfg.rope == "mrope":
                batch["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.is_encdec:
            enc_len = lm.encoder_len(cfg, S)
            batch["embeds"] = jax.ShapeDtypeStruct((B, enc_len, cfg.d_model), dt)
        return {"batch": batch}
    # decode: one new token against a cache of S
    cache = lm.abstract_cache(cfg, B, S)
    return {
        "cache": cache,
        "batch": {"token": jax.ShapeDtypeStruct((B,), i32)},
        "length": jax.ShapeDtypeStruct((), i32),
    }


def batch_specs_tree(cfg: ArchConfig, mesh: Mesh, batch: Dict[str, Any],
                     batch_axes: Tuple[str, ...]) -> Dict[str, P]:
    """PartitionSpecs for a train/prefill batch dict."""
    out = {}
    for k, v in batch.items():
        if k == "positions":              # (3, B, S)
            out[k] = P(None, batch_axes or None, None)
        else:
            out[k] = P(batch_axes or None, *([None] * (len(v.shape) - 1)))
    return out


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, tcfg: TrainCfg, mesh: Optional[Mesh] = None):
    """Returns train_step(state, batch) -> (state, metrics)."""
    sched = SCHEDULES[tcfg.schedule]
    nm = max(1, cfg.runtime.microbatches)

    def loss_fn(params, mb):
        return lm.forward_train(params, cfg, mb)

    def train_step_body(state, batch):
        params = state["params"]

        def reshape_mb(x):
            return x.reshape(nm, x.shape[0] // nm, *x.shape[1:])

        def reshape_pos(x):                      # (3, B, S) -> (nm, 3, b, S)
            return jnp.swapaxes(
                x.reshape(x.shape[0], nm, x.shape[1] // nm, *x.shape[2:]), 0, 1)

        mbs = {k: (reshape_pos(v) if k == "positions" else reshape_mb(v))
               for k, v in batch.items()}

        gzero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.runtime.adam_dtype)
                                if cfg.runtime.adam_dtype != "float32"
                                else jnp.float32), params)
        mzero = {"loss": jnp.zeros((), jnp.float32),
                 "accuracy": jnp.zeros((), jnp.float32)}

        def micro(carry, mb):
            gsum, msum = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            gsum = jax.tree.map(lambda a, g: a + g.astype(a.dtype), gsum, grads)
            msum = {k: msum[k] + metrics[k].astype(jnp.float32) for k in msum}
            return (gsum, msum), None

        if nm > 1:
            (gsum, msum), _ = jax.lax.scan(micro, (gzero, mzero), mbs)
        else:
            (gsum, msum), _ = micro((gzero, mzero),
                                    jax.tree.map(lambda x: x[0], mbs))
        lr = sched(state["opt"]["step"], peak_lr=tcfg.lr,
                   warmup_steps=tcfg.warmup_steps,
                   total_steps=tcfg.total_steps)
        params_new, opt_new, om = adamw.apply_updates(
            params, gsum, state["opt"], tcfg.adam, lr, grad_scale=1.0 / nm)
        metrics = {k: v / nm for k, v in msum.items()}
        metrics.update(om)
        metrics["lr"] = lr
        return {"params": params_new, "opt": opt_new}, metrics

    if mesh is None:
        return train_step_body

    def train_step(state, batch):
        with sharding_ctx(mesh, pt.rules_for(cfg)):
            return train_step_body(state, batch)

    return train_step


def make_jitted_train_step(cfg: ArchConfig, mesh: Mesh, tcfg: TrainCfg,
                           shape: ShapeCfg):
    """AOT-shardable train step + its (state, batch) in_shardings."""
    adam = dataclasses.replace(tcfg.adam, state_dtype=cfg.runtime.adam_dtype)
    tcfg = dataclasses.replace(tcfg, adam=adam)
    step = make_train_step(cfg, tcfg, mesh)
    ss = state_specs(cfg, mesh, tcfg)
    batch = input_specs(cfg, shape)["batch"]
    bspec = batch_specs_tree(cfg, mesh, batch, pt.batch_axes(mesh))
    in_sh = (jax.tree.map(lambda p: NamedSharding(mesh, p), ss,
                          is_leaf=lambda x: isinstance(x, P)),
             jax.tree.map(lambda p: NamedSharding(mesh, p), bspec,
                          is_leaf=lambda x: isinstance(x, P)))
    out_sh = (in_sh[0], None)
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0,))
    return jitted, ss, bspec


def abstract_state(cfg: ArchConfig, tcfg: TrainCfg):
    adam = dataclasses.replace(tcfg.adam, state_dtype=cfg.runtime.adam_dtype)
    params = lm.abstract_params(cfg)
    return {"params": params, "opt": adamw.abstract_opt_state(params, adam)}


# ---------------------------------------------------------------------------
# Prefill / decode steps
# ---------------------------------------------------------------------------


def make_jitted_prefill(cfg: ArchConfig, mesh: Mesh, shape: ShapeCfg):
    ctx = decode_ctx(cfg, mesh, shape)
    rules = decode_rules(cfg, mesh, shape)

    def prefill_step(params, batch):
        with sharding_ctx(mesh, rules):
            return lm.prefill(params, cfg, batch, max_len=shape.seq_len, ctx=ctx)

    psh = param_shardings(cfg, mesh)
    batch = input_specs(cfg, shape)["batch"]
    bspec = batch_specs_tree(cfg, mesh, batch, pt.batch_axes(mesh))
    bsh = jax.tree.map(lambda p: NamedSharding(mesh, p), bspec,
                       is_leaf=lambda x: isinstance(x, P))
    csh = jax.tree.map(lambda p: NamedSharding(mesh, p),
                       cache_specs(cfg, mesh, shape),
                       is_leaf=lambda x: isinstance(x, P))
    jitted = jax.jit(prefill_step, in_shardings=(psh, bsh),
                     out_shardings=(None, csh))
    return jitted


def make_jitted_decode(cfg: ArchConfig, mesh: Mesh, shape: ShapeCfg):
    ctx = decode_ctx(cfg, mesh, shape)
    rules = decode_rules(cfg, mesh, shape)

    def decode_step(params, cache, batch, length):
        with sharding_ctx(mesh, rules):
            return lm.decode_step(params, cfg, cache, batch, length, ctx=ctx)

    psh = param_shardings(cfg, mesh)
    csh = jax.tree.map(lambda p: NamedSharding(mesh, p),
                       cache_specs(cfg, mesh, shape),
                       is_leaf=lambda x: isinstance(x, P))
    db = pt.decode_batch_axes(mesh, shape.global_batch)
    bsh = {"token": NamedSharding(mesh, P(db or None))}
    lsh = NamedSharding(mesh, P())
    jitted = jax.jit(decode_step, in_shardings=(psh, csh, bsh, lsh),
                     out_shardings=(None, csh), donate_argnums=(1,))
    return jitted
