"""Deterministic synthetic corpus + packing + sharded host loader.

No external datasets ship with the container, so the pipeline generates a
reproducible token stream (hash-seeded Zipf-ish n-gram chains — enough
structure for a small LM to measurably learn) and exercises the full path a
real deployment needs: document sampling → EOS packing → fixed-length
batches → per-host sharding → async device prefetch.
"""

from __future__ import annotations

import threading
import queue as queue_mod
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax
import numpy as np

EOS = 1
PAD = 0


@dataclass(frozen=True)
class DataCfg:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    mean_doc_len: int = 512
    ngram: int = 3


class SyntheticCorpus:
    """Markov-chain documents with a Zipfian unigram backbone."""

    def __init__(self, cfg: DataCfg):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        V = cfg.vocab_size
        ranks = np.arange(2, V)  # 0=pad, 1=eos reserved
        probs = 1.0 / ranks ** 1.1
        self._uni = np.concatenate([[0.0, 0.0], probs / probs.sum()])
        self._uni = self._uni / self._uni.sum()
        # per-context offsets make the stream learnable (hash-mixed bigrams)
        self._mix_a = rng.randint(1, 2**31 - 1)
        self._mix_b = rng.randint(1, 2**31 - 1)

    def document(self, doc_id: int) -> np.ndarray:
        rng = np.random.RandomState((self.cfg.seed * 1_000_003 + doc_id)
                                    % (2**31 - 1))
        n = max(8, int(rng.exponential(self.cfg.mean_doc_len)))
        V = self.cfg.vocab_size
        toks = np.empty(n, np.int32)
        prev = rng.randint(2, V)
        for i in range(n):
            # bigram determinism with unigram noise: next token is a hash of
            # prev 70% of the time -> learnable structure
            if rng.rand() < 0.7:
                t = 2 + (prev * self._mix_a + self._mix_b) % (V - 2)
            else:
                t = rng.choice(V, p=self._uni)
            toks[i] = t
            prev = int(t)
        return toks


def pack_documents(corpus: SyntheticCorpus, seq_len: int, start_doc: int,
                   n_seqs: int) -> Tuple[np.ndarray, int]:
    """Greedy EOS-separated packing into (n_seqs, seq_len+1) buffers."""
    out = np.full((n_seqs, seq_len + 1), PAD, np.int32)
    doc = start_doc
    row, col = 0, 0
    buf = corpus.document(doc)
    off = 0
    while row < n_seqs:
        take = min(len(buf) - off, seq_len + 1 - col)
        out[row, col: col + take] = buf[off: off + take]
        col += take
        off += take
        if off >= len(buf):
            doc += 1
            buf = corpus.document(doc)
            off = 0
            if col < seq_len + 1:
                out[row, col] = EOS
                col += 1
        if col >= seq_len + 1:
            row += 1
            col = 0
    return out, doc


class ShardedLoader:
    """Per-host shard of the global batch with background prefetch."""

    def __init__(self, cfg: DataCfg, *, host_id: int = 0, n_hosts: int = 1,
                 prefetch: int = 2):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // n_hosts
        self.corpus = SyntheticCorpus(cfg)
        self._doc = host_id * 1_000_000  # disjoint doc ranges per host
        self._q: queue_mod.Queue = queue_mod.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _make_batch(self) -> Dict[str, np.ndarray]:
        packed, self._doc = pack_documents(
            self.corpus, self.cfg.seq_len, self._doc, self.local_batch)
        tokens = packed[:, :-1]
        targets = packed[:, 1:].copy()
        targets[targets == PAD] = -1            # ignore padding in the loss
        return {"tokens": tokens, "targets": targets}

    def _worker(self):
        while not self._stop.is_set():
            try:
                self._q.put(self._make_batch(), timeout=0.5)
            except queue_mod.Full:
                continue

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
