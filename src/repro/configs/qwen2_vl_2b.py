"""qwen2-vl-2b — VLM transformer backbone [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 — M-RoPE (3-section
temporal/height/width rotary), dynamic resolution.

Per the assignment spec the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (B, S, d_model) plus 3D M-RoPE position
ids (3, B, S); the backbone here is the real contribution surface.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    act="swiglu",
    rope="mrope",
    rope_theta=1_000_000.0,
    embed_inputs=True,
    tie_embeddings=True,
)
