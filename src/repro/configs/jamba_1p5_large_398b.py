"""jamba-1.5-large-398b — hybrid Mamba+attention MoE [arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Structure: 1:7 attention:Mamba interleave (one attention layer per 8-layer
period, at position 3 as in the released config), MoE on every other layer.
No RoPE — Mamba layers carry position information (per the Jamba paper).

LeoAM applicability: chunk selection runs on the 9 attention layers' KV
caches; Mamba layers keep fixed-size SSM state (no KV to manage).
"""

from repro.configs.base import ArchConfig, LeoAMCfg, MambaCfg, MoECfg, RuntimeCfg

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=65_536,
    act="swiglu",
    rope="none",
    layer_pattern=(
        "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba",
    ),
    mlp_pattern=("dense", "moe"),
    moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=24_576),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
    # unroll the full first period so the first attention layer (global
    # index 3) gets the early/dense LeoAM budget and the scanned body stays
    # pattern-periodic (64 = 8 x 8 layers)
    prologue_layers=8,
    leoam=LeoAMCfg(early_layers=4),   # first attention layer (idx 3) = early
    tie_embeddings=False,
    runtime=RuntimeCfg(microbatches=8, remat="block", adam_dtype="bfloat16",
                       fsdp_params=True, remat_groups=4),
)
