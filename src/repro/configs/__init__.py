"""Config registry: ``--arch <id>`` resolution.

>>> from repro.configs import get_config, list_configs
>>> cfg = get_config("phi4-mini-3.8b")
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    ArchConfig, LeoAMCfg, MLACfg, MambaCfg, MoECfg, RuntimeCfg, ShapeCfg,
    SHAPES, get_shape, smoke_variant, tokens_per_step,
)

# arch id -> module name
_REGISTRY: Dict[str, str] = {
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "nemotron-4-340b": "nemotron4_340b",
    "qwen3-1.7b": "qwen3_1p7b",
    "gemma2-2b": "gemma2_2b",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "xlstm-125m": "xlstm_125m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    # the paper's own evaluation model (LongChat-7B-v1.5-32k, llama arch)
    "longchat-7b-32k": "longchat_7b_32k",
}


def list_configs() -> List[str]:
    return sorted(_REGISTRY)


ASSIGNED = [a for a in sorted(_REGISTRY) if a != "longchat-7b-32k"]


def get_config(name: str, *, smoke: bool = False) -> ArchConfig:
    if name.endswith("-smoke"):
        name, smoke = name[: -len("-smoke")], True
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {list_configs()}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[name]}")
    cfg: ArchConfig = mod.CONFIG
    return smoke_variant(cfg) if smoke else cfg
