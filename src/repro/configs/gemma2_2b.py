"""gemma2-2b — local+global alternating attention [arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000 — sliding-window (4096)
local layers alternate with full-attention global layers; GeGLU; attention and
final-logit softcapping.

LeoAM applicability: sparse decode selection runs on the *global* layers;
local layers already touch only the window (see DESIGN.md §4).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    act="geglu",
    rope="rope",
    rope_theta=10_000.0,
    window=4096,
    layer_pattern=("attn_local", "attn_global"),
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
)
