"""longchat-7b-32k — the paper's own evaluation model (LLaMA-7B arch,
rope-scaled to 32k) [hf:lmsys/longchat-7b-v1.5-32k].

Used by the LeoAM serving benchmarks to mirror the paper's latency tables.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="longchat-7b-32k",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11_008,
    vocab_size=32_000,
    act="swiglu",
    rope="rope",
    rope_theta=10_000.0,
    tie_embeddings=False,
)
