"""xlstm-125m — sLSTM + mLSTM recurrent blocks [arXiv:2405.04517; unverified].

12L d_model=768 4H d_ff=0 vocab=50304.  Block mix: 2 mLSTM : 1 sLSTM period
(8 mLSTM + 4 sLSTM over 12 layers; the paper's 125M uses a small sLSTM
fraction — documented deviation, the assigned spec fixes only the totals).
d_ff=0: xLSTM blocks carry their own up/down projections, no separate FFN.

LeoAM applicability: NOT APPLICABLE — there is no KV cache; state is a
fixed-size matrix memory per head.  Implemented without the technique
(DESIGN.md §4 Arch-applicability).  ``long_500k`` runs on the native
recurrence (mLSTM chunkwise-parallel for train/prefill, stepwise for decode).
"""

from repro.configs.base import ArchConfig, LeoAMCfg

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50_304,
    act="swiglu",
    rope="none",
    layer_pattern=("mlstm", "mlstm", "slstm"),
    mlp_pattern=("none",),
    leoam=LeoAMCfg(enabled=False),
    tie_embeddings=True,
)
