"""moonshot-v1-16b-a3b — fine-grained MoE (kimi/moonlight)
[hf:moonshotai/Moonlight-16B-A3B; hf].

48L d_model=2048 16H (GQA kv=16) d_ff=1408(expert) vocab=163840,
MoE 64e top-6.  Details filled from the public Moonlight config: 2 shared
experts, first layer dense (d_ff 11264), rope_theta 50000.
"""

from repro.configs.base import RuntimeCfg, ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=11_264,            # dense prologue FFN width
    d_ff_dense=11_264,
    vocab_size=163_840,
    act="swiglu",
    rope="rope",
    rope_theta=50_000.0,
    mlp_pattern=("moe",),
    first_dense=1,
    moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    tie_embeddings=False,
    runtime=RuntimeCfg(adam_dtype="bfloat16", fsdp_params=True),
)
