"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone
[arXiv:2308.11596; hf].

24L(enc) + 24L(dec) d_model=1024 16H d_ff=8192 vocab=256206.  The audio
frontend (conformer feature extractor) is a STUB per the assignment spec:
``input_specs()`` provides precomputed frame embeddings (B, S_enc, d) for the
encoder; the decoder is an autoregressive text decoder with cross-attention.

Adaptation note: sinusoidal positions are replaced with RoPE so the decode
shapes (32k/500k self-attention cache) remain position-generalizable; this is
a documented deviation (DESIGN.md §7).  Decode shapes exercise the decoder
self-attention cache (the cross-attention KV is static per request and
tier-resident, not re-selected).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    enc_layers=24,
    cross_attn=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    act="relu",
    rope="rope",
    rope_theta=10_000.0,
    embed_inputs=True,
    tie_embeddings=True,
)
