"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434; hf].

27L d_model=2048 16H d_ff=1408(expert) vocab=102400, MLA kv_lora=512,
2 shared + 64 routed experts top-6 (the assigned-spec comment's "160 routed"
is the full DeepSeek-V2; the Lite config verified on HF uses 64 routed, which
matches the "MoE 64e top-6" header we follow).  First layer dense (d_ff
10944).  MLA dims from the paper: qk_nope 128, qk_rope 64, v 128.

LeoAM adaptation: KV abstracts are min/max boxes over the *compressed latent*
c_kv (rank 512) + the shared rope key; bounds are computed in latent space
after absorbing W_UK into the query (DESIGN.md §4).
"""

from repro.configs.base import RuntimeCfg, ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,          # MLA: logical kv heads == q heads
    head_dim=128,
    d_ff=10_944,            # dense prologue FFN width
    d_ff_dense=10_944,
    vocab_size=102_400,
    act="swiglu",
    rope="rope",
    rope_theta=10_000.0,
    mlp_pattern=("moe",),
    first_dense=1,
    moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    mla=MLACfg(kv_lora_rank=512, q_lora_rank=None,
               qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    tie_embeddings=False,
    runtime=RuntimeCfg(adam_dtype="bfloat16", fsdp_params=True),
)
