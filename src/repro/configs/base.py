"""Architecture / shape / runtime configuration schema.

Every assigned architecture gets one module in ``repro.configs`` exporting a
``CONFIG: ArchConfig``.  The registry in ``repro.configs.__init__`` resolves
``--arch <id>`` strings.  ``smoke_variant`` derives a reduced config of the
same *family* (same layer pattern / block kinds, tiny dims) for CPU tests.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoECfg:
    """Mixture-of-experts block config (GShard-style dense dispatch)."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0              # shared (always-on) experts
    capacity_factor: float = 1.25
    aux_loss_weight: float = 1e-2
    router_z_weight: float = 1e-3


@dataclass(frozen=True)
class MLACfg:
    """DeepSeek multi-head latent attention dims."""

    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None   # None => direct q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None       # None => ceil(d_model/16)


@dataclass(frozen=True)
class LeoAMCfg:
    """Paper-technique knobs (IAKM / LKA / DTP). §4 of the paper."""

    enabled: bool = True
    chunk_size: int = 64            # initial chunk size (paper default, §6.1)
    early_chunk_size: int = 8       # finer chunks for early layers (§6.1)
    importance_rate: float = 0.10   # fraction of KV loaded (paper default)
    early_layers: int = 2           # first-K layers: denser attention (§4.3)
    early_rate: float = 0.50        # 50% budget on the first two layers (§6.1)
    sink_chunks: int = 1            # always-resident leading chunks
    recent_chunks: int = 2          # always-resident trailing chunks
    pyramid_levels: int = 3         # abstract pyramid depth (TPU adaptation)
    refine_factor: int = 2          # candidate multiplier per pyramid level
    compression: str = "int4"       # transit compression codec
    min_seq_for_sparse: int = 1024  # below this, dense decode is cheaper


@dataclass(frozen=True)
class RuntimeCfg:
    """Per-(arch x shape) execution knobs; overridable from launch scripts."""

    microbatches: int = 1           # grad-accumulation steps (scan)
    remat: str = "block"            # none | block  (full block recompute)
    adam_dtype: str = "float32"     # Adam m/v dtype (bf16 for 100B+ archs)
    # FSDP-shard parameter embed dims over the data axes.  Off for archs
    # whose params+opt fit replicated-over-data (pure TP+DP — no per-layer
    # weight all-gathers); on for the frontier archs that need it.
    fsdp_params: bool = False
    # Two-level (sqrt-N) recursive remat: outer scan over this many layer
    # groups, inner scan rematted per layer.  Cuts loop-carry activation
    # memory from O(L) to O(G + L/G) at ~one extra forward of recompute.
    # None => single-level remat.
    remat_groups: Optional[int] = None
    scan_layers: bool = True        # lax.scan over layer groups
    attn_block_q: int = 512         # blocked-attention query tile
    attn_block_kv: int = 1024       # blocked-attention kv tile
    seq_shard_decode: bool = True   # shard KV sequence for decode shapes
    exact_global_topk: bool = False # exact (all-gather bounds) chunk top-k


# ---------------------------------------------------------------------------
# Main architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int                   # decoder layers
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                       # dense FFN width (0 => no FFN, e.g. xLSTM)
    vocab_size: int

    head_dim: Optional[int] = None  # default: d_model // n_heads
    act: str = "swiglu"             # swiglu | relu2 | geglu
    norm_eps: float = 1e-5
    qk_norm: bool = False
    rope: str = "rope"              # rope | mrope | none
    rope_theta: float = 10_000.0
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    window: Optional[int] = None    # local-attention window (tokens)

    # Layer pattern: block kind per layer position within one period.
    # Kinds: "attn" | "attn_local" | "attn_global" | "mamba" | "mlstm" | "slstm"
    layer_pattern: Tuple[str, ...] = ("attn",)
    # MLP kind per period position: "dense" | "moe" | "none"
    mlp_pattern: Tuple[str, ...] = ("dense",)
    first_dense: int = 0            # prologue: first-K layers forced dense MLP
    # Layers unrolled before the scanned body (None => max(first_dense,
    # leoam.early_layers)).  Must leave a pattern-periodic remainder.
    prologue_layers: Optional[int] = None

    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    mamba: Optional[MambaCfg] = None

    enc_layers: int = 0             # >0 => encoder-decoder
    cross_attn: bool = False        # decoder cross-attention (enc-dec)
    embed_inputs: bool = False      # modality stub: prefill/train take embeds
    tie_embeddings: bool = True
    d_ff_dense: Optional[int] = None  # FFN width of prologue dense layers

    leoam: LeoAMCfg = field(default_factory=LeoAMCfg)
    runtime: RuntimeCfg = field(default_factory=RuntimeCfg)
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    # Derived properties
    # ------------------------------------------------------------------

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Block kind for every decoder layer."""
        p = self.layer_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def mlp_kinds(self) -> Tuple[str, ...]:
        p = self.mlp_pattern
        kinds = [p[i % len(p)] for i in range(self.n_layers)]
        for i in range(min(self.first_dense, self.n_layers)):
            if kinds[i] == "moe":
                kinds[i] = "dense"
        return tuple(kinds)

    def prologue(self) -> int:
        """Unrolled leading layers (early-layer LeoAM budgets / dense MLPs)."""
        if self.prologue_layers is not None:
            return min(self.prologue_layers, self.n_layers)
        early = self.leoam.early_layers if self.leoam.enabled else 0
        return min(max(self.first_dense, early), self.n_layers)

    def period(self) -> int:
        """Smallest repeating period of (layer, mlp) kinds after the prologue."""
        kinds = list(zip(self.layer_kinds(), self.mlp_kinds()))[self.prologue():]
        n = len(kinds)
        if n == 0:
            return 1
        for p in range(1, n + 1):
            if n % p == 0 and all(kinds[i] == kinds[i % p] for i in range(n)):
                return p
        return n

    def n_params(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.hd
        total = 0
        kinds, mlps = self.layer_kinds(), self.mlp_kinds()
        for kind, mlp in zip(kinds, mlps):
            total += self._block_params(kind)
            total += self._mlp_params(mlp)
            total += 2 * d  # two RMSNorm scales
        if self.is_encdec:
            for _ in range(self.enc_layers):
                total += self._block_params("attn") + self._mlp_params("dense") + 2 * self.d_model
            total += self.n_layers * (self._block_params("attn") + self.d_model)  # cross attn
        total += self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d
        total += d  # final norm
        return total

    def n_active_params(self) -> int:
        """Per-token activated params (MoE counts shared + top_k experts)."""
        d = self.d_model
        total = 0
        for kind, mlp in zip(self.layer_kinds(), self.mlp_kinds()):
            total += self._block_params(kind)
            if mlp == "moe":
                assert self.moe is not None
                m = self.moe
                per_e = self._ffn_params(m.d_ff_expert)
                total += (m.top_k + m.n_shared) * per_e + d * m.n_experts
            else:
                total += self._mlp_params(mlp)
            total += 2 * d
        if self.is_encdec:
            for _ in range(self.enc_layers):
                total += self._block_params("attn") + self._mlp_params("dense") + 2 * d
            total += self.n_layers * (self._block_params("attn") + d)
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total

    def _ffn_params(self, ff: int) -> int:
        d = self.d_model
        if ff == 0:
            return 0
        gated = self.act in ("swiglu", "geglu")
        return d * ff * (3 if gated else 2)

    def _mlp_params(self, mlp_kind: str) -> int:
        if mlp_kind == "none" or self.d_ff == 0:
            return 0
        if mlp_kind == "moe":
            assert self.moe is not None
            m = self.moe
            per_e = self._ffn_params(m.d_ff_expert)
            return m.n_experts * per_e + m.n_shared * per_e + self.d_model * m.n_experts
        ff = self.d_ff_dense if (mlp_kind == "dense" and self.d_ff_dense) else self.d_ff
        return self._ffn_params(ff)

    def _block_params(self, kind: str) -> int:
        d, hd = self.d_model, self.hd
        if kind.startswith("attn"):
            if self.mla is not None:
                c = self.mla
                qk = c.qk_nope_head_dim + c.qk_rope_head_dim
                q_p = (d * c.q_lora_rank + c.q_lora_rank * self.n_heads * qk
                       if c.q_lora_rank else d * self.n_heads * qk)
                kv_down = d * (c.kv_lora_rank + c.qk_rope_head_dim)
                kv_up = c.kv_lora_rank * self.n_heads * (c.qk_nope_head_dim + c.v_head_dim)
                o_p = self.n_heads * c.v_head_dim * d
                return q_p + kv_down + kv_up + o_p
            return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if kind == "mamba":
            assert self.mamba is not None
            m = self.mamba
            d_in = m.expand * d
            dt_rank = m.dt_rank or -(-d // 16)
            return (d * 2 * d_in + d_in * m.d_conv + d_in * (dt_rank + 2 * m.d_state)
                    + dt_rank * d_in + d_in * m.d_state + d_in + d_in * d)
        if kind == "mlstm":
            d_in = 2 * d
            # up proj (x,z), q/k/v projs on d_in, gates, out proj
            return d * 2 * d_in + 3 * d_in * d_in // 1 + 2 * d_in + d_in * d
        if kind == "slstm":
            # recurrent + input weights for 4 gates + ffn-ish proj
            return 8 * d * d + 4 * d
        raise ValueError(kind)


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set; identical for all 10 LM-family archs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> ShapeCfg:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}") from None


# ---------------------------------------------------------------------------
# Smoke variants
# ---------------------------------------------------------------------------


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced config of the same family: same layer/mlp pattern, tiny dims."""
    period = cfg.period()
    prologue = cfg.prologue()
    # always keep >=1 scanned body repeat so the scan path is exercised
    n_layers = prologue + period * (2 if period * 2 + prologue <= 6 else 1)
    n_heads = 4
    n_kv = min(cfg.n_kv_heads, n_heads)
    if n_heads % n_kv:
        n_kv = 2
    kw = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        d_ff_dense=None if cfg.d_ff_dense is None else 160,
        vocab_size=512,
        enc_layers=0 if cfg.enc_layers == 0 else 2,
        window=None if cfg.window is None else 64,
        leoam=dataclasses.replace(
            cfg.leoam, chunk_size=8, early_chunk_size=4, pyramid_levels=2,
            min_seq_for_sparse=32, sink_chunks=1, recent_chunks=1),
        runtime=dataclasses.replace(cfg.runtime, microbatches=1, remat="none"),
        dtype="float32",
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=64,
            n_shared=min(cfg.moe.n_shared, 1))
    if cfg.mla is not None:
        kw["mla"] = MLACfg(kv_lora_rank=32, q_lora_rank=None,
                           qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
    if cfg.mamba is not None:
        kw["mamba"] = MambaCfg(d_state=8, d_conv=4, expand=2, dt_rank=8)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)


def tokens_per_step(shape: ShapeCfg) -> int:
    if shape.kind == "train":
        return shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return shape.seq_len * shape.global_batch
    return shape.global_batch  # decode: one token per sequence
