"""nemotron-4-340b — dense GQA decoder [arXiv:2402.16819; unverified].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000 — GQA, squared-ReLU.
Frontier-scale dense arch; training uses bf16 Adam states + aggressive
microbatching (see runtime overrides in launch/dryrun.py).
"""

import dataclasses

from repro.configs.base import ArchConfig, RuntimeCfg

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73_728,
    vocab_size=256_000,
    act="relu2",
    rope="rope",
    rope_theta=10_000.0,
    tie_embeddings=False,
    # 6 unrolled prologue layers leave a 90-layer body = 9 groups x 10
    # layers for sqrt-N remat; 8 microbatches balance FSDP re-gather traffic
    # (collective term scales with microbatch count; see §Perf A1) vs carries
    prologue_layers=6,
    runtime=RuntimeCfg(microbatches=8, remat="block", adam_dtype="bfloat16",
                       fsdp_params=True, remat_groups=9),
)
