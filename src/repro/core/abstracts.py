"""Lightweight KV Abstracts (paper §4.3) and the abstract *pyramid*.

An abstract of a KV chunk is the element-wise (min, max) of its key vectors —
two vectors per chunk regardless of chunk size.  The paper stores abstracts
on disk next to the full KV so importance evaluation reads ``2/n'`` of the
data.  Our TPU adaptation additionally stacks abstracts into a segment-tree
**pyramid** (level *l* merges 2^l base chunks), which is what makes the
IAKM merge/split tree expressible with static shapes on the device: staying
at a coarse level *is* the paper's "merge", descending *is* its "split".
"""

from __future__ import annotations

import math
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30  # sentinel for "no key present" (max side); min side uses +1e30


class Pyramid(NamedTuple):
    """Per-level (kmax, kmin); level l arrays: (B, nc0 >> l, Hkv, hd)."""

    kmax: Tuple[jax.Array, ...]
    kmin: Tuple[jax.Array, ...]

    @property
    def levels(self) -> int:
        return len(self.kmax)

    @property
    def base_chunks(self) -> int:
        return self.kmax[0].shape[1]


def num_levels(n_chunks: int, requested: int) -> int:
    """Levels usable for a power-of-two divisible chunk count."""
    lv = 1
    while lv < requested and n_chunks % (1 << lv) == 0 and (n_chunks >> lv) >= 2:
        lv += 1
    return lv


def chunk_minmax(k: jax.Array, chunk: int,
                 length: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Base-level abstracts.

    k: (B, S, Hkv, hd) roped keys; S % chunk == 0 (caller pads).
    length: optional valid length (B,) or scalar — positions >= length are
    excluded (masked to ∓inf sentinels so they never win a bound).
    """
    B, S, Hkv, hd = k.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    kc = k.reshape(B, nc, chunk, Hkv, hd).astype(jnp.float32)
    if length is not None:
        pos = jnp.arange(S).reshape(nc, chunk)
        valid = (pos[None] < jnp.reshape(length, (-1, 1, 1)))[..., None, None]
        kmax = jnp.max(jnp.where(valid, kc, NEG), axis=2)
        kmin = jnp.min(jnp.where(valid, kc, -NEG), axis=2)
    else:
        kmax = jnp.max(kc, axis=2)
        kmin = jnp.min(kc, axis=2)
    return kmax, kmin


def build_pyramid(k: jax.Array, chunk: int, levels: int,
                  length: Optional[jax.Array] = None) -> Pyramid:
    kmax0, kmin0 = chunk_minmax(k, chunk, length)
    levels = num_levels(kmax0.shape[1], levels)
    kmaxs, kmins = [kmax0], [kmin0]
    for _ in range(1, levels):
        km, kn = kmaxs[-1], kmins[-1]
        B, nc, Hkv, hd = km.shape
        kmaxs.append(jnp.max(km.reshape(B, nc // 2, 2, Hkv, hd), axis=2))
        kmins.append(jnp.min(kn.reshape(B, nc // 2, 2, Hkv, hd), axis=2))
    return Pyramid(tuple(kmaxs), tuple(kmins))


def update_pyramid(pyr: Pyramid, k_new: jax.Array, pos: jax.Array,
                   chunk: int) -> Pyramid:
    """Incremental decode-step update: fold one new key into its chunk.

    k_new: (B, Hkv, hd) the roped key of the token written at position
    ``pos`` (scalar int32); ``chunk`` is the base chunk size.  Touches one
    node per level — O(levels) work, matching the paper's claim that abstract
    maintenance is negligible (§6.5: 1.56% of system overhead).
    """
    kmaxs, kmins = [], []
    k32 = k_new.astype(jnp.float32)[:, None]
    for lvl in range(pyr.levels):
        span = chunk << lvl
        km, kn = pyr.kmax[lvl], pyr.kmin[lvl]
        idx = (pos // span).astype(jnp.int32)
        old_max = jax.lax.dynamic_slice_in_dim(km, idx, 1, axis=1)
        old_min = jax.lax.dynamic_slice_in_dim(kn, idx, 1, axis=1)
        kmaxs.append(jax.lax.dynamic_update_slice_in_dim(
            km, jnp.maximum(old_max, k32), idx, axis=1))
        kmins.append(jax.lax.dynamic_update_slice_in_dim(
            kn, jnp.minimum(old_min, k32), idx, axis=1))
    return Pyramid(tuple(kmaxs), tuple(kmins))


def abstract_bytes(pyr: Pyramid) -> int:
    return sum(int(math.prod(a.shape)) * a.dtype.itemsize
               for a in (*pyr.kmax, *pyr.kmin))
