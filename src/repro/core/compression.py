"""KV transit compression (paper §4.4 "Dynamic KV compression").

The paper stores KV in FP16 and compresses to INT4 for transmission.  We
implement symmetric per-(chunk, channel) int8 and int4 quantization; int4
packs two nibbles per byte.  ``repro.kernels.kv_quant`` provides the fused
dequantize-on-load Pallas kernel; this module is the reference/runtime codec
used by the offload engine.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class QuantizedKV(NamedTuple):
    data: jax.Array       # int8 payload (packed for int4)
    scale: jax.Array      # f32 per-(group, channel) scales
    codec: str            # "int8" | "int4"
    shape: Tuple[int, ...]  # original shape

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.data.shape)) + int(np.prod(self.scale.shape)) * 4


def _group_reshape(x: jax.Array, group: int) -> jax.Array:
    """(..., S, d) -> (..., S//group, group, d)."""
    *lead, S, d = x.shape
    assert S % group == 0, (S, group)
    return x.reshape(*lead, S // group, group, d)


def quantize(x: jax.Array, codec: str = "int4", group: int = 64) -> QuantizedKV:
    orig_shape = tuple(x.shape)
    g = _group_reshape(x.astype(jnp.float32), group)
    amax = jnp.max(jnp.abs(g), axis=-2, keepdims=True)          # per channel
    qmax = 127.0 if codec == "int8" else 7.0
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax).astype(jnp.int8)
    q = q.reshape(orig_shape)
    scale = scale[..., 0, :]                                    # (..., S/g, d)
    if codec == "int4":
        # pack along the channel dim: two nibbles per byte
        *lead, S, d = orig_shape
        assert d % 2 == 0
        q = q.reshape(*lead, S, d // 2, 2)
        lo = (q[..., 0] & 0xF).astype(jnp.uint8)
        hi = ((q[..., 1] & 0xF) << 4).astype(jnp.uint8)
        q = (lo | hi).astype(jnp.int8)
    return QuantizedKV(q, scale.astype(jnp.float32), codec, orig_shape)


def dequantize(qkv: QuantizedKV, group: int = 64,
               dtype=jnp.bfloat16) -> jax.Array:
    q = qkv.data
    if qkv.codec == "int4":
        u = q.astype(jnp.uint8)
        lo = (u & 0xF).astype(jnp.int8)
        hi = ((u >> 4) & 0xF).astype(jnp.int8)
        # sign-extend 4-bit two's complement
        lo = jnp.where(lo > 7, lo - 16, lo)
        hi = jnp.where(hi > 7, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=-1).reshape(qkv.shape)
    g = _group_reshape(q.astype(jnp.float32), group)
    out = g * qkv.scale[..., None, :]
    return out.reshape(qkv.shape).astype(dtype)


def packed_dim(codec: str, d: int) -> int:
    """Payload channel width of :func:`quantize_chunks` for ``d`` fp16
    channels: int4 packs two nibbles per byte along the channel dim."""
    if codec == "int4":
        assert d % 2 == 0, d
        return d // 2
    assert codec == "int8", codec
    return d


def packed_chunk_bytes(codec: str, chunk: int, d: int) -> int:
    """Exact packed bytes of ONE (chunk, d) plane through
    :func:`quantize_chunks` (int payload + one f32 scale per channel).
    ``2 * packed_chunk_bytes == chunk_bytes * codec_ratio(codec, chunk)``
    for a K+V chunk pair — the sidecar/billing identity the offload store
    relies on (tested)."""
    return chunk * packed_dim(codec, d) + 4 * d


def codec_ratio(codec: str, group: int = 64) -> float:
    """Compressed bytes / fp16 bytes (scales amortized over ``group``).

    Exact for :func:`quantize` on a (..., group, d) tensor: the int payload
    is ``payload`` of the fp16 bytes and each group contributes one f32
    scale per channel (4 bytes per ``group`` fp16 values)."""
    payload = {"int8": 0.5, "int4": 0.25}[codec]
    scale_overhead = 4.0 / (group * 2.0)   # f32 scale per group fp16 values
    return payload + scale_overhead


def quantize_chunks(k: np.ndarray, codec: str = "int4"
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Transit-pack a stack of KV chunks: (n, c, H, hd) -> packed payload.

    Groups along the whole chunk (group == c, one scale per channel per
    chunk), so the packed nbytes are EXACTLY
    ``n * c * H * hd * 2 * codec_ratio(codec, group=c)``.

    Returns (data, scale): data (n, c, H*hd) int8 for int8 or
    (n, c, H*hd//2) packed int8 for int4; scale (n, H*hd) f32 — the layout
    ``repro.kernels.kv_quant`` dequantizes on device.
    """
    n, c, H, hd = k.shape
    d = H * hd
    q = quantize(jnp.asarray(k.reshape(n, c, d)), codec, group=c)
    data = np.asarray(q.data)
    scale = np.asarray(q.scale).reshape(n, d)
    return data, scale


def dequantize_chunks(data: np.ndarray, scale: np.ndarray, codec: str,
                      kv_heads: int, head_dim: int, dtype=np.float16
                      ) -> np.ndarray:
    """Host-side inverse of :func:`quantize_chunks` (reference path)."""
    n, c = data.shape[:2]
    d = kv_heads * head_dim
    q = QuantizedKV(jnp.asarray(data), jnp.asarray(scale)[:, None, :], codec,
                    (n, c, d))
    out = dequantize(q, group=c, dtype=jnp.float32)
    return np.asarray(out).astype(dtype).reshape(n, c, kv_heads, head_dim)


def quantization_rmse(x: np.ndarray, codec: str = "int4",
                      group: int = 64) -> float:
    xq = dequantize(quantize(jnp.asarray(x), codec, group), group, jnp.float32)
    return float(np.sqrt(np.mean((np.asarray(xq) - x) ** 2)))
