"""Attention-desert statistics and the Eq.(2) chunk-size policy (paper §3.5,
§4.2 "Dynamic chunk resizing").

``A(m) = m · Σ_{i=0}^{log2(n/m)-1} (2ρ)^i`` is the expected number of chunk
evaluations when the tree splits with probability ρ (the layer's
important-token density) at each level.  The optimal initial chunk count m*
minimizes A — dense layers (early layers / early decode steps, Insight 2)
get finer initial chunks; sparse layers get coarse ones.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np


def eval_cost(n: int, m: int, rho: float) -> float:
    """A(m) from Eq.(2); n tokens, m initial chunks, density rho."""
    if m >= n:
        return float(n)
    levels = int(math.log2(n // m)) if n % m == 0 else int(math.log2(n / m))
    x = 2.0 * rho
    if abs(x - 1.0) < 1e-9:
        s = levels
    else:
        s = (x ** levels - 1.0) / (x - 1.0)
    return m * max(s, 1.0)


def optimal_chunk_count(n: int, rho: float, *, floor: int = 8,
                        cap: int = 512,
                        candidates: Optional[Sequence[int]] = None) -> int:
    """argmin_m A(m) over power-of-two chunk counts (Eq. 3 extremum).

    When 2ρ >= 1 the geometric series diverges — every split at least
    doubles the work, so descending never pays and the optimum is the
    finest practical granularity (the paper's Insight-2 conclusion: early
    dense layers get initial chunk size 8 instead of 64).
    """
    if 2.0 * rho >= 1.0:
        return max(1, n // floor)
    if candidates is None:
        candidates = [m for m in (1 << i for i in range(
            0, int(math.log2(max(n, 2))) + 1))
            if floor <= n // m <= cap]
        candidates = candidates or [max(1, n // cap)]
    costs = [eval_cost(n, m, rho) for m in candidates]
    return int(candidates[int(np.argmin(costs))])


def optimal_chunk_size(n: int, rho: float, *, floor: int = 8,
                       cap: int = 512) -> int:
    m = optimal_chunk_count(n, rho, floor=floor, cap=cap)
    size = max(1, n // m)
    # clamp to practical sizes (transfer granularity / MXU alignment)
    size = max(floor, min(cap, size))
    # round to power of two
    return 1 << int(round(math.log2(size)))


def desert_rate(importance: np.ndarray, chunk: int, rate: float = 0.10) -> float:
    """Fraction of chunks containing no top-``rate`` token (paper Fig. 7)."""
    n = len(importance)
    k = max(1, int(n * rate))
    top = set(np.argsort(-importance)[:k].tolist())
    n_chunks = math.ceil(n / chunk)
    deserts = 0
    for c in range(n_chunks):
        if not any(t in top for t in range(c * chunk, min((c + 1) * chunk, n))):
            deserts += 1
    return deserts / n_chunks


def layer_density_schedule(n_layers: int, *, early_layers: int = 2,
                           early_rho: float = 0.5, late_rho: float = 0.1
                           ) -> np.ndarray:
    """Offline ρ(l) prior per the paper's Insight 2 (first layers are dense)."""
    rho = np.full(n_layers, late_rho)
    rho[:early_layers] = early_rho
    return rho


def chunk_size_schedule(n: int, n_layers: int, *, early_layers: int = 2,
                        early_rho: float = 0.5, late_rho: float = 0.1,
                        floor: int = 8, cap: int = 512) -> np.ndarray:
    rhos = layer_density_schedule(n_layers, early_layers=early_layers,
                                  early_rho=early_rho, late_rho=late_rho)
    return np.array([optimal_chunk_size(n, r, floor=floor, cap=cap)
                     for r in rhos])
