"""Three-tier KV placement planning + LKA accounting (paper §4.1, §4.3).

The planner decides, per layer, what fraction of KV lives on each tier
(GPU-resident working set / CPU / disk) subject to capacities, implementing
the paper's placement rules:

* the first ``early_layers`` layers never go to disk (their attention is
  dense — §4.3 "KV Management and optimization under LKA");
* a token access-frequency table keeps hot tokens off the disk tier;
* the disk keeps full replicas, so CPU→disk eviction costs no write I/O;
* KV abstracts (2 key vectors per chunk) are stored next to the data.

``lka_transfer_ratio`` is the paper's r = α + 2/n' (Fig. 11).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class TierSpec:
    gpu_bytes: float
    cpu_bytes: float
    disk_bytes: float = float("inf")


@dataclass
class LayerPlacement:
    gpu_frac: float
    cpu_frac: float
    disk_frac: float

    def __post_init__(self):
        s = self.gpu_frac + self.cpu_frac + self.disk_frac
        assert abs(s - 1.0) < 1e-6, s


def lka_transfer_ratio(alpha: float, chunk: int) -> float:
    """r = α + 2/n' — fraction of disk KV bytes moved per evaluation+fetch."""
    return alpha + 2.0 / chunk


def plan_placement(kv_bytes_per_layer: float, n_layers: int, spec: TierSpec, *,
                   early_layers: int = 2, importance_rate: float = 0.1,
                   hot_frac: float = 0.05) -> List[LayerPlacement]:
    """Greedy capacity-aware placement.

    GPU gets each layer's working set (importance_rate + hot tokens), early
    layers are pinned to GPU/CPU only; remaining bytes spill to CPU then disk.
    """
    placements: List[LayerPlacement] = []
    gpu_left, cpu_left = spec.gpu_bytes, spec.cpu_bytes
    for layer in range(n_layers):
        want_gpu = kv_bytes_per_layer * min(1.0, importance_rate + hot_frac)
        g = min(want_gpu, max(gpu_left, 0.0))
        gpu_left -= g
        rest = kv_bytes_per_layer - g
        if layer < early_layers:
            c = min(rest, max(cpu_left, 0.0))
            cpu_left -= c
            d = rest - c
            if d > 1e-9:  # overflow of a pinned layer: spill to CPU anyway
                c += d
                d = 0.0
        else:
            c = min(rest, max(cpu_left, 0.0))
            cpu_left -= c
            d = rest - c
        placements.append(LayerPlacement(g / kv_bytes_per_layer,
                                         c / kv_bytes_per_layer,
                                         d / kv_bytes_per_layer))
    return placements


@dataclass
class AccessTable:
    """Token access-frequency table (EMA) for hot-token pinning (§4.3)."""

    n_tokens: int
    decay: float = 0.9
    counts: np.ndarray = field(init=False)

    def __post_init__(self):
        self.counts = np.zeros(self.n_tokens, dtype=np.float64)

    def record(self, token_ids: np.ndarray) -> None:
        self.counts *= self.decay
        np.add.at(self.counts, np.asarray(token_ids, dtype=np.int64), 1.0)

    def grow(self, n: int) -> None:
        if n > self.n_tokens:
            self.counts = np.concatenate(
                [self.counts, np.zeros(n - self.n_tokens)])
            self.n_tokens = n

    def hot_tokens(self, frac: float) -> np.ndarray:
        k = max(1, int(self.n_tokens * frac))
        return np.argsort(-self.counts)[:k]

    def hot_mask(self, frac: float) -> np.ndarray:
        mask = np.zeros(self.n_tokens, dtype=bool)
        mask[self.hot_tokens(frac)] = True
        return mask


def kv_bytes(seq: int, n_kv_heads: int, head_dim: int, *,
             dtype_bytes: int = 2, factor: int = 2) -> float:
    """Bytes of one layer's KV cache for one sequence (K and V)."""
    return float(factor * seq * n_kv_heads * head_dim * dtype_bytes)


def abstract_overhead(chunk: int) -> float:
    """Extra storage fraction from abstracts: 2 key vectors per chunk on K+V
    (paper §6.5: <1.6% at chunk=64 — 2/(2·64) = 1.56%)."""
    return 2.0 / (2.0 * chunk)


def shared_prefix_savings(hit_chunks: int, n_layers: int, chunk_bytes: float,
                          abstract_bytes: float) -> float:
    """Tier bytes a warm-prefix admission does NOT write or duplicate:
    per adopted chunk, every layer skips its disk replica AND its LKA
    abstract (both computed once by the registrant and shared by
    reference).  The store accumulates this into ``bytes_deduped``."""
    return float(hit_chunks) * n_layers * (chunk_bytes + abstract_bytes)
