"""IAKM — Importance-aware Adaptive KV Management (paper §4.2).

Two implementations of the same tree semantics:

* :func:`tree_select` — the paper's exact host-side algorithm: a max-heap of
  variable-size chunks ordered by upper bound; pop → confirm / split; desert
  runs merge into coarse chunks.  Used by the serving engine and by the
  fidelity/eval-count benchmarks (Fig. 10).  Exact top-T with provably
  correct confirmation rules; evaluation count is the paper's cost metric.

* :func:`pyramid_select_gqa` / :func:`pyramid_select_mla` — the TPU-native
  fixed-shape equivalent: descend the abstract pyramid coarse→fine keeping a
  bounded candidate beam per level (`lax.top_k`).  Staying coarse == merge,
  descending == split.  jit/pjit-able, used inside the decode step.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abstracts import Pyramid
from repro.core.bounds import (chunk_bounds_gqa_matmul, chunk_bounds_mla,
                               positive_negative_split)

# ---------------------------------------------------------------------------
# Host-side exact tree selection (paper Fig. 10)
# ---------------------------------------------------------------------------


@dataclass
class TreeSelectResult:
    selected: np.ndarray            # sorted token indices, len == budget
    evaluations: int                # chunk-bound evaluations performed
    partition: List[Tuple[int, int, bool]]  # (lo, hi, important) final chunks
    transfer_tokens: int            # tokens fetched (selected segments only)

    @property
    def transfer_ratio(self) -> float:
        """Fraction of fetched tokens that are truly wanted (paper's metric)."""
        return len(self.selected) / max(1, self.transfer_tokens)


def tree_select(scores: np.ndarray, budget: int, chunk: int,
                max_merge_span: Optional[int] = None) -> TreeSelectResult:
    """Exact top-``budget`` token selection with minimal chunk evaluations.

    ``scores`` are per-token importance values (attention-mass proxy); one
    "evaluation" computes a chunk's (ub, lb) from its abstract.  Branch and
    bound: the max-ub segment on the heap either (a) is a single token →
    confirmed, (b) has lb >= every other segment's ub → wholly confirmed
    (the paper's "at least 4 important tokens in Chunk₇¹" step), or (c) is
    split in two (two new evaluations).  Unpopped segments form the
    attention desert and are merged for the next step's partition.
    """
    n = len(scores)
    budget = min(budget, n)
    n_chunks = math.ceil(n / chunk)
    evals = 0

    # heap of (-ub, lo, hi, lb); ub/lb from the chunk "abstract"
    heap: List[Tuple[float, int, int, float]] = []
    for c in range(n_chunks):
        lo, hi = c * chunk, min((c + 1) * chunk, n)
        seg = scores[lo:hi]
        evals += 1
        heapq.heappush(heap, (-float(seg.max()), lo, hi, float(seg.min())))

    selected: List[int] = []
    confirmed_segs: List[Tuple[int, int]] = []
    while len(selected) < budget and heap:
        nub, lo, hi, lb = heapq.heappop(heap)
        size = hi - lo
        remaining = budget - len(selected)
        next_ub = -heap[0][0] if heap else -np.inf
        if size == 1:
            selected.append(lo)
            confirmed_segs.append((lo, hi))
            continue
        if lb >= next_ub and size <= remaining:
            # whole segment provably in the top set
            selected.extend(range(lo, hi))
            confirmed_segs.append((lo, hi))
            continue
        mid = lo + size // 2
        for a, b in ((lo, mid), (mid, hi)):
            seg = scores[a:b]
            evals += 1
            heapq.heappush(heap, (-float(seg.max()), a, b, float(seg.min())))

    selected_arr = np.array(sorted(selected), dtype=np.int64)

    # Final partition: confirmed segments + merged desert runs.
    span_cap = max_merge_span or (chunk * 8)
    important = np.zeros(n, dtype=bool)
    important[selected_arr] = True
    partition: List[Tuple[int, int, bool]] = []
    i = 0
    while i < n:
        j = i
        flag = bool(important[i])
        cap = n if flag else min(n, i + span_cap)
        while j < cap and (j == i or important[j] == flag):
            j += 1
            if flag and j < n and not important[j]:
                break
        partition.append((i, j, flag))
        i = j
    transfer = sum(hi - lo for lo, hi, imp in partition if imp)
    return TreeSelectResult(selected_arr, evals, partition, transfer)


def tree_select_chunks(chunk_ub: np.ndarray, length: int, budget: int,
                       chunk: int) -> Tuple[List[int], int]:
    """Chunk-level fast path for :func:`tree_select` on per-chunk scores.

    Equivalent to ``tree_select(np.repeat(chunk_ub, chunk)[:length], budget,
    chunk)`` followed by ``{t // chunk for t in selected}`` — but O(n_chunks
    log n_chunks + log chunk) instead of O(length): with scores constant
    inside a chunk every segment has lb == ub, so the branch-and-bound
    confirmation rule collapses to "take the whole segment iff it fits the
    remaining budget, else split".  Heap keys match ``tree_select``'s
    ``(-ub, lo, hi, lb)`` exactly (lo breaks ties), so the selected chunk
    set AND the evaluation count are identical to the per-token path.

    Returns (sorted selected chunk ids, evaluations).
    """
    n = int(length)
    budget = min(budget, n)
    n_chunks = math.ceil(n / chunk)
    evals = n_chunks
    heap: List[Tuple[float, int, int]] = []
    for c in range(n_chunks):
        lo, hi = c * chunk, min((c + 1) * chunk, n)
        heapq.heappush(heap, (-float(chunk_ub[c]), lo, hi))
    taken = 0
    sel: set = set()
    while taken < budget and heap:
        nub, lo, hi = heapq.heappop(heap)
        size = hi - lo
        # lb == ub == -nub, and the popped segment is the heap max, so the
        # per-token rule "lb >= next_ub and size <= remaining" is just the
        # size check; size == 1 is its degenerate case.
        if size <= budget - taken:
            taken += size
            sel.add(lo // chunk)
            continue
        mid = lo + size // 2
        evals += 2
        heapq.heappush(heap, (nub, lo, mid))
        heapq.heappush(heap, (nub, mid, hi))
    return sorted(sel), evals


def flat_select_chunks(chunk_ub: np.ndarray, length: int, budget: int,
                       chunk: int) -> Tuple[List[int], int]:
    """Chunk-level fast path for :func:`flat_chunk_select` on chunk scores.

    The Quest-like baseline takes chunks in score order until ``budget``
    tokens are covered; with per-token scores constant inside a chunk the
    top-``budget`` token set is exactly the tokens of that chunk prefix, so
    no per-token array is needed.  Ties across chunks follow the same
    ``np.argsort(-ubs)`` call the per-token path makes.
    """
    n = int(length)
    budget = min(budget, n)
    n_chunks = math.ceil(n / chunk)
    order = np.argsort(-np.asarray(chunk_ub[:n_chunks]))
    sel: List[int] = []
    covered = 0
    for c in order:
        if covered >= budget:
            break
        sel.append(int(c))
        covered += min(chunk, n - int(c) * chunk)
    return sorted(sel), n_chunks


def flat_chunk_select(scores: np.ndarray, budget: int, chunk: int
                      ) -> TreeSelectResult:
    """Quest-like fixed-chunk baseline: score every chunk, take top chunks."""
    n = len(scores)
    n_chunks = math.ceil(n / chunk)
    ubs = np.array([scores[c * chunk: (c + 1) * chunk].max() for c in range(n_chunks)])
    order = np.argsort(-ubs)
    picked: List[int] = []
    transfer = 0
    top_tokens = set(np.argsort(-scores)[:budget].tolist())
    chosen = []
    for c in order:
        if len(picked) >= budget:
            break
        lo, hi = c * chunk, min((c + 1) * chunk, n)
        chosen.append((lo, hi, True))
        transfer += hi - lo
        picked.extend(t for t in range(lo, hi) if t in top_tokens)
    hit = np.array(sorted(set(picked)), dtype=np.int64)
    res = TreeSelectResult(hit, n_chunks, chosen, transfer)
    return res


# ---------------------------------------------------------------------------
# Device-side pyramid refinement (fixed shapes)
# ---------------------------------------------------------------------------


def _beam_sizes(levels: int, budget: int, nc: Sequence[int], rf: int,
                forced: int) -> List[int]:
    """Candidates kept per level (index 0 = finest)."""
    out = []
    for lvl in range(levels):
        want = rf * max(1, -(-budget // (1 << lvl))) + forced
        out.append(min(nc[lvl], want))
    return out


def _ub_gathered(q4: jax.Array, km: jax.Array, kn: jax.Array) -> jax.Array:
    """ub for gathered boxes.  q4: (B,Hkv,G,hd); km/kn: (B,Hkv,C,hd)."""
    qp, qn = positive_negative_split(q4.astype(jnp.float32))
    ub = (jnp.einsum("bkgd,bkcd->bkgc", qp, km.astype(jnp.float32))
          + jnp.einsum("bkgd,bkcd->bkgc", qn, kn.astype(jnp.float32)))
    return jnp.sum(ub, axis=2)


def _force_bias(ub: jax.Array, ids: jax.Array, lvl: int, nc0: int,
                sink_chunks: int, recent_chunks: int,
                n_valid0, chunk_offset=0) -> jax.Array:
    """+inf bias for sink/recent nodes so they always survive the beam.

    ``chunk_offset``/``n_valid0`` are in GLOBAL base-chunk units: under
    sequence sharding only the shard owning the global sink (or the tail)
    forces those chunks — naive per-shard forcing burned ~40% of every
    shard's budget on non-sink chunks (§Perf C3).
    """
    span = 1 << lvl
    gids = ids * span + chunk_offset            # global base-chunk of node
    forced = jnp.zeros_like(ub, dtype=bool)
    if sink_chunks:
        forced = forced | (gids < sink_chunks)
    if recent_chunks:
        # node covers [gids, gids+span): force if it overlaps the tail
        forced = forced | ((gids + span) > (n_valid0 - recent_chunks))
    forced = forced & (gids < n_valid0)
    return jnp.where(forced, jnp.inf, ub)


def pyramid_select_gqa(q: jax.Array, pyr: Pyramid, budget: int, *,
                       rf: int = 2, sink_chunks: int = 1,
                       recent_chunks: int = 2,
                       n_valid0: Optional[jax.Array] = None,
                       chunk_offset=0) -> jax.Array:
    """Select ``budget`` base chunks per (batch, kv-head).

    q: (B, H, hd) scaled+roped query.  Returns int32 ids (B, Hkv, budget).
    ``n_valid0``: GLOBAL valid base-chunk count; ``chunk_offset``: this
    shard's global base-chunk offset (0 when unsharded).
    """
    L = pyr.levels
    B, H, hd = q.shape
    Hkv = pyr.kmax[0].shape[2]
    nc = [pyr.kmax[l].shape[1] for l in range(L)]
    budget = min(budget, nc[0])
    if n_valid0 is None:
        n_valid0 = nc[0]
    forced = sink_chunks + recent_chunks
    beams = _beam_sizes(L, budget, nc, rf, forced)
    q4 = q.reshape(B, Hkv, H // Hkv, hd)

    # coarsest level: score everything
    ub, _ = chunk_bounds_gqa_matmul(q, pyr.kmax[L - 1], pyr.kmin[L - 1])
    ids = jnp.broadcast_to(jnp.arange(nc[L - 1], dtype=jnp.int32),
                           ub.shape)                     # (B,Hkv,ncL)
    ub = _force_bias(ub, ids, L - 1, nc[0], sink_chunks, recent_chunks,
                     n_valid0, chunk_offset)
    _, sel = jax.lax.top_k(ub, beams[L - 1])
    ids = jnp.take_along_axis(ids, sel, axis=-1)         # (B,Hkv,beamL)

    for lvl in range(L - 2, -1, -1):
        ids = jnp.concatenate([ids * 2, ids * 2 + 1], axis=-1)  # children
        km = jnp.swapaxes(pyr.kmax[lvl], 1, 2)           # (B,Hkv,nc,hd)
        kn = jnp.swapaxes(pyr.kmin[lvl], 1, 2)
        gkm = jnp.take_along_axis(km, ids[..., None], axis=2)
        gkn = jnp.take_along_axis(kn, ids[..., None], axis=2)
        ub = _ub_gathered(q4, gkm, gkn)                  # (B,Hkv,2*beam)
        ub = _force_bias(ub, ids, lvl, nc[0], sink_chunks, recent_chunks,
                         n_valid0, chunk_offset)
        width = beams[lvl] if lvl > 0 else budget
        _, sel = jax.lax.top_k(ub, min(width, ids.shape[-1]))
        ids = jnp.take_along_axis(ids, sel, axis=-1)
    return ids.astype(jnp.int32)


def flat_select_gqa(q: jax.Array, kmax0: jax.Array, kmin0: jax.Array,
                    budget: int, *, sink_chunks: int = 1,
                    recent_chunks: int = 2,
                    n_valid0=None, chunk_offset=0) -> jax.Array:
    """Quest-like baseline: score all base chunks, top-k.  Same interface."""
    ub, _ = chunk_bounds_gqa_matmul(q, kmax0, kmin0)
    nc0 = ub.shape[-1]
    if n_valid0 is None:
        n_valid0 = nc0
    ids = jnp.broadcast_to(jnp.arange(nc0, dtype=jnp.int32), ub.shape)
    ub = _force_bias(ub, ids, 0, nc0, sink_chunks, recent_chunks, n_valid0,
                     chunk_offset)
    _, sel = jax.lax.top_k(ub, min(budget, nc0))
    return sel.astype(jnp.int32)


def pyramid_select_mla(q_lat: jax.Array, q_rope: jax.Array, pyr_c: Pyramid,
                       pyr_r: Pyramid, budget: int, *, rf: int = 2,
                       sink_chunks: int = 1, recent_chunks: int = 2,
                       n_valid0=None, chunk_offset=0) -> jax.Array:
    """MLA variant: boxes over the compressed latent (+rope key).

    pyr_c levels: (B, nc, 1, r); pyr_r: (B, nc, 1, rr).  Returns (B, 1, k).
    """
    L = pyr_c.levels
    B, H, r = q_lat.shape
    nc = [pyr_c.kmax[l].shape[1] for l in range(L)]
    budget = min(budget, nc[0])
    if n_valid0 is None:
        n_valid0 = nc[0]
    beams = _beam_sizes(L, budget, nc, rf, sink_chunks + recent_chunks)

    def score(lvl, ids=None):
        cm, cn = pyr_c.kmax[lvl][:, :, 0], pyr_c.kmin[lvl][:, :, 0]  # (B,nc,r)
        rm, rn = pyr_r.kmax[lvl][:, :, 0], pyr_r.kmin[lvl][:, :, 0]
        if ids is not None:
            take = lambda a: jnp.take_along_axis(a, ids[:, 0, :, None], axis=1)
            cm, cn, rm, rn = take(cm), take(cn), take(rm), take(rn)
        ub, _ = chunk_bounds_mla(q_lat, q_rope, cm, cn, rm, rn)
        return ub[:, None]                               # (B,1,nc)

    ub = score(L - 1)
    ids = jnp.broadcast_to(jnp.arange(nc[L - 1], dtype=jnp.int32), ub.shape)
    ub = _force_bias(ub, ids, L - 1, nc[0], sink_chunks, recent_chunks,
                     n_valid0, chunk_offset)
    _, sel = jax.lax.top_k(ub, beams[L - 1])
    ids = jnp.take_along_axis(ids, sel, axis=-1)
    for lvl in range(L - 2, -1, -1):
        ids = jnp.concatenate([ids * 2, ids * 2 + 1], axis=-1)
        ub = score(lvl, ids)
        ub = _force_bias(ub, ids, lvl, nc[0], sink_chunks, recent_chunks,
                         n_valid0, chunk_offset)
        width = beams[lvl] if lvl > 0 else budget
        _, sel = jax.lax.top_k(ub, min(width, ids.shape[-1]))
        ids = jnp.take_along_axis(ids, sel, axis=-1)
    return ids.astype(jnp.int32)


def pyramid_eval_count(levels: int, nc0: int, budget: int, rf: int = 2,
                       forced: int = 3) -> int:
    """Analytic number of chunk-bound evaluations for the pyramid descent."""
    nc = [max(1, nc0 >> l) for l in range(levels)]
    beams = _beam_sizes(levels, budget, nc, rf, forced)
    total = nc[levels - 1]
    for lvl in range(levels - 2, -1, -1):
        total += 2 * beams[lvl + 1]
    return total
