"""Functional LeoAM sparse decode attention (pure JAX; kernels plug in via
``repro.kernels.*.ops``).

The decode path is: score chunk abstracts → adaptive (pyramid) selection →
gather selected chunks → flash attention over the gathered working set.
All functions return stable partial-softmax triples (num, den, m) so they
compose across sequence shards (``combine_partials`` psums them) — this is
the sequence-parallel decode used for every decode shape on the production
mesh (DESIGN.md §2/§5).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.abstracts import Pyramid
from repro.core.adaptive import pyramid_select_gqa, pyramid_select_mla, flat_select_gqa

NEG_INF = float("-inf")


class Partials(NamedTuple):
    num: jax.Array    # (B, H, vd) un-normalized weighted values
    den: jax.Array    # (B, H) softmax denominator (relative to m)
    m: jax.Array      # (B, H) running max logit


def _finish(p: Partials) -> jax.Array:
    den = jnp.where(p.den == 0.0, 1.0, p.den)
    return (p.num / den[..., None])


def combine_partials(p: Partials, axes: Sequence[str]) -> jax.Array:
    """Merge per-shard partial softmax over mesh ``axes`` (inside shard_map)."""
    if not axes:
        return _finish(p)
    gm = p.m
    for ax in axes:
        gm = jax.lax.pmax(gm, ax)
    gm_safe = jnp.where(jnp.isfinite(gm), gm, 0.0)
    w = jnp.where(jnp.isfinite(p.m), jnp.exp(p.m - gm_safe), 0.0)
    num = p.num * w[..., None]
    den = p.den * w
    num = jax.lax.psum(num, tuple(axes))
    den = jax.lax.psum(den, tuple(axes))
    den = jnp.where(den == 0.0, 1.0, den)
    return num / den[..., None]


def _masked_softmax_partials(scores: jax.Array, v: jax.Array,
                             mask: jax.Array) -> Partials:
    """scores: (B,Hkv,G,T) f32; v: (B,Hkv,T,vd); mask: (B,Hkv,1,T) bool.

    v stays in its storage dtype — the einsum accumulates in f32 via
    preferred_element_type (an explicit .astype(f32) here made XLA
    materialize f32 copies of the full KV cache inside the decode layer
    loop: +160 GiB/step of converts on decode_32k; §Perf C1).
    """
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                                  # (B,Hkv,G)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.exp(scores - m_safe[..., None])
    e = jnp.where(mask, e, 0.0)
    den = jnp.sum(e, axis=-1)
    num = jnp.einsum("bkgt,bktv->bkgv", e.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    B, Hkv, G = m.shape
    return Partials(num.reshape(B, Hkv * G, -1), den.reshape(B, Hkv * G),
                    m.reshape(B, Hkv * G))


def gather_chunk_tokens(ids: jax.Array, chunk: int) -> jax.Array:
    """(B,Hkv,k) chunk ids -> (B,Hkv,k*chunk) token positions."""
    tok = ids[..., None] * chunk + jnp.arange(chunk, dtype=ids.dtype)
    return tok.reshape(*ids.shape[:-1], -1)


def sparse_decode_gqa(q: jax.Array, k: jax.Array, v: jax.Array,
                      ids: jax.Array, chunk: int, *,
                      length, attn_softcap: Optional[float] = None,
                      base_pos: int | jax.Array = 0) -> Partials:
    """Attention over selected chunks.

    q: (B,H,hd) scaled+roped; k/v: (B,S,Hkv,hd) (local shard);
    ids: (B,Hkv,nsel) base-chunk ids local to this shard;
    length: valid token count within this shard (scalar or (B,));
    base_pos: global position offset of this shard (for masking only).
    """
    B, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    tok = gather_chunk_tokens(ids, chunk)                         # (B,Hkv,T)
    tok_c = jnp.minimum(tok, S - 1)
    # gather along the sequence axis directly — transposing the (tiny)
    # index array instead of the multi-GiB cache (§Perf C1)
    idx = jnp.swapaxes(tok_c, 1, 2)                               # (B,T,Hkv)
    kg = jnp.take_along_axis(k, idx[..., None], axis=1)           # (B,T,Hkv,hd)
    vg = jnp.take_along_axis(v, idx[..., None], axis=1)
    kg = jnp.swapaxes(kg, 1, 2)                                   # (B,Hkv,T,hd)
    vg = jnp.swapaxes(vg, 1, 2)
    qg = q.reshape(B, Hkv, G, hd)
    scores = jnp.einsum("bkgd,bktd->bkgt", qg.astype(kg.dtype), kg,
                        preferred_element_type=jnp.float32)
    if attn_softcap is not None:
        scores = attn_softcap * jnp.tanh(scores / attn_softcap)
    len_b = jnp.reshape(jnp.asarray(length), (-1, 1, 1))          # (B,1,1)
    valid = (tok < len_b) & (tok < S)
    return _masked_softmax_partials(scores, vg, valid[:, :, None, :])


def dense_decode_gqa(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     length, attn_softcap: Optional[float] = None,
                     window: Optional[int] = None,
                     base_pos: int | jax.Array = 0,
                     query_pos=None) -> Partials:
    """Full (or sliding-window) decode attention over a local KV shard."""
    B, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    qg = q.reshape(B, Hkv, G, hd)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                        kt.astype(jnp.float32))
    if attn_softcap is not None:
        scores = attn_softcap * jnp.tanh(scores / attn_softcap)
    local_pos = jnp.arange(S)[None, None, :]
    len_b = jnp.reshape(jnp.asarray(length), (-1, 1, 1))          # local count
    valid = local_pos < len_b
    if window is not None:
        qp = jnp.reshape(jnp.asarray(query_pos if query_pos is not None
                                     else length), (-1, 1, 1))
        valid = valid & ((local_pos + base_pos) > (qp - window))  # global pos
    return _masked_softmax_partials(scores, vt, valid)


def sparse_decode_mla(q_lat: jax.Array, q_rope: jax.Array,
                      ckv: jax.Array, krope: jax.Array, ids: jax.Array,
                      chunk: int, *, length) -> Partials:
    """Absorbed-MLA sparse decode in latent space.

    q_lat: (B,H,r); q_rope: (B,H,rr); ckv: (B,S,r); krope: (B,S,rr);
    ids: (B,1,nsel).  Returns Partials with num in latent space (B,H,r) —
    the caller applies W_UV afterwards (absorbed value projection).
    """
    B, H, r = q_lat.shape
    S = ckv.shape[1]
    tok = gather_chunk_tokens(ids[:, 0], chunk)                   # (B,T)
    tok_c = jnp.minimum(tok, S - 1)
    cg = jnp.take_along_axis(ckv, tok_c[..., None], axis=1)       # (B,T,r)
    rg = jnp.take_along_axis(krope, tok_c[..., None], axis=1)     # (B,T,rr)
    scores = (jnp.einsum("bhr,btr->bht", q_lat.astype(jnp.float32),
                         cg.astype(jnp.float32))
              + jnp.einsum("bhr,btr->bht", q_rope.astype(jnp.float32),
                           rg.astype(jnp.float32)))
    len_b = jnp.reshape(jnp.asarray(length), (-1, 1))
    valid = (tok < len_b) & (tok < S)                             # (B,T)
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.exp(scores - m_safe[..., None])
    e = jnp.where(valid[:, None, :], e, 0.0)
    den = jnp.sum(e, axis=-1)
    num = jnp.einsum("bht,btr->bhr", e, cg.astype(jnp.float32))
    return Partials(num, den, m)


def dense_decode_mla(q_lat, q_rope, ckv, krope, *, length) -> Partials:
    B, H, r = q_lat.shape
    S = ckv.shape[1]
    scores = (jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32),
                         ckv.astype(jnp.float32))
              + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                           krope.astype(jnp.float32)))
    valid = (jnp.arange(S)[None, :] < jnp.reshape(jnp.asarray(length), (-1, 1)))
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.exp(scores - m_safe[..., None])
    e = jnp.where(valid[:, None, :], e, 0.0)
    den = jnp.sum(e, axis=-1)
    num = jnp.einsum("bhs,bsr->bhr", e, ckv.astype(jnp.float32))
    return Partials(num, den, m)


# ---------------------------------------------------------------------------
# High-level entry: select + attend on one (possibly sequence-sharded) shard
# ---------------------------------------------------------------------------


def leoam_decode_shard(q: jax.Array, k: jax.Array, v: jax.Array,
                       pyr: Pyramid, *, chunk: int, budget: int,
                       length, attn_softcap: Optional[float] = None,
                       sink_chunks: int = 1, recent_chunks: int = 2,
                       rf: int = 2, adaptive: bool = True,
                       n_valid_chunks=None, chunk_offset=0) -> Partials:
    """One shard's worth of LeoAM decode: pyramid-select then attend.

    ``n_valid_chunks``/``chunk_offset`` are global base-chunk coordinates
    (sink/recent forcing is global under sequence sharding; §Perf C3)."""
    if adaptive and pyr.levels > 1:
        ids = pyramid_select_gqa(q, pyr, budget, rf=rf,
                                 sink_chunks=sink_chunks,
                                 recent_chunks=recent_chunks,
                                 n_valid0=n_valid_chunks if n_valid_chunks
                                 is not None else pyr.base_chunks,
                                 chunk_offset=chunk_offset)
    else:
        ids = flat_select_gqa(q, pyr.kmax[0], pyr.kmin[0], budget,
                              sink_chunks=sink_chunks,
                              recent_chunks=recent_chunks,
                              n_valid0=n_valid_chunks if n_valid_chunks
                              is not None else pyr.base_chunks,
                              chunk_offset=chunk_offset)
    return sparse_decode_gqa(q, k, v, ids, chunk, length=length,
                             attn_softcap=attn_softcap)


def decode_budget_chunks(seq_len: int, chunk: int, rate: float,
                         sink_chunks: int, recent_chunks: int) -> int:
    nc = seq_len // chunk
    return max(1, min(nc, int(math.ceil(nc * rate)) + sink_chunks + recent_chunks))
