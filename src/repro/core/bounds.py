"""Chunk importance bounds from KV abstracts (paper §4.2–4.3).

For a chunk whose keys lie in the axis-aligned box [kmin, kmax], the dot
product q·k for any k in the box is bounded by

    ub = Σ_d max(q_d·kmax_d, q_d·kmin_d)
    lb = Σ_d min(q_d·kmax_d, q_d·kmin_d)

(the linear function q·k over a box attains its extrema at corners chosen
per-coordinate by sign(q_d)).  These are *sound* bounds: lb <= q·k <= ub —
property-tested in tests/test_bounds.py.

GQA aggregation: per-chunk scores are per q-head; a KV chunk is fetched per
kv-head, so group scores are summed over the q-heads of the group (total
attention-mass proxy, the paper's §4.1 column-sum metric).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def box_bounds(q: jax.Array, kmax: jax.Array, kmin: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Generic box bound.

    q: (..., H, hd); kmax/kmin: (..., nc, hd) broadcastable against q's
    batch dims.  Returns (ub, lb): (..., H, nc).
    """
    q = q.astype(jnp.float32)
    hi = jnp.einsum("...hd,...cd->...hcd", q, kmax.astype(jnp.float32))
    lo = jnp.einsum("...hd,...cd->...hcd", q, kmin.astype(jnp.float32))
    ub = jnp.sum(jnp.maximum(hi, lo), axis=-1)
    lb = jnp.sum(jnp.minimum(hi, lo), axis=-1)
    return ub, lb


def chunk_bounds_gqa(q: jax.Array, kmax: jax.Array, kmin: jax.Array,
                     ) -> Tuple[jax.Array, jax.Array]:
    """GQA chunk bounds.

    q: (B, H, hd) scaled query (already divided by sqrt(hd), roped);
    kmax/kmin: (B, nc, Hkv, hd).
    Returns (ub, lb): (B, Hkv, nc) — group-summed scores.
    """
    B, H, hd = q.shape
    Hkv = kmax.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    km = jnp.swapaxes(kmax, 1, 2).astype(jnp.float32)   # (B, Hkv, nc, hd)
    kn = jnp.swapaxes(kmin, 1, 2).astype(jnp.float32)
    hi = jnp.einsum("bkgd,bkcd->bkgcd", qg, km)          # per-coordinate
    lo = jnp.einsum("bkgd,bkcd->bkgcd", qg, kn)
    ub = jnp.sum(jnp.maximum(hi, lo), axis=(-1, 2))      # Σ_d then Σ_group
    lb = jnp.sum(jnp.minimum(hi, lo), axis=(-1, 2))
    return ub, lb


def chunk_bounds_mla(q_lat: jax.Array, q_rope: jax.Array,
                     cmax: jax.Array, cmin: jax.Array,
                     rmax: jax.Array, rmin: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """Sound MLA chunk bounds in latent space (DESIGN.md §4).

    q_lat: (B, H, r) = q_nope @ W_UK (absorbed query); q_rope: (B, H, rr);
    cmax/cmin: (B, nc, r) latent boxes; rmax/rmin: (B, nc, rr) rope-key boxes.
    Uses the q⁺/q⁻ split so the bound is two matmuls per part.
    Returns (ub, lb): (B, nc) summed over heads (single logical kv head).
    """
    def part(qq, hi_box, lo_box):
        qq = qq.astype(jnp.float32)
        qp, qn = positive_negative_split(qq)
        hi_box = hi_box.astype(jnp.float32)
        lo_box = lo_box.astype(jnp.float32)
        ub = (jnp.einsum("bhr,bcr->bhc", qp, hi_box)
              + jnp.einsum("bhr,bcr->bhc", qn, lo_box))
        lb = (jnp.einsum("bhr,bcr->bhc", qp, lo_box)
              + jnp.einsum("bhr,bcr->bhc", qn, hi_box))
        return ub, lb
    ub_c, lb_c = part(q_lat, cmax, cmin)
    ub_r, lb_r = part(q_rope, rmax, rmin)
    ub = jnp.sum(ub_c + ub_r, axis=1)                    # sum over heads
    lb = jnp.sum(lb_c + lb_r, axis=1)
    return ub, lb


def positive_negative_split(q: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """q = q⁺ + q⁻ decomposition: ub = q⁺·kmax + q⁻·kmin (matmul-friendly).

    Identical value to the per-coordinate corner rule but expressed as two
    einsums over (possibly large) chunk axes — this is the form the Pallas
    kernel uses on the MXU.
    """
    qp = jnp.maximum(q, 0.0)
    qn = jnp.minimum(q, 0.0)
    return qp, qn


def chunk_bounds_gqa_matmul(q: jax.Array, kmax: jax.Array, kmin: jax.Array
                            ) -> Tuple[jax.Array, jax.Array]:
    """MXU-friendly equivalent of :func:`chunk_bounds_gqa`.

    max(q_d·kmax_d, q_d·kmin_d) == max(q_d,0)·kmax_d + min(q_d,0)·kmin_d
    elementwise, so the ub reduces to two dense matmuls.
    """
    B, H, hd = q.shape
    Hkv = kmax.shape[2]
    G = H // Hkv
    q32 = q.astype(jnp.float32).reshape(B, Hkv, G, hd)
    qp, qn = positive_negative_split(q32)
    km = jnp.swapaxes(kmax, 1, 2).astype(jnp.float32)
    kn = jnp.swapaxes(kmin, 1, 2).astype(jnp.float32)
    ub = jnp.einsum("bkgd,bkcd->bkgc", qp, km) + jnp.einsum("bkgd,bkcd->bkgc", qn, kn)
    lb = jnp.einsum("bkgd,bkcd->bkgc", qp, kn) + jnp.einsum("bkgd,bkcd->bkgc", qn, km)
    return jnp.sum(ub, axis=2), jnp.sum(lb, axis=2)
