"""DTP — Dynamic Three-tier Pipeline (paper §4.4).

Two parts:

* :func:`optimal_theta` — the paper's dynamic-compression balance: choose the
  compressed fraction θ of the D bytes to transfer so that transfer hides
  exactly under compute:  T0 + (D(1-θ) + Dθδ)/B  =  Tc + t(Dθ),
  with t(x) = κx the decompression cost.  Solving for θ:

      θ* = (Tc + T0' ... )  — closed form below, clamped to [0, 1].

* :class:`PipelineSchedule` — an event-timeline builder for the three-tier
  layer pipeline: disk→CPU abstract loads, CPU evaluation, CPU→GPU selected-KV
  transfer, GPU layer compute; with per-layer overlap (the paper's Fig. 13).
  The discrete-event serving simulator and the Fig.13/16 benchmarks use it.

* :func:`prefill_schedule` — the ADMISSION-side counterpart: per-layer
  prefill compute vs the layer's tier writes (disk replica + abstract,
  optionally packed through the transit codec).  Serial admission stalls
  compute behind every write; write-behind admission drains the writes on
  the disk link under the remaining layers' compute, so TTFT collapses to
  the compute chain plus whatever write tail outlives it — the model the
  fig13 TTFT-breakdown benchmark checks the live engine against.

* :func:`chunked_admission_model` — the CHUNKED-admission trade: splitting
  a prompt's prefill into fixed chunks advanced between decode rounds
  bounds the running batch's max round gap at the per-round chunk budget
  (vs the whole prefill) while TTFT stretches by the interleaved rounds —
  the fig13 mixed-length benchmark measures the live scheduler against it.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


def optimal_theta(D: float, B: float, delta: float, T0: float, Tc: float,
                  kappa: float) -> float:
    """Paper §4.4: smallest θ∈[0,1] hiding transfer under compute.

    Latency-if-uncompressed must satisfy
        T0 + (D(1-θ) + Dθδ)/B <= Tc + κDθ.
    LHS decreases in θ (δ<1), RHS increases, so the equality point is the
    minimum compression that removes the GPU bubble:
        θ* = (T0 + D/B - Tc) / (D(1-δ)/B + κD).
    θ<0 → no compression needed; θ>1 → even full compression can't hide it
    (compress everything; the residual bubble shows in the timeline).
    """
    if D <= 0:
        return 0.0
    denom = D * (1.0 - delta) / B + kappa * D
    if denom <= 0:
        return 0.0
    theta = (T0 + D / B - Tc) / denom
    return float(min(1.0, max(0.0, theta)))


def transfer_time(D: float, theta: float, delta: float, B: float) -> float:
    return (D * (1.0 - theta) + D * theta * delta) / B


def theta_from_measured(upload_bytes: float, disk_bytes: float,
                        compute_s: float, bw: "TierBW",
                        delta: Optional[float] = None) -> float:
    """Per-layer θ from the live engine's measured round costs (§4.4).

    ``upload_bytes``: last round's host→device delta for the layer (the D
    the codec can shrink); ``disk_bytes``: bytes staged off disk for the
    layer (serial prefix T0); ``compute_s``: measured per-layer attention
    window.  The engine calls this every round so θ tracks the working set
    as residency warms up — fully pool-resident layers get θ=0 for free.
    """
    return optimal_theta(upload_bytes, bw.pcie,
                         bw.delta if delta is None else delta,
                         disk_bytes / bw.disk, compute_s, bw.kappa)


@dataclass
class PrefillLayerCost:
    """Per-layer admission costs: prefill compute + tier-write bytes."""
    compute: float                 # GPU prefill compute for the layer
    replica_bytes: float           # host->disk replica + abstract bytes
                                   # (packed bytes when the sidecar is on)


def prefill_schedule(layers: Sequence["PrefillLayerCost"], disk_bw: float, *,
                     write_behind: bool = True) -> "Timeline":
    """Admission (TTFT) timeline: layer-streamed prefill vs serial ingest.

    Serial: each layer's replica/abstract writes stall the admission chain
    (compute → write → next layer).  Write-behind: writes queue on the disk
    link as soon as their layer's compute finishes and drain under the
    remaining layers' compute; the first token is ready at the end of the
    compute chain (``Timeline.compute[-1][1]``), while ``makespan`` extends
    to the last write landing — the window the completion fence covers.
    """
    tl = Timeline()
    t = 0.0
    disk_free = 0.0
    for lc in layers:
        c0, c1 = t, t + lc.compute
        w = lc.replica_bytes / disk_bw
        if write_behind:
            x0 = max(c1, disk_free)
            x1 = x0 + w
            disk_free = x1
            t = c1
        else:
            x0, x1 = c1, c1 + w
            t = x1
        tl.compute.append((c0, c1))
        tl.transfer.append((x0, x1))
        tl.thetas.append(0.0)
    return tl


def chunked_admission_model(chunk_s: float, n_chunks: int, round_s: float,
                            chunks_per_round: int) -> Dict[str, float]:
    """Analytic model of CHUNKED admission interleaved with decode rounds.

    Whole-prompt admission runs all ``n_chunks`` prefill chunks back to
    back between two decode rounds: the running batch sees ONE decode gap
    of ``round_s + n_chunks * chunk_s`` and TTFT is the prefill chain.
    Chunked admission advances at most ``chunks_per_round`` chunks per
    round, bounding the decode gap at ``round_s + chunks_per_round *
    chunk_s`` while TTFT stretches by the decode rounds now interleaved
    into the prefill.  The fig13 mixed-length benchmark checks the live
    scheduler against exactly this trade: bounded stall, modest TTFT tax.
    """
    assert chunks_per_round >= 1
    interleaved = max(0, -(-n_chunks // chunks_per_round) - 1)
    return {
        "ttft_whole_s": n_chunks * chunk_s,
        "ttft_chunked_s": n_chunks * chunk_s + interleaved * round_s,
        "max_round_gap_whole_s": round_s + n_chunks * chunk_s,
        "max_round_gap_chunked_s": round_s + min(n_chunks, chunks_per_round)
        * chunk_s,
        "interleaved_rounds": float(interleaved),
    }


@dataclass
class LayerCost:
    """Per-layer per-step costs (seconds / bytes) for the pipeline model."""
    compute: float                 # GPU layer compute time
    eval_cpu: float                # importance evaluation on CPU
    abstract_bytes: float          # disk->CPU abstract traffic
    kv_bytes_cpu: float            # CPU->GPU selected KV (resident in CPU)
    kv_bytes_disk: float           # disk->CPU->GPU selected KV (cold)


@dataclass
class TierBW:
    """Tier link bandwidths (bytes/s) + decompression throughput."""
    pcie: float = 16e9             # CPU <-> GPU
    disk: float = 3.5e9            # disk -> CPU (sustained)
    kappa: float = 1.0 / 80e9      # s per byte decompressed on GPU
    delta: float = 0.25 + 4 / 128  # int4 codec ratio incl. scales


@dataclass
class Timeline:
    """Per-layer event spans; all times absolute seconds."""
    compute: List[Tuple[float, float]] = field(default_factory=list)
    transfer: List[Tuple[float, float]] = field(default_factory=list)
    evaluate: List[Tuple[float, float]] = field(default_factory=list)
    thetas: List[float] = field(default_factory=list)

    @property
    def makespan(self) -> float:
        ends = [e for spans in (self.compute, self.transfer, self.evaluate)
                for _, e in spans]
        return max(ends) if ends else 0.0

    @property
    def gpu_idle(self) -> float:
        busy = sum(e - s for s, e in self.compute)
        return self.makespan - busy


def schedule(layers: Sequence[LayerCost], bw: TierBW, *,
             pipelined: bool = True, dynamic_compression: bool = True,
             prefetch_depth: int = 1) -> Timeline:
    """Build the decode-step timeline.

    Non-pipelined: eval → transfer → compute strictly per layer.
    Pipelined (paper Fig. 13b/c): layer l computes while layer l+1 evaluates
    and transfers; dynamic compression picks θ per layer so transfer fits the
    compute window (Fig. 13c).
    """
    tl = Timeline()
    if not pipelined:
        t = 0.0
        for lc in layers:
            e0, e1 = t, t + lc.eval_cpu + lc.abstract_bytes / bw.disk
            D = lc.kv_bytes_cpu + lc.kv_bytes_disk
            x0 = e1
            x1 = x0 + lc.kv_bytes_disk / bw.disk + D / bw.pcie
            c0, c1 = x1, x1 + lc.compute
            tl.evaluate.append((e0, e1))
            tl.transfer.append((x0, x1))
            tl.compute.append((c0, c1))
            tl.thetas.append(0.0)
            t = c1
        return tl

    # pipelined: transfers for layer l+1 overlap compute of layer l
    gpu_free = 0.0
    xfer_done = [0.0] * (len(layers) + 1)
    eval_done = [0.0] * (len(layers) + 1)
    # layer 0's eval/transfer cannot overlap anything in this decode step
    for i, lc in enumerate(layers):
        # evaluation (CPU) for layer i starts as soon as the previous
        # layer's evaluation finished (CPU is serial across layers)
        e0 = eval_done[i]
        e1 = e0 + lc.eval_cpu + lc.abstract_bytes / bw.disk
        eval_done[i + 1] = e1

        D = lc.kv_bytes_cpu + lc.kv_bytes_disk
        compute_window = lc.compute   # the window we can hide under
        if dynamic_compression and D > 0:
            T0 = lc.kv_bytes_disk / bw.disk
            theta = optimal_theta(D, bw.pcie, bw.delta, T0, compute_window,
                                  bw.kappa)
        else:
            theta = 0.0
        xfer = (lc.kv_bytes_disk / bw.disk
                + transfer_time(D, theta, bw.delta, bw.pcie))
        decomp = bw.kappa * D * theta

        x0 = max(e1, xfer_done[i])
        x1 = x0 + xfer
        xfer_done[i + 1] = x1

        c0 = max(gpu_free, x1)
        c1 = c0 + lc.compute + decomp
        gpu_free = c1

        tl.evaluate.append((e0, e1))
        tl.transfer.append((x0, x1))
        tl.compute.append((c0, c1))
        tl.thetas.append(theta)
    return tl
