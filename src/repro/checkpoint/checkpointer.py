"""Checkpointing: atomic step directories, async save, reshard-on-restore.

Layout::

    <root>/step_000100.tmp/   (written, then atomically renamed)
    <root>/step_000100/
        meta.json             (step, tree structure, shapes/dtypes)
        <flat..path>.npy      (one file per leaf, host-gathered)

Restore accepts a *different* mesh/sharding than the save used: leaves are
loaded on host and ``jax.device_put`` with the new sharding — this is the
elastic-rescale path (``runtime.elastic``).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, root: str, *, keep: int = 3, async_save: bool = True):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Any] = None
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def save(self, step: int, tree: Any, *, block: bool = False) -> None:
        """Host-gather then write; async by default (double-buffered)."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        fut = self._pool.submit(self._write, step, host_tree)
        self._pending = fut
        if block or not self.async_save:
            self.wait()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_tree: Any) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_tree)
        meta = {"step": step, "leaves": {}}
        for key, leaf in flat:
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), leaf)
            meta["leaves"][key] = {"file": fname,
                                   "shape": list(np.shape(leaf)),
                                   "dtype": str(np.asarray(leaf).dtype)}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[len("step_"):]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[Any, int]:
        """Load into the structure of ``template``; device_put with
        ``shardings`` when given (elastic reshard)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._step_dir(step)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        flat_t = _flatten(template)
        shard_flat = _flatten(shardings) if shardings is not None else None
        leaves = []
        for i, (key, leaf) in enumerate(flat_t):
            info = meta["leaves"][key]
            arr = np.load(os.path.join(d, info["file"]))
            want = tuple(np.shape(leaf)) if hasattr(leaf, "shape") else None
            if want is not None and tuple(arr.shape) != want:
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {want}")
            if shard_flat is not None:
                arr = jax.device_put(arr, shard_flat[i][1])
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, leaves), step
