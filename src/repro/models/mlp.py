"""Feed-forward blocks: dense (SwiGLU / GeGLU / squared-ReLU / ReLU) and
mixture-of-experts with sort-based static-shape dispatch (EP-friendly).

MoE dispatch avoids the O(T·E·C) GShard one-hot tensor: tokens are argsorted
by expert id, ranked within their expert, and scattered into (E, C) slots —
index arrays only, static shapes, capacity drops are explicit.  Expert
matmuls run as (E, C, d) einsums with the expert dim sharded over the
``model``/EP axis.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import activation
from repro.models.params import ParamDef
from repro.sharding.ctx import constrain


def _gated(act: str) -> bool:
    return act in ("swiglu", "geglu")


def _act_fn(act: str):
    return {"swiglu": jax.nn.silu, "geglu":
            lambda x: jax.nn.gelu(x, approximate=True)}.get(act) or activation(act)


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------


def dense_params(cfg: ArchConfig, ff: Optional[int] = None) -> Dict[str, ParamDef]:
    d = cfg.d_model
    ff = ff or cfg.d_ff
    p = {"w_up": ParamDef((d, ff), ("embed", "ffn")),
         "w_down": ParamDef((ff, d), ("ffn", "embed"))}
    if _gated(cfg.act):
        p["w_gate"] = ParamDef((d, ff), ("embed", "ffn"))
    return p


def dense_apply(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    h = x @ p["w_up"]
    if _gated(cfg.act):
        h = _act_fn(cfg.act)(x @ p["w_gate"]) * h
    else:
        h = _act_fn(cfg.act)(h)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of experts
# ---------------------------------------------------------------------------


def moe_params(cfg: ArchConfig) -> Dict[str, ParamDef]:
    assert cfg.moe is not None
    m, d = cfg.moe, cfg.d_model
    ff = m.d_ff_expert
    p = {
        "router": ParamDef((d, m.n_experts), ("embed", None), dtype="float32"),
        "w_up": ParamDef((m.n_experts, d, ff), ("expert", "embed", None)),
        "w_down": ParamDef((m.n_experts, ff, d), ("expert", None, "embed")),
    }
    if _gated(cfg.act):
        p["w_gate"] = ParamDef((m.n_experts, d, ff), ("expert", "embed", None))
    if m.n_shared:
        sp = dense_params(cfg, ff=m.n_shared * ff)
        p.update({f"shared_{k}": v for k, v in sp.items()})
    return p


def _capacity(tokens: int, m) -> int:
    c = int(math.ceil(tokens * m.top_k / m.n_experts * m.capacity_factor))
    return max(4, c)


def moe_apply(p, cfg: ArchConfig, x: jax.Array, *, no_drop: bool = False,
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (B, S, d), aux metrics (load-balance/z losses).

    ``no_drop=True`` is the INFERENCE dispatch: expert capacity is raised
    to the worst case (every token to one expert) so no token is ever
    capacity-dropped.  Training keeps the standard capacity-factor drops,
    but drops depend on the token count T — a serving path that splits one
    prompt across prefill chunks (or pads it to a length bucket) would
    route identical tokens differently at different T, breaking
    chunked-vs-whole token identity.  With no_drop each token's output
    depends only on that token, so any chunking/padding of the same prompt
    produces bitwise-identical rows (chunked admission also keeps the
    (E, T, d) dispatch buffer small, since T is the chunk size).

    Dispatch is PER SEQUENCE (batch row): the argsort/rank/scatter all run
    along the row axis, and the batch dim is data-sharded — so token
    routing never communicates.  A single flattened (B·S·K) sort made XLA
    emit a *distributed* sort (~1 TiB of all-reduce/collective-permute per
    step on the MoE train cells; §Perf B1).  Capacity is per row.
    """
    # NOTE §Perf B (deepseek train_4k hillclimb): three dispatch
    # reformulations were measured against this implementation and ALL
    # regressed on the compiled-HLO terms —
    #   B1 per-row argsort:        coll 22.6->21.0 s but mem 24.3->39.1 s,
    #                              peak 14.5->56 GiB;
    #   B2 pinned routing specs:   coll 131 s (resharding ping-pong);
    #   B3 sort-free cumsum rank:  same coll as B1, mem 35.9 s;
    #   B5 no-FSDP (pure EP/TP):   compiled flops x7, peak 75 GiB.
    # Root cause of the residual collective term is the FSDP layout
    # contracting expert matmuls over the data-sharded d dim plus the
    # per-microbatch expert-grad reductions; the proper fix (shard_map
    # local grad accumulation) is recorded as future work in EXPERIMENTS.md.
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    top_p, top_e = jax.lax.top_k(probs, K)                    # (T, K)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)         # renormalize

    C = T if no_drop else _capacity(T, m)
    # ---- sort-based dispatch ----
    e_flat = top_e.reshape(-1)                                # (T*K,)
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    # rank within expert: position - first-occurrence(expert)
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank = jnp.arange(T * K) - starts[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)        # overflow -> sink
    token_of = order // K

    # gather tokens into (E*C + 1, d) slots (last row = overflow sink)
    xe = jnp.zeros((E * C + 1, d), xf.dtype).at[slot].set(xf[token_of])
    xe = xe[:-1].reshape(E, C, d)

    h = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    if _gated(cfg.act):
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
        h = _act_fn(cfg.act)(g) * h
    else:
        h = _act_fn(cfg.act)(h)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], 0)

    # combine: inverse permutation back to (T, K) slots
    slot_tk = jnp.zeros((T * K,), jnp.int32).at[order].set(slot.astype(jnp.int32))
    y_tk = ye[slot_tk].reshape(T, K, d)
    y = jnp.einsum("tkd,tk->td", y_tk.astype(jnp.float32),
                   top_p.astype(jnp.float32)).astype(x.dtype)

    if m.n_shared:
        sp = {k[len("shared_"):]: v for k, v in p.items() if k.startswith("shared_")}
        y = y + dense_apply(sp, cfg, xf)

    # aux losses (Switch-style load balance + router z-loss)
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)       # (T,K,E)
    frac_tokens = jnp.mean(jnp.sum(onehot, 1), 0)              # f_e
    frac_probs = jnp.mean(probs, 0)                            # P_e
    lb_loss = E * jnp.sum(frac_tokens * frac_probs)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, -1)))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss, "moe_drop_frac": dropped}
    return y.reshape(B, S, d), aux


def moe_loss(aux: Dict[str, jax.Array], cfg: ArchConfig) -> jax.Array:
    m = cfg.moe
    return (m.aux_loss_weight * aux["moe_lb_loss"]
            + m.router_z_weight * aux["moe_z_loss"])
