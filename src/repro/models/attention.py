"""Attention layers: GQA (with qk-norm / softcap / sliding window) and
DeepSeek MLA — train/prefill blocked-flash paths and LeoAM sparse decode.

The absorbed-MLA cache is ONE latent row per token (ckv ‖ krope); the
serving engine tiers exactly that row through its single-plane store and
scores chunks in latent space (see docs/ARCHITECTURE.md), so the cache
builders here and the engine's chunked-admission path must zero/pad
identically — that invariant is what the bucketed/chunked parity tests
pin down.

Decode-path distribution: the KV cache sequence dim is sharded over the mesh
axes returned by ``sharding.partition.seq_shard_axes`` and attention runs
inside ``shard_map`` — chunk selection and the gathered flash attention are
fully shard-local; only the O(B·H) partial-softmax combine crosses shards
(DESIGN.md §2).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import sparse_attention as sa
from repro.core.abstracts import Pyramid, build_pyramid, num_levels, update_pyramid
from repro.models.common import rms_norm, rotate, softcap
from repro.models.params import ParamDef
from repro.sharding.ctx import constrain, constrain_priority, shard_map


# ---------------------------------------------------------------------------
# Decode context: how decode shards the cache
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DecodeCtx:
    """Static decode-distribution info (None mesh => pure local execution)."""
    mesh: Optional[Mesh] = None
    seq_axes: Tuple[str, ...] = ()
    batch_axes: Tuple[str, ...] = ()

    @property
    def n_seq_shards(self) -> int:
        if self.mesh is None or not self.seq_axes:
            return 1
        return math.prod(self.mesh.shape[a] for a in self.seq_axes)


LOCAL_CTX = DecodeCtx()


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def gqa_params(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d, hd = cfg.d_model, cfg.hd
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": ParamDef((d, H * hd), ("embed", "heads")),
        "wk": ParamDef((d, Hkv * hd), ("embed", "kv")),
        "wv": ParamDef((d, Hkv * hd), ("embed", "kv")),
        "wo": ParamDef((H * hd, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = ParamDef((hd,), (None,), init="ones")
        p["k_norm"] = ParamDef((hd,), (None,), init="ones")
    return p


def mla_params(cfg: ArchConfig) -> Dict[str, ParamDef]:
    assert cfg.mla is not None
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {
        "wkv_a": ParamDef((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", None)),
        "kv_norm": ParamDef((m.kv_lora_rank,), (None,), init="ones"),
        "wk_b": ParamDef((H, m.kv_lora_rank, m.qk_nope_head_dim), ("heads", None, None)),
        "wv_b": ParamDef((H, m.kv_lora_rank, m.v_head_dim), ("heads", None, None)),
        "wo": ParamDef((H * m.v_head_dim, d), ("heads", "embed")),
    }
    if m.q_lora_rank:
        p["wq_a"] = ParamDef((d, m.q_lora_rank), ("embed", None))
        p["q_norm_a"] = ParamDef((m.q_lora_rank,), (None,), init="ones")
        p["wq_b"] = ParamDef((m.q_lora_rank, H * qk), (None, "heads"))
    else:
        p["wq"] = ParamDef((d, H * qk), ("embed", "heads"))
    return p


def attn_params(cfg: ArchConfig) -> Dict[str, ParamDef]:
    return mla_params(cfg) if cfg.mla is not None else gqa_params(cfg)


# ---------------------------------------------------------------------------
# Cache definitions
# ---------------------------------------------------------------------------


def gqa_cache_defs(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, ParamDef]:
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    chunk = cfg.leoam.chunk_size
    defs = {
        "k": ParamDef((batch, max_len, Hkv, hd), ("batch", "kv_seq", "kv", None), init="zeros"),
        "v": ParamDef((batch, max_len, Hkv, hd), ("batch", "kv_seq", "kv", None), init="zeros"),
    }
    if cfg.leoam.enabled:
        nc0 = max_len // chunk
        for lvl in range(num_levels(nc0, cfg.leoam.pyramid_levels)):
            nc = nc0 >> lvl
            defs[f"kmax{lvl}"] = ParamDef((batch, nc, Hkv, hd),
                                          ("batch", "kv_seq", "kv", None),
                                          init="zeros", dtype="float32")
            defs[f"kmin{lvl}"] = ParamDef((batch, nc, Hkv, hd),
                                          ("batch", "kv_seq", "kv", None),
                                          init="zeros", dtype="float32")
    return defs


def mla_cache_defs(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, ParamDef]:
    assert cfg.mla is not None
    m = cfg.mla
    chunk = cfg.leoam.chunk_size
    defs = {
        "ckv": ParamDef((batch, max_len, m.kv_lora_rank), ("batch", "kv_seq", None), init="zeros"),
        "krope": ParamDef((batch, max_len, m.qk_rope_head_dim), ("batch", "kv_seq", None), init="zeros"),
    }
    if cfg.leoam.enabled:
        nc0 = max_len // chunk
        for lvl in range(num_levels(nc0, cfg.leoam.pyramid_levels)):
            nc = nc0 >> lvl
            for nm, dim in (("cmax", m.kv_lora_rank), ("cmin", m.kv_lora_rank),
                            ("rmax", m.qk_rope_head_dim), ("rmin", m.qk_rope_head_dim)):
                defs[f"{nm}{lvl}"] = ParamDef((batch, nc, 1, dim),
                                              ("batch", "kv_seq", None, None),
                                              init="zeros", dtype="float32")
    return defs


def cache_defs(cfg: ArchConfig, kind: str, batch: int, max_len: int):
    if not kind.startswith("attn"):
        return None
    if cfg.mla is not None:
        return mla_cache_defs(cfg, batch, max_len)
    return gqa_cache_defs(cfg, batch, max_len)


def _pyr_from_cache(cache: Dict[str, jax.Array], prefix: str = "k") -> Pyramid:
    kmaxs, kmins, lvl = [], [], 0
    while f"{prefix}max{lvl}" in cache:
        kmaxs.append(cache[f"{prefix}max{lvl}"])
        kmins.append(cache[f"{prefix}min{lvl}"])
        lvl += 1
    return Pyramid(tuple(kmaxs), tuple(kmins))


# ---------------------------------------------------------------------------
# Blocked causal attention (train / prefill)
# ---------------------------------------------------------------------------


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      attn_softcap: Optional[float] = None,
                      block_q: int = 512, block_kv: int = 1024,
                      cross: bool = False, q_offset=0) -> jax.Array:
    """Flash-style attention: full query rows × scanned KV blocks.

    q: (B, S, H, hd) pre-scaled; k/v: (B, Skv, Hkv, hd).  Shardability is
    the design driver: queries keep a flat head dim (sharded over ``model``
    when H divides, else the S dim is sharded) and KV blocks are expanded to
    H heads *inside* the scan (a local slice of replicated KV) — no
    collective ever lands inside the loop.  O(S·block) memory.
    ``cross=True`` disables the causal mask (encoder-decoder).

    ``q_offset`` (static or traced scalar) places the query rows at global
    positions ``q_offset + [0, S)`` against the keys' absolute positions —
    the chunked-prefill path attends one prompt chunk against the whole
    (zero-initialised) decode cache, and the causal mask alone keeps
    not-yet-written / padding key rows out of every valid query row.
    """
    B, S, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    vd = v.shape[-1]                                     # may differ (MLA)
    G = H // Hkv
    bkv = min(block_kv, Skv)
    nkv = Skv // bkv
    assert Skv % bkv == 0, (Skv, bkv)

    q = constrain_priority(q, ("batch", None, "heads", None),
                           ("batch", "act_seq", None, None))
    k = constrain(k, ("batch", None, None, None))        # replicated / model
    v = constrain(v, ("batch", None, None, None))
    kb = k.reshape(B, nkv, bkv, Hkv, hd)
    vb = v.reshape(B, nkv, bkv, Hkv, vd)
    q_pos = jnp.arange(S) + q_offset

    def kv_step(carry, kj_and_kv):
        num, den, m = carry
        kj, kblk, vblk = kj_and_kv
        kh = jnp.repeat(kblk, G, axis=2)                 # (B,bkv,H,hd) local
        vh = jnp.repeat(vblk, G, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kh,
                       preferred_element_type=jnp.float32)
        if attn_softcap is not None:
            s = attn_softcap * jnp.tanh(s / attn_softcap)
        k_pos = kj * bkv + jnp.arange(bkv)
        mask = jnp.ones((S, bkv), bool)
        if causal and not cross:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= k_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(mask[None, None], s, sa.NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        scale_old = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        e = jnp.exp(s - m_safe[..., None])
        e = jnp.where(mask[None, None], e, 0.0)
        num = num * scale_old[..., None] + jnp.einsum(
            "bhqk,bkhv->bhqv", e, vh.astype(jnp.float32))
        den = den * scale_old + jnp.sum(e, axis=-1)
        return (num, den, m_new), None

    init = (jnp.zeros((B, H, S, vd), jnp.float32),
            jnp.zeros((B, H, S), jnp.float32),
            jnp.full((B, H, S), sa.NEG_INF, jnp.float32))
    (num, den, _), _ = jax.lax.scan(
        kv_step, init,
        (jnp.arange(nkv), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
    den = jnp.where(den == 0.0, 1.0, den)
    out = jnp.moveaxis(num / den[..., None], 1, 2)       # (B,S,H,vd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------


def _qkv(p, cfg: ArchConfig, x: jax.Array, pos) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rotate(cfg, q, pos)
    k = rotate(cfg, k, pos)
    return q, k, v


def gqa_train(p, cfg: ArchConfig, kind: str, x: jax.Array, pos,
              cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
              causal: bool = True) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    if cross_kv is not None:
        # cross-attention: no RoPE (keys are un-rotated encoder projections)
        q = (x @ p["wq"]).reshape(B, S, H, hd)
        k, v = cross_kv
        causal = False
    else:
        q, k, v = _qkv(p, cfg, x, pos)
    window = cfg.window if kind == "attn_local" else None
    out = blocked_attention(
        q * (1.0 / math.sqrt(hd)), k, v, causal=causal, window=window,
        attn_softcap=cfg.attn_softcap,
        block_q=cfg.runtime.attn_block_q, block_kv=cfg.runtime.attn_block_kv)
    return out.reshape(B, S, H * hd) @ p["wo"]


def cross_kv(p, cfg: ArchConfig, enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Encoder-output K/V for cross-attention (computed once per request)."""
    B, S, d = enc_out.shape
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    k = (enc_out @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (enc_out @ p["wv"]).reshape(B, S, Hkv, hd)
    return k, v


def gqa_prefill_cache(cfg: ArchConfig, k: jax.Array, v: jax.Array,
                      max_len: int, length) -> Dict[str, jax.Array]:
    """Build the decode cache (padded KV + abstract pyramid) after prefill.

    Rows at positions >= ``length`` are zeroed before the pad: with bucketed
    prefill the prompt rides in padded to a bucket size, and the tier store
    ingests this cache — zeroing the bucket-padding rows keeps the stored
    chunks (and their min/max abstracts) bit-identical to exact-length
    prefill, whose pad rows were already zeros."""
    B, S, Hkv, hd = k.shape
    valid = (jnp.arange(S, dtype=jnp.int32)
             < jnp.asarray(length, jnp.int32))[None, :, None, None]
    k = jnp.where(valid, k, 0)
    v = jnp.where(valid, v, 0)
    pad = max_len - S
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # pin to the decode layout NOW — otherwise the prefill layer scan stacks
    # every layer's cache replicated before one big reshard (observed tens
    # of GiB of scan-ys buffering on the 32k prefill cells)
    kp = constrain(kp, ("batch", "kv_seq", "kv", None))
    vp = constrain(vp, ("batch", "kv_seq", "kv", None))
    cache = {"k": kp, "v": vp}
    if cfg.leoam.enabled:
        chunk = cfg.leoam.chunk_size
        pyr = build_pyramid(kp, chunk, cfg.leoam.pyramid_levels, length=length)
        for lvl in range(pyr.levels):
            cache[f"kmax{lvl}"] = constrain(pyr.kmax[lvl],
                                            ("batch", "kv_seq", "kv", None))
            cache[f"kmin{lvl}"] = constrain(pyr.kmin[lvl],
                                            ("batch", "kv_seq", "kv", None))
    return cache


def _layer_budget(cfg: ArchConfig, layer_idx: int, n_local_chunks: int,
                  n_seq_shards: int = 1) -> int:
    lcfg = cfg.leoam
    rate = lcfg.early_rate if layer_idx < lcfg.early_layers else lcfg.importance_rate
    # global sink/recent forcing (§Perf C3): with >1 sequence shard, no
    # single shard hosts both the sink and the tail, so the static budget
    # only reserves max(sink, recent) slots instead of their sum
    if n_seq_shards > 1:
        forced = max(lcfg.sink_chunks, lcfg.recent_chunks)
    else:
        forced = lcfg.sink_chunks + lcfg.recent_chunks
    want = int(math.ceil(n_local_chunks * rate)) + forced
    return max(1, min(n_local_chunks, want))


def gqa_decode(p, cfg: ArchConfig, kind: str, x: jax.Array,
               cache: Dict[str, jax.Array], length: jax.Array, *,
               layer_idx: int, ctx: DecodeCtx = LOCAL_CTX,
               cross_kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step.  x: (B, 1, d); length: scalar current cache length."""
    B, _, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    scale = 1.0 / math.sqrt(hd)

    if cross_kv_cache is not None:
        q = (x @ p["wq"]).reshape(B, H, hd)
        ck, cv = cross_kv_cache
        part = sa.dense_decode_gqa(q * scale, ck, cv, length=ck.shape[1])
        out = sa._finish(part).astype(x.dtype)
        return (out.reshape(B, 1, H * hd) @ p["wo"]), cache

    pos = jnp.full((B, 1), length, jnp.int32)
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, B, 1))
    q, k_new, v_new = _qkv(p, cfg, x, pos)
    q = (q[:, 0] * scale)                                    # (B, H, hd)
    k_new, v_new = k_new[:, 0], v_new[:, 0]                  # (B, Hkv, hd)

    S_total = cache["k"].shape[1]
    chunk = cfg.leoam.chunk_size
    use_sparse = (cfg.leoam.enabled and kind != "attn_local"
                  and S_total >= cfg.leoam.min_seq_for_sparse)
    window = cfg.window if kind == "attn_local" else None

    # NOTE (§Perf C2, refuted): moving the cache write OUTSIDE the
    # shard_map (global DUS on the sharded seq dim, letting SPMD localize
    # it) was measured WORSE — XLA partitions a traced-index DUS on a
    # sharded dim with cache-scale collective traffic (22 MB -> 1.7 GiB
    # per step).  Writes stay inside the manual region, conditioned on the
    # owner shard, touching only the written slice.
    def local_fn(q, k_new, v_new, length, *cache_leaves):
        names = sorted(cache.keys())
        c = dict(zip(names, cache_leaves))
        S_l = c["k"].shape[1]
        if ctx.seq_axes:
            shard_idx = jax.lax.axis_index(ctx.seq_axes).astype(jnp.int32)
        else:
            shard_idx = jnp.int32(0)
        owner = (length // S_l) == shard_idx
        wpos = (length % S_l).astype(jnp.int32)
        old_k = jax.lax.dynamic_slice_in_dim(c["k"], wpos, 1, axis=1)
        old_v = jax.lax.dynamic_slice_in_dim(c["v"], wpos, 1, axis=1)
        new_k = jnp.where(owner, k_new[:, None].astype(c["k"].dtype), old_k)
        new_v = jnp.where(owner, v_new[:, None].astype(c["v"].dtype), old_v)
        c["k"] = jax.lax.dynamic_update_slice_in_dim(c["k"], new_k, wpos, axis=1)
        c["v"] = jax.lax.dynamic_update_slice_in_dim(c["v"], new_v, wpos, axis=1)
        if use_sparse:
            pyr = _pyr_from_cache(c)
            k_eff = jnp.where(owner, k_new.astype(jnp.float32),
                              jnp.full_like(k_new, -jnp.inf, jnp.float32))
            k_eff_min = jnp.where(owner, k_new.astype(jnp.float32),
                                  jnp.full_like(k_new, jnp.inf, jnp.float32))
            pyr = Pyramid(
                update_pyramid(pyr, k_eff, wpos, chunk).kmax,
                update_pyramid(Pyramid(pyr.kmax, pyr.kmin), k_eff_min,
                               wpos, chunk).kmin)
            for lvl in range(pyr.levels):
                c[f"kmax{lvl}"] = pyr.kmax[lvl]
                c[f"kmin{lvl}"] = pyr.kmin[lvl]
        local_len = jnp.clip(length + 1 - shard_idx * S_l, 0, S_l)
        if use_sparse:
            budget = _layer_budget(cfg, layer_idx, S_l // chunk,
                                   ctx.n_seq_shards)
            # sink/recent forcing is in GLOBAL chunk coordinates (§Perf C3)
            global_valid = (length + chunk) // chunk
            offset = shard_idx * (S_l // chunk)
            part = sa.leoam_decode_shard(
                q, c["k"], c["v"], pyr, chunk=chunk, budget=budget,
                length=local_len, attn_softcap=cfg.attn_softcap,
                sink_chunks=cfg.leoam.sink_chunks,
                recent_chunks=cfg.leoam.recent_chunks,
                rf=cfg.leoam.refine_factor, n_valid_chunks=global_valid,
                chunk_offset=offset)
        else:
            part = sa.dense_decode_gqa(
                q, c["k"], c["v"], length=local_len,
                attn_softcap=cfg.attn_softcap, window=window,
                base_pos=shard_idx * S_l, query_pos=length)
        out = sa.combine_partials(part, ctx.seq_axes)
        return (out, *[c[n] for n in names])

    names = sorted(cache.keys())
    if ctx.seq_axes:
        db = ctx.batch_axes
        cache_spec = {
            n: P(db or None, ctx.seq_axes if len(ctx.seq_axes) > 1 else ctx.seq_axes[0],
                 *([None] * (cache[n].ndim - 2))) for n in names}
        fn = shard_map(
            local_fn, mesh=ctx.mesh,
            in_specs=(P(db or None, None, None), P(db or None, None, None),
                      P(db or None, None, None), P(),
                      *[cache_spec[n] for n in names]),
            out_specs=(P(db or None, None, None), *[cache_spec[n] for n in names]),
            check_vma=False)
    else:
        fn = local_fn
    out, *new_leaves = fn(q, k_new, v_new, length, *[cache[n] for n in names])
    new_cache = dict(zip(names, new_leaves))
    out = out.astype(x.dtype).reshape(B, 1, H * hd)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLA layer (DeepSeek): absorbed decode, latent-space LeoAM selection
# ---------------------------------------------------------------------------


def _mla_q(p, cfg: ArchConfig, x: jax.Array, pos) -> Tuple[jax.Array, jax.Array]:
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        qa = rms_norm(x @ p["wq_a"], p["q_norm_a"], cfg.norm_eps)
        q = (qa @ p["wq_b"]).reshape(B, S, H, qk)
    else:
        q = (x @ p["wq"]).reshape(B, S, H, qk)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = rotate(cfg, q[..., m.qk_nope_head_dim:], pos)
    return q_nope, q_rope


def mla_train(p, cfg: ArchConfig, kind: str, x: jax.Array, pos) -> jax.Array:
    """Non-absorbed MLA for train/prefill (materializes per-head K/V)."""
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(p, cfg, x, pos)
    kv_a = x @ p["wkv_a"]
    ckv = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = rotate(cfg, kv_a[..., None, m.kv_lora_rank:], pos)   # (B,S,1,rr)
    k_nope = jnp.einsum("bsr,hrd->bshd", ckv, p["wk_b"])
    val = jnp.einsum("bsr,hrd->bshd", ckv, p["wv_b"])
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, S, H, m.qk_rope_head_dim))], -1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    out = blocked_attention(q * scale, k, val, causal=True,
                            block_q=cfg.runtime.attn_block_q,
                            block_kv=cfg.runtime.attn_block_kv)
    return out.reshape(B, S, H * m.v_head_dim) @ p["wo"]


def mla_prefill_cache(p, cfg: ArchConfig, x: jax.Array, pos, max_len: int,
                      length) -> Dict[str, jax.Array]:
    """Build the absorbed-MLA decode cache (latent ckv/krope + abstract
    pyramids) after prefill.

    ``length`` (static or traced) marks the prompt's true length under
    bucketed prefill: rows at positions >= length are zeroed BEFORE the
    max_len pad, exactly as :func:`gqa_prefill_cache` — the serving
    engine ingests these latents into its single-plane tier store
    (concat(ckv, krope) per token), so bucket-padding rows must match
    the exact-length path bit-for-bit for chunk replicas and min/max
    abstracts to agree."""
    m = cfg.mla
    B, S, _ = x.shape
    kv_a = x @ p["wkv_a"]
    ckv = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    krope = rotate(cfg, kv_a[..., None, m.kv_lora_rank:], pos)[:, :, 0]
    # zero bucket-padding rows (see gqa_prefill_cache)
    valid = (jnp.arange(S, dtype=jnp.int32)
             < jnp.asarray(length, jnp.int32))[None, :, None]
    ckv = jnp.where(valid, ckv, 0)
    krope = jnp.where(valid, krope, 0)
    pad = max_len - S
    ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
    krope = jnp.pad(krope, ((0, 0), (0, pad), (0, 0)))
    ckv = constrain(ckv, ("batch", "kv_seq", None))
    krope = constrain(krope, ("batch", "kv_seq", None))
    cache = {"ckv": ckv, "krope": krope}
    if cfg.leoam.enabled:
        chunk = cfg.leoam.chunk_size
        cs = ("batch", "kv_seq", None, None)
        pc = build_pyramid(ckv[:, :, None], chunk, cfg.leoam.pyramid_levels,
                           length=length)
        pr = build_pyramid(krope[:, :, None], chunk, cfg.leoam.pyramid_levels,
                           length=length)
        for lvl in range(pc.levels):
            cache[f"cmax{lvl}"] = constrain(pc.kmax[lvl], cs)
            cache[f"cmin{lvl}"] = constrain(pc.kmin[lvl], cs)
            cache[f"rmax{lvl}"] = constrain(pr.kmax[lvl], cs)
            cache[f"rmin{lvl}"] = constrain(pr.kmin[lvl], cs)
    return cache


def mla_decode(p, cfg: ArchConfig, kind: str, x: jax.Array,
               cache: Dict[str, jax.Array], length: jax.Array, *,
               layer_idx: int, ctx: DecodeCtx = LOCAL_CTX
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    m = cfg.mla
    B, _, d = x.shape
    H = cfg.n_heads
    pos = jnp.full((B, 1), length, jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, pos)
    # absorb W_UK into the query: q_lat = q_nope @ W_UK  -> latent space
    q_lat = jnp.einsum("bhd,hrd->bhr", q_nope[:, 0], p["wk_b"])
    q_rope = q_rope[:, 0]
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_lat, q_rope = q_lat * scale, q_rope * scale

    kv_a = (x @ p["wkv_a"])[:, 0]
    ckv_new = rms_norm(kv_a[:, : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    krope_new = rotate(cfg, kv_a[:, None, None, m.kv_lora_rank:], pos)[:, 0, 0]

    S_total = cache["ckv"].shape[1]
    chunk = cfg.leoam.chunk_size
    use_sparse = (cfg.leoam.enabled and S_total >= cfg.leoam.min_seq_for_sparse)

    # writes stay inside the manual region (see §Perf C2 note in gqa_decode)
    def local_fn(q_lat, q_rope, ckv_new, krope_new, length, *cache_leaves):
        names = sorted(cache.keys())
        c = dict(zip(names, cache_leaves))
        S_l = c["ckv"].shape[1]
        if ctx.seq_axes:
            shard_idx = jax.lax.axis_index(ctx.seq_axes).astype(jnp.int32)
        else:
            shard_idx = jnp.int32(0)
        owner = (length // S_l) == shard_idx
        wpos = (length % S_l).astype(jnp.int32)
        old_ck = jax.lax.dynamic_slice_in_dim(c["ckv"], wpos, 1, axis=1)
        old_kr = jax.lax.dynamic_slice_in_dim(c["krope"], wpos, 1, axis=1)
        new_ck = jnp.where(owner, ckv_new[:, None].astype(c["ckv"].dtype), old_ck)
        new_kr = jnp.where(owner, krope_new[:, None].astype(c["krope"].dtype), old_kr)
        c["ckv"] = jax.lax.dynamic_update_slice_in_dim(c["ckv"], new_ck, wpos, axis=1)
        c["krope"] = jax.lax.dynamic_update_slice_in_dim(c["krope"], new_kr, wpos, axis=1)
        if use_sparse:
            def upd_pyr(pyr, vec):
                hi = jnp.where(owner, vec.astype(jnp.float32),
                               jnp.full_like(vec, -jnp.inf, jnp.float32))
                lo = jnp.where(owner, vec.astype(jnp.float32),
                               jnp.full_like(vec, jnp.inf, jnp.float32))
                return Pyramid(update_pyramid(pyr, hi, wpos, chunk).kmax,
                               update_pyramid(pyr, lo, wpos, chunk).kmin)
            pc = upd_pyr(_pyr_from_cache(c, "c"), ckv_new[:, None])
            pr = upd_pyr(_pyr_from_cache(c, "r"), krope_new[:, None])
            for lvl in range(pc.levels):
                c[f"cmax{lvl}"], c[f"cmin{lvl}"] = pc.kmax[lvl], pc.kmin[lvl]
                c[f"rmax{lvl}"], c[f"rmin{lvl}"] = pr.kmax[lvl], pr.kmin[lvl]
        local_len = jnp.clip(length + 1 - shard_idx * S_l, 0, S_l)
        if use_sparse:
            budget = _layer_budget(cfg, layer_idx, S_l // chunk,
                                   ctx.n_seq_shards)
            global_valid = (length + chunk) // chunk
            offset = shard_idx * (S_l // chunk)
            from repro.core.adaptive import pyramid_select_mla
            ids = pyramid_select_mla(q_lat, q_rope, pc, pr, budget,
                                     rf=cfg.leoam.refine_factor,
                                     sink_chunks=cfg.leoam.sink_chunks,
                                     recent_chunks=cfg.leoam.recent_chunks,
                                     n_valid0=global_valid,
                                     chunk_offset=offset)
            part = sa.sparse_decode_mla(q_lat, q_rope, c["ckv"], c["krope"],
                                        ids, chunk, length=local_len)
        else:
            part = sa.dense_decode_mla(q_lat, q_rope, c["ckv"], c["krope"],
                                       length=local_len)
        out_lat = sa.combine_partials(part, ctx.seq_axes)     # (B,H,r)
        return (out_lat, *[c[n] for n in names])

    names = sorted(cache.keys())
    if ctx.seq_axes:
        db = ctx.batch_axes
        seqs = ctx.seq_axes if len(ctx.seq_axes) > 1 else ctx.seq_axes[0]
        cache_spec = {n: P(db or None, seqs, *([None] * (cache[n].ndim - 2)))
                      for n in names}
        fn = shard_map(
            local_fn, mesh=ctx.mesh,
            in_specs=(P(db or None, None, None), P(db or None, None, None),
                      P(db or None, None), P(db or None, None), P(),
                      *[cache_spec[n] for n in names]),
            out_specs=(P(db or None, None, None), *[cache_spec[n] for n in names]),
            check_vma=False)
    else:
        fn = local_fn
    out_lat, *new_leaves = fn(q_lat, q_rope, ckv_new, krope_new, length,
                              *[cache[n] for n in names])
    new_cache = dict(zip(names, new_leaves))
    # absorbed value up-projection: (B,H,r) @ (H,r,vd) -> (B,H,vd)
    out = jnp.einsum("bhr,hrv->bhv", out_lat.astype(jnp.float32),
                     p["wv_b"].astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(B, 1, H * m.v_head_dim)
    return out @ p["wo"], new_cache
