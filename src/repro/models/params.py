"""Module-free parameter system.

A model is described by a pytree (nested dicts) of :class:`ParamDef`; the same
tree yields initialized arrays (``init_tree``) and logical
``PartitionSpec``s (``spec_tree``).  Logical axis names are resolved to mesh
axes by ``repro.sharding.partition.logical_to_mesh`` with divisibility
fallback, so one rule set serves every architecture.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Logical axes used across the zoo:
#   "embed"  — d_model dims              (FSDP-sharded)
#   "ffn"    — mlp hidden dims           (TP-sharded)
#   "heads"  — q-head dims               (TP-sharded)
#   "kv"     — kv-head dims              (TP if divisible, else replicated)
#   "vocab"  — vocabulary dim            (TP-sharded)
#   "expert" — MoE expert dim            (EP = TP axis)
#   "layer"  — stacked-layer dim         (never sharded)
#   None     — replicated


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis per dim
    init: str = "fan_in"                      # fan_in | embed | zeros | ones
    dtype: Optional[str] = None               # override model dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: Tuple[int, ...]) -> int:
    return shape[-2] if len(shape) >= 2 else shape[-1]


def init_leaf(rng: jax.Array, d: ParamDef, dtype: Any) -> jax.Array:
    dt = jnp.dtype(d.dtype) if d.dtype else dtype
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    if d.init == "embed":
        return (jax.random.normal(rng, d.shape, jnp.float32) * 0.02).astype(dt)
    if d.init == "fan_in":
        scale = 1.0 / np.sqrt(max(1, _fan_in(d.shape)))
        return (jax.random.normal(rng, d.shape, jnp.float32) * scale).astype(dt)
    raise ValueError(f"unknown init {d.init!r}")


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def init_tree(defs: Any, rng: jax.Array, dtype: Any) -> Any:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    rngs = jax.random.split(rng, len(leaves))
    out = [init_leaf(r, d, dtype) for r, d in zip(rngs, leaves)]
    return jax.tree.unflatten(treedef, out)


def abstract_tree(defs: Any, dtype: Any) -> Any:
    """ShapeDtypeStruct mirror (for dry-run lowering without allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(
            d.shape, jnp.dtype(d.dtype) if d.dtype else dtype),
        defs, is_leaf=is_def)


def axes_tree(defs: Any) -> Any:
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_def)
