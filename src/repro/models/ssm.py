"""Mamba (selective SSM) block — Jamba's recurrent layer.

Train/prefill uses a parallel associative scan over time; decode is a
single-step recurrence on carried (conv window, SSM state).  The d_inner
dimension is TP-sharded (logical axis "ffn"), which also keeps the
(B, S, d_inner, d_state) scan intermediate shard-local.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.params import ParamDef


def _dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    dt_rank = m.dt_rank or -(-cfg.d_model // 16)
    return d_in, m.d_state, m.d_conv, dt_rank


def mamba_params(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    d_in, ds, dc, dtr = _dims(cfg)
    return {
        "in_proj": ParamDef((d, 2 * d_in), ("embed", "ffn")),
        "conv_w": ParamDef((dc, d_in), (None, "ffn")),
        "conv_b": ParamDef((d_in,), ("ffn",), init="zeros"),
        "x_proj": ParamDef((d_in, dtr + 2 * ds), ("ffn", None)),
        "dt_proj": ParamDef((dtr, d_in), (None, "ffn")),
        "dt_bias": ParamDef((d_in,), ("ffn",), init="zeros"),
        "a_log": ParamDef((d_in, ds), ("ffn", None), init="ones", dtype="float32"),
        "d_skip": ParamDef((d_in,), ("ffn",), init="ones", dtype="float32"),
        "out_proj": ParamDef((d_in, d), ("ffn", "embed")),
    }


def mamba_cache_defs(cfg: ArchConfig, batch: int) -> Dict[str, ParamDef]:
    d_in, ds, dc, _ = _dims(cfg)
    return {
        "conv": ParamDef((batch, dc - 1, d_in), ("batch", None, "ffn"),
                         init="zeros"),
        "state": ParamDef((batch, d_in, ds), ("batch", "ffn", None),
                          init="zeros", dtype="float32"),
    }


def _ssm_inputs(p, cfg: ArchConfig, xc: jax.Array):
    """xc: post-conv activations (..., d_in) -> (dt, Bc, Cc, A)."""
    d_in, ds, _, dtr = _dims(cfg)
    proj = xc @ p["x_proj"]
    dt, Bc, Cc = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])       # (..., d_in)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                 # (d_in, ds)
    return dt, Bc, Cc, A


def mamba_train(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, d) -> (B, S, d) via parallel associative scan."""
    B, S, d = x.shape
    d_in, ds, dc, _ = _dims(cfg)
    xz = x @ p["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)                            # (B,S,d_in)

    # causal depthwise conv over time
    xpad = jnp.pad(xr, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(xpad[:, i: i + S] * p["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu(xc + p["conv_b"])

    dt, Bc, Cc, A = _ssm_inputs(p, cfg, xc)
    dt32 = dt.astype(jnp.float32)
    # discretize: a_t = exp(dt*A); b_t = dt * B_t * x_t
    a = jnp.exp(dt32[..., None] * A)                             # (B,S,d_in,ds)
    bx = (dt32 * xc.astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[..., None, :]

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    y = jnp.einsum("bsdz,bsz->bsd", h, Cc.astype(jnp.float32))
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_decode(p, cfg: ArchConfig, x: jax.Array,
                 cache: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token step.  x: (B, 1, d); cache: {"conv","state"}."""
    B, _, d = x.shape
    d_in, ds, dc, _ = _dims(cfg)
    xz = (x @ p["in_proj"])[:, 0]
    xr, z = jnp.split(xz, 2, axis=-1)                            # (B,d_in)

    window = jnp.concatenate([cache["conv"], xr[:, None]], axis=1)  # (B,dc,d_in)
    xc = jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)

    dt, Bc, Cc, A = _ssm_inputs(p, cfg, xc)
    dt32 = dt.astype(jnp.float32)
    a = jnp.exp(dt32[..., None] * A)                             # (B,d_in,ds)
    bx = (dt32 * xc.astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[:, None, :]
    h = cache["state"] * a + bx
    y = jnp.einsum("bdz,bz->bd", h, Cc.astype(jnp.float32))
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    return out, {"conv": window[:, 1:], "state": h}
