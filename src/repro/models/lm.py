"""Model assembly: decoder-only LMs, hybrids, recurrent stacks, and
encoder-decoder — one functional API for all ten architectures.

Layer organisation: ``prologue`` layers (first ``cfg.prologue()``) are
unrolled — they carry the per-layer LeoAM early budgets and first-dense
MLPs — and the remaining layers form a pattern-periodic ``body`` that is
``lax.scan``-ned with parameters stacked per period position (compile time
independent of depth).

Entry points:
  init(cfg, rng) / param_defs(cfg) / abstract_params(cfg)
  forward_train(params, cfg, batch)          -> (loss, metrics)
  prefill(params, cfg, batch, max_len, ctx)  -> (logits, cache)
  decode_step(params, cfg, cache, batch, length, ctx) -> (logits, cache)
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import DecodeCtx, LOCAL_CTX
from repro.models.common import (cross_entropy, positions_for, rms_norm,
                                 rotate, softcap)
from repro.models.params import (ParamDef, abstract_tree, axes_tree,
                                 init_tree, is_def)
from repro.sharding.ctx import constrain

Params = Any


# ---------------------------------------------------------------------------
# Structure helpers
# ---------------------------------------------------------------------------


def _layer_plan(cfg: ArchConfig):
    """(prologue [(idx, kind, mlp)], body period [(kind, mlp)], repeats)."""
    kinds, mlps = cfg.layer_kinds(), cfg.mlp_kinds()
    pro_n = cfg.prologue()
    period = cfg.period()
    body = list(zip(kinds, mlps))[pro_n:]
    repeats = len(body) // period if body else 0
    assert repeats * period == len(body), (cfg.name, pro_n, period, len(body))
    prologue = [(i, kinds[i], mlps[i]) for i in range(pro_n)]
    return prologue, body[:period], repeats


def _core_params(cfg: ArchConfig, kind: str) -> Dict[str, ParamDef]:
    if kind.startswith("attn"):
        return attn.attn_params(cfg)
    if kind == "mamba":
        return ssm_mod.mamba_params(cfg)
    if kind == "mlstm":
        return xlstm_mod.mlstm_params(cfg)
    if kind == "slstm":
        return xlstm_mod.slstm_params(cfg)
    raise ValueError(kind)


def _block_defs(cfg: ArchConfig, kind: str, mlp_kind: str,
                cross: bool = False) -> Dict[str, Any]:
    d = cfg.d_model
    blk: Dict[str, Any] = {
        "ln1": ParamDef((d,), (None,), init="ones"),
        "core": _core_params(cfg, kind),
    }
    if mlp_kind == "dense":
        ff = cfg.d_ff_dense if mlp_kind == "dense" and cfg.d_ff_dense else None
        blk["ln2"] = ParamDef((d,), (None,), init="ones")
        blk["mlp"] = mlp_mod.dense_params(cfg, ff=ff)
    elif mlp_kind == "moe":
        blk["ln2"] = ParamDef((d,), (None,), init="ones")
        blk["mlp"] = mlp_mod.moe_params(cfg)
    if cross:
        blk["ln_x"] = ParamDef((d,), (None,), init="ones")
        blk["cross"] = attn.gqa_params(cfg)
    return blk


def _stack_defs(defs: Dict[str, Any], n: int) -> Dict[str, Any]:
    return jax.tree.map(
        lambda d: ParamDef((n, *d.shape), ("layer", *d.axes), d.init, d.dtype),
        defs, is_leaf=is_def)


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def _constrain_block_params(cfg: ArchConfig, period, layer_params):
    """Pin per-layer weight slices to their sharded layout inside the scan.

    Without this, XLA's sharding propagation is free to replicate the whole
    stacked body-weight tensor over the data axes before the loop — observed
    as a 42 GiB/device all-gather on nemotron-340b.  Constraining the slice
    keeps FSDP gathers per-layer and inside the loop.
    """
    cross = cfg.is_encdec and cfg.cross_attn
    out = []
    for pi, (kind, mlpk) in enumerate(period):
        axes = axes_tree(_block_defs(cfg, kind, mlpk, cross))
        out.append(jax.tree.map(lambda ax, w: constrain(w, ax), axes,
                                layer_params[pi], is_leaf=_is_axes))
    return tuple(out)


def param_defs(cfg: ArchConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab_size
    prologue, period, repeats = _layer_plan(cfg)
    cross = cfg.is_encdec and cfg.cross_attn
    defs: Dict[str, Any] = {
        "embed": ParamDef((V, d), ("vocab", "embed"), init="embed"),
        "final_norm": ParamDef((d,), (None,), init="ones"),
        "prologue": [_block_defs(cfg, k, m, cross) for (_, k, m) in prologue],
        "body": [_stack_defs(_block_defs(cfg, k, m, cross), repeats)
                 for (k, m) in period] if repeats else [],
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, V), ("embed", "vocab"))
    if cfg.is_encdec:
        enc_blk = _block_defs(cfg, "attn", "dense")
        defs["encoder"] = {
            "body": _stack_defs(enc_blk, cfg.enc_layers),
            "final_norm": ParamDef((d,), (None,), init="ones"),
        }
    return defs


def init(cfg: ArchConfig, rng: jax.Array) -> Params:
    return init_tree(param_defs(cfg), rng, jnp.dtype(cfg.dtype))


def abstract_params(cfg: ArchConfig) -> Params:
    return abstract_tree(param_defs(cfg), jnp.dtype(cfg.dtype))


# ---------------------------------------------------------------------------
# Cache structure
# ---------------------------------------------------------------------------


def _block_cache_defs(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      cross: bool = False) -> Optional[Dict[str, ParamDef]]:
    c: Dict[str, ParamDef] = {}
    if kind.startswith("attn"):
        c.update(attn.cache_defs(cfg, kind, batch, max_len) or {})
        if cross:
            Hkv, hd = cfg.n_kv_heads, cfg.hd
            enc_len = encoder_len(cfg, max_len)
            c["cross_k"] = ParamDef((batch, enc_len, Hkv, hd),
                                    ("batch", None, "kv", None), init="zeros")
            c["cross_v"] = ParamDef((batch, enc_len, Hkv, hd),
                                    ("batch", None, "kv", None), init="zeros")
    elif kind == "mamba":
        c.update(ssm_mod.mamba_cache_defs(cfg, batch))
    elif kind == "mlstm":
        c.update(xlstm_mod.mlstm_cache_defs(cfg, batch))
    elif kind == "slstm":
        c.update(xlstm_mod.slstm_cache_defs(cfg, batch))
    return c or None


def cache_defs(cfg: ArchConfig, batch: int, max_len: int) -> Dict[str, Any]:
    prologue, period, repeats = _layer_plan(cfg)
    cross = cfg.is_encdec and cfg.cross_attn
    return {
        "prologue": [_block_cache_defs(cfg, k, batch, max_len, cross)
                     for (_, k, m) in prologue],
        "body": [_stack_defs(_block_cache_defs(cfg, k, batch, max_len, cross),
                             repeats)
                 for (k, m) in period] if repeats else [],
    }


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int):
    return abstract_tree(cache_defs(cfg, batch, max_len), jnp.dtype(cfg.dtype))


def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Zeroed decode cache (the chunked-prefill starting state): same pytree
    structure ``prefill`` returns, so a sequence admitted chunk-by-chunk
    carries a cache indistinguishable from a whole-prompt admission."""
    def z(d: ParamDef):
        dt = jnp.dtype(d.dtype) if d.dtype else jnp.dtype(cfg.dtype)
        return jnp.zeros(d.shape, dt)
    return jax.tree.map(z, cache_defs(cfg, batch, max_len), is_leaf=is_def)


def load_prefix_rows(cfg: ArchConfig, cache, kv_rows, n_tokens: int):
    """Seed a chunked-prefill cache with shared-prefix KV rows.

    ``kv_rows`` holds one ``(k_rows, v_rows)`` pair per *attention* layer
    (store layer order), each of shape ``(n_tokens, Hkv, hd)`` — for MLA
    a single latent plane ``(n_tokens, 1, kv_lora_rank + rope_dim)``.
    The rows are written into positions ``[0, n_tokens)`` of the batch-1
    admission cache, so chunked prefill can resume at ``q_offset ==
    n_tokens`` and attend over the warm span without recomputing it.
    """
    prologue, period_plan, _ = _layer_plan(cfg)
    pro_n = len(prologue)
    period = cfg.period()
    kinds = cfg.layer_kinds()
    ai = 0
    for layer, kind in enumerate(kinds):
        if not kind.startswith("attn"):
            continue
        k_rows, v_rows = kv_rows[ai]
        ai += 1
        if layer < pro_n:
            leafset = cache["prologue"][layer]

            def put(name, rows, ls=leafset):
                leaf = ls[name]
                ls[name] = leaf.at[0, :n_tokens].set(
                    jnp.asarray(rows, leaf.dtype))
        else:
            pi = (layer - pro_n) % period
            bi = (layer - pro_n) // period
            leafset = cache["body"][pi]

            def put(name, rows, ls=leafset, b=bi):
                leaf = ls[name]
                ls[name] = leaf.at[b, 0, :n_tokens].set(
                    jnp.asarray(rows, leaf.dtype))
        if cfg.mla is not None:
            lat = np.asarray(k_rows)[:, 0, :]
            r = cfg.mla.kv_lora_rank
            put("ckv", lat[:, :r])
            put("krope", lat[:, r:])
        else:
            put("k", np.asarray(k_rows))
            put("v", np.asarray(v_rows))
    assert ai == len(kv_rows), (ai, len(kv_rows))
    return cache


def encoder_len(cfg: ArchConfig, dec_len: int) -> int:
    """Static encoder length for enc-dec decode shapes (DESIGN.md §4)."""
    return min(4096, max(256, dec_len // 8))


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _apply_mlp(blk, cfg: ArchConfig, mlp_kind: str, x, aux, *,
               no_drop: bool = False):
    """``no_drop``: inference MoE dispatch — no capacity drops, so token
    outputs are independent of the surrounding batch shape (chunked /
    bucketed prefill stays token-identical to whole-prompt; see
    :func:`mlp.moe_apply`)."""
    if mlp_kind == "none" or "mlp" not in blk:
        return x, aux
    h = rms_norm(x, blk["ln2"], cfg.norm_eps)
    if mlp_kind == "moe":
        y, a = mlp_mod.moe_apply(blk["mlp"], cfg, h, no_drop=no_drop)
        aux = {k: aux.get(k, 0.0) + v for k, v in a.items()} if aux is not None else None
    else:
        y = mlp_mod.dense_apply(blk["mlp"], cfg, h)
    return x + y, aux


def _block_train(blk, cfg: ArchConfig, kind: str, mlp_kind: str, x, pos,
                 enc_out=None, aux=None, causal: bool = True):
    h = rms_norm(x, blk["ln1"], cfg.norm_eps)
    if kind.startswith("attn"):
        if cfg.mla is not None:
            y = attn.mla_train(blk["core"], cfg, kind, h, pos)
        else:
            y = attn.gqa_train(blk["core"], cfg, kind, h, pos, causal=causal)
    elif kind == "mamba":
        y = ssm_mod.mamba_train(blk["core"], cfg, h)
    elif kind == "mlstm":
        y = xlstm_mod.mlstm_train(blk["core"], cfg, h)
    elif kind == "slstm":
        y = xlstm_mod.slstm_train(blk["core"], cfg, h)
    else:
        raise ValueError(kind)
    x = x + y
    if enc_out is not None and "cross" in blk:
        hx = rms_norm(x, blk["ln_x"], cfg.norm_eps)
        ckv = attn.cross_kv(blk["cross"], cfg, enc_out)
        y = attn.gqa_train(blk["cross"], cfg, "attn", hx, pos, cross_kv=ckv)
        x = x + y
    return _apply_mlp(blk, cfg, mlp_kind, x, aux)


def _block_decode(blk, cfg: ArchConfig, kind: str, mlp_kind: str, x, cache,
                  length, *, layer_idx: int, ctx: DecodeCtx, aux=None):
    h = rms_norm(x, blk["ln1"], cfg.norm_eps)
    new_cache = dict(cache) if cache else {}
    if kind.startswith("attn"):
        sub = {k: v for k, v in cache.items() if not k.startswith("cross_")}
        if cfg.mla is not None:
            y, sub = attn.mla_decode(blk["core"], cfg, kind, h, sub, length,
                                     layer_idx=layer_idx, ctx=ctx)
        else:
            y, sub = attn.gqa_decode(blk["core"], cfg, kind, h, sub, length,
                                     layer_idx=layer_idx, ctx=ctx)
        new_cache.update(sub)
    elif kind == "mamba":
        y, sub = ssm_mod.mamba_decode(blk["core"], cfg, h, cache)
        new_cache.update(sub)
    elif kind == "mlstm":
        y, sub = xlstm_mod.mlstm_decode(blk["core"], cfg, h, cache)
        new_cache.update(sub)
    elif kind == "slstm":
        y, sub = xlstm_mod.slstm_decode(blk["core"], cfg, h, cache)
        new_cache.update(sub)
    else:
        raise ValueError(kind)
    x = x + y
    if "cross" in blk and "cross_k" in cache:
        hx = rms_norm(x, blk["ln_x"], cfg.norm_eps)
        y, _ = attn.gqa_decode(blk["cross"], cfg, "attn", hx, {}, length,
                               layer_idx=layer_idx, ctx=LOCAL_CTX,
                               cross_kv_cache=(cache["cross_k"], cache["cross_v"]))
        x = x + y
    x, aux = _apply_mlp(blk, cfg, mlp_kind, x, aux, no_drop=True)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Encoder (enc-dec archs)
# ---------------------------------------------------------------------------


def _encode(params, cfg: ArchConfig, embeds: jax.Array) -> jax.Array:
    enc = params["encoder"]
    B, S, d = embeds.shape
    pos = positions_for(cfg, B, S)
    x = embeds.astype(jnp.dtype(cfg.dtype))

    def step(x, blk):
        x, _ = _block_train(blk, cfg, "attn", "dense", x, pos, causal=False)
        return x, None

    if cfg.runtime.remat == "block":
        step = jax.checkpoint(step,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(step, x, enc["body"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Train forward
# ---------------------------------------------------------------------------


def _embed_in(params, cfg: ArchConfig, batch: Dict[str, jax.Array]):
    if "embeds" in batch and not cfg.is_encdec:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        B, S = x.shape[0], x.shape[1]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, ("batch", None, None))
    return x, B, S


def _logits(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["lm_head"]
    # keep the vocab dim model-sharded through softmax/loss
    logits = constrain(logits, ("batch", None, "vocab"))
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def forward_train(params, cfg: ArchConfig, batch: Dict[str, jax.Array]
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    prologue, period, repeats = _layer_plan(cfg)
    x, B, S = _embed_in(params, cfg, batch)
    pos = batch.get("positions")
    if pos is None:
        pos = positions_for(cfg, B, S)
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(params, cfg, batch["embeds"])
    aux: Dict[str, jax.Array] = {}

    pro_fn = _block_train
    if cfg.runtime.remat == "block":
        pro_fn = jax.checkpoint(
            _block_train, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(1, 2, 3))
    for blk, (idx, kind, mlpk) in zip(params["prologue"], prologue):
        x, aux = pro_fn(blk, cfg, kind, mlpk, x, pos, enc_out, aux)

    if repeats:
        def step(carry, layer_params):
            x, aux = carry
            layer_params = _constrain_block_params(cfg, period, layer_params)
            for pi, (kind, mlpk) in enumerate(period):
                x, aux = _block_train(layer_params[pi], cfg, kind, mlpk, x,
                                      pos, enc_out, aux)
            return (x, aux), None

        aux0 = dict(aux)
        for k in ("moe_lb_loss", "moe_z_loss", "moe_drop_frac"):
            if any(m == "moe" for _, m in period) and k not in aux0:
                aux0[k] = jnp.array(0.0, jnp.float32)
        body_fn = step
        if cfg.runtime.remat == "block":
            body_fn = jax.checkpoint(
                step, policy=jax.checkpoint_policies.nothing_saveable)
        G = cfg.runtime.remat_groups
        if (cfg.runtime.remat == "block" and G and G > 1
                and repeats % G == 0):
            # sqrt-N recursive remat: only G outer carries + L/G inner
            # carries are ever live (fits 340B-class loop-carry memory)
            k_in = repeats // G
            grouped = jax.tree.map(
                lambda a: a.reshape(G, k_in, *a.shape[1:]),
                tuple(params["body"]))

            def outer(carry, group_params):
                c, _ = jax.lax.scan(body_fn, carry, group_params)
                return c, None

            outer_fn = jax.checkpoint(
                outer, policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux), _ = jax.lax.scan(outer_fn, (x, aux0), grouped)
        else:
            (x, aux), _ = jax.lax.scan(body_fn, (x, aux0),
                                       tuple(params["body"]))

    logits = _logits(params, cfg, x)
    loss, metrics = cross_entropy(logits, batch["targets"])
    if cfg.moe is not None and "moe_lb_loss" in aux:
        loss = loss + mlp_mod.moe_loss(aux, cfg)
        metrics.update({k: v for k, v in aux.items()})
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Prefill: run the full prompt, build the decode cache
# ---------------------------------------------------------------------------


def prefill(params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            max_len: int, ctx: DecodeCtx = LOCAL_CTX
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Returns (last-position logits (B, V), cache).

    ``batch["length"]`` (optional scalar, may be traced) marks the prompt's
    true length when the token row is right-padded to a BUCKET size: the
    returned logits come from position ``length - 1``, K/V cache rows past
    ``length`` are zeroed, and recurrent layer states stop absorbing tokens
    at ``length`` — so one compiled program per bucket serves every prompt
    length in the bucket, token-identical to exact-length prefill (padding
    keys are causally invisible to every real query row)."""
    prologue, period, repeats = _layer_plan(cfg)
    x, B, S = _embed_in(params, cfg, batch)
    pos = batch.get("positions")
    if pos is None:
        pos = positions_for(cfg, B, S)
    length = batch.get("length", S)
    mask_len = batch.get("length")       # None => no bucket padding
    enc_out = _encode(params, cfg, batch["embeds"]) if cfg.is_encdec else None
    cross = cfg.is_encdec and cfg.cross_attn

    def block_prefill(blk, kind, mlpk, x, layer_idx):
        h = rms_norm(x, blk["ln1"], cfg.norm_eps)
        cache = {}
        if kind.startswith("attn"):
            if cfg.mla is not None:
                y = attn.mla_train(blk["core"], cfg, kind, h, pos)
                cache = attn.mla_prefill_cache(blk["core"], cfg, h, pos,
                                               max_len, length)
            else:
                q, k, v = attn._qkv(blk["core"], cfg, h, pos)
                window = cfg.window if kind == "attn_local" else None
                o = attn.blocked_attention(
                    q * (1.0 / math.sqrt(cfg.hd)), k, v, causal=True,
                    window=window, attn_softcap=cfg.attn_softcap,
                    block_q=cfg.runtime.attn_block_q,
                    block_kv=cfg.runtime.attn_block_kv)
                y = o.reshape(B, S, -1) @ blk["core"]["wo"]
                cache = attn.gqa_prefill_cache(cfg, k, v, max_len, length)
            x = x + y
            if cross:
                hx = rms_norm(x, blk["ln_x"], cfg.norm_eps)
                ckv = attn.cross_kv(blk["cross"], cfg, enc_out)
                x = x + attn.gqa_train(blk["cross"], cfg, "attn", hx, pos,
                                       cross_kv=ckv)
                cache["cross_k"], cache["cross_v"] = ckv
        elif kind == "mamba":
            y, st = _mamba_prefill(blk["core"], cfg, h, length=mask_len)
            x, cache = x + y, st
        elif kind == "mlstm":
            y, st = _scan_prefill(xlstm_mod.mlstm_train,
                                  xlstm_mod.mlstm_decode, blk["core"], cfg, h,
                                  length=mask_len)
            x, cache = x + y, st
        elif kind == "slstm":
            y, st = _scan_prefill(xlstm_mod.slstm_train,
                                  xlstm_mod.slstm_decode, blk["core"], cfg, h,
                                  length=mask_len)
            x, cache = x + y, st
        x, _ = _apply_mlp(blk, cfg, mlpk, x, None, no_drop=True)
        return x, cache

    caches_pro = []
    for blk, (idx, kind, mlpk) in zip(params["prologue"], prologue):
        x, c = block_prefill(blk, kind, mlpk, x, idx)
        caches_pro.append(c or None)

    caches_body = []
    if repeats:
        def step(x, layer_params):
            cs = []
            for pi, (kind, mlpk) in enumerate(period):
                # body layers use the standard (non-early) LeoAM budget
                x, c = block_prefill(layer_params[pi], kind, mlpk, x, 10**6)
                cs.append(c)
            return x, tuple(cs)

        x, caches = jax.lax.scan(step, x, tuple(params["body"]))
        caches_body = list(caches)

    if "length" in batch:
        # bucketed prompt: the true last row sits at length - 1, not S - 1
        idx = jnp.clip(jnp.asarray(length, jnp.int32) - 1, 0, S - 1)
        x_last = jax.lax.dynamic_slice_in_dim(x, idx, 1, axis=1)
    else:
        x_last = x[:, -1:]
    logits_last = _logits(params, cfg, x_last)[:, 0]
    return logits_last, {"prologue": caches_pro, "body": caches_body}


def _masked_state_scan(step_fn, cache, x, length):
    """Scan a per-token state update over ``x`` (``step_fn(cache, xt) ->
    cache'``); with ``length`` the state stops updating at that position
    (bucket-padding rows are identity), so the final recurrent state matches
    exact-length prefill."""
    if length is None:
        def step(c, xt):
            return step_fn(c, xt), None
        cache, _ = jax.lax.scan(step, cache, jnp.moveaxis(x, 1, 0))
        return cache
    idxs = jnp.arange(x.shape[1], dtype=jnp.int32)

    def step(c, inp):
        xt, i = inp
        c2 = step_fn(c, xt)
        keep = i < jnp.asarray(length, jnp.int32)
        return jax.tree.map(lambda n, o: jnp.where(keep, n, o), c2, c), None

    cache, _ = jax.lax.scan(step, cache, (jnp.moveaxis(x, 1, 0), idxs))
    return cache


def _mamba_prefill(p, cfg, x, length=None):
    """Run mamba over the prompt AND produce the decode state."""
    y = ssm_mod.mamba_train(p, cfg, x)
    # recompute final state by stepping the last d_conv tokens (cheap)
    B, S, d = x.shape
    d_in, ds, dc, _ = ssm_mod._dims(cfg)
    cache = {"conv": jnp.zeros((B, dc - 1, d_in), x.dtype),
             "state": jnp.zeros((B, d_in, ds), jnp.float32)}
    step = lambda c, xt: ssm_mod.mamba_decode(p, cfg, xt[:, None], c)[1]
    return y, _masked_state_scan(step, cache, x, length)


def _scan_prefill(train_fn, decode_fn, p, cfg, x, length=None):
    y = train_fn(p, cfg, x)
    B, S, d = x.shape
    if train_fn is xlstm_mod.mlstm_train:
        defs = xlstm_mod.mlstm_cache_defs(cfg, B)
    else:
        defs = xlstm_mod.slstm_cache_defs(cfg, B)
    cache = {k: jnp.zeros(v.shape, jnp.dtype(v.dtype or cfg.dtype))
             for k, v in defs.items()}
    step = lambda c, xt: decode_fn(p, cfg, xt[:, None], c)[1]
    return y, _masked_state_scan(step, cache, x, length)


# ---------------------------------------------------------------------------
# Chunked prefill: advance admission one fixed-size token chunk at a time
# ---------------------------------------------------------------------------


def prefill_chunk(params, cfg: ArchConfig, batch: Dict[str, jax.Array],
                  cache: Dict[str, Any], max_len: int
                  ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One chunk of a vLLM-style chunked prefill.

    batch: ``{"tokens": (B, C), "start": scalar, "length": scalar}`` — the
    chunk occupies global positions ``[start, start + C)``; rows at
    positions >= ``length`` are padding (last chunk only).  ``cache`` is the
    decode cache (:func:`init_decode_cache` to start); each call writes the
    chunk's K/V into it at ``start`` (padding rows zeroed) and attends the
    chunk's queries over the whole cache with an offset causal mask —
    not-yet-written rows sit at future positions, so the causal mask alone
    excludes them and the outputs are token-identical to whole-prompt
    prefill (masked keys contribute exact zeros to the f32 softmax
    accumulators).  Shapes are independent of ``start``/``length``: ONE
    compiled program serves every chunk of every prompt.

    Returns (logits at position ``min(length, start + C) - 1``, cache');
    the final chunk's logits row is the prompt's first sampled token.
    Recurrent (mamba/xlstm) layers advance their decode state per token
    under the same validity mask.  Attention supports GQA and absorbed
    MLA: MLA chunks write the latent cache (``ckv``/``krope``) with the
    same validity zeroing as :func:`~repro.models.attention.mla_prefill_cache`
    and attend non-absorbed (per-head K/V re-expanded from the cached
    latents, matching :func:`~repro.models.attention.mla_train` numerics),
    so a chunked MLA admission is token-identical to whole-prompt prefill.
    """
    assert not cfg.is_encdec, "chunked prefill drives decoder-only models"
    prologue, period, repeats = _layer_plan(cfg)
    tokens = batch["tokens"]
    B, C = tokens.shape
    start = jnp.asarray(batch["start"], jnp.int32)
    length = jnp.asarray(batch["length"], jnp.int32)
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = positions_for(cfg, B, C, offset=start)
    valid = (jnp.arange(C, dtype=jnp.int32) + start) < length       # (C,)

    def attn_chunk(blk, kind, mlpk, x, c):
        h = rms_norm(x, blk["ln1"], cfg.norm_eps)
        q, k, v = attn._qkv(blk["core"], cfg, h, pos)
        kz = jnp.where(valid[None, :, None, None], k, 0).astype(c["k"].dtype)
        vz = jnp.where(valid[None, :, None, None], v, 0).astype(c["v"].dtype)
        c = dict(c)
        c["k"] = jax.lax.dynamic_update_slice_in_dim(c["k"], kz, start,
                                                     axis=1)
        c["v"] = jax.lax.dynamic_update_slice_in_dim(c["v"], vz, start,
                                                     axis=1)
        window = cfg.window if kind == "attn_local" else None
        o = attn.blocked_attention(
            q * (1.0 / math.sqrt(cfg.hd)), c["k"], c["v"], causal=True,
            window=window, attn_softcap=cfg.attn_softcap,
            block_q=cfg.runtime.attn_block_q,
            block_kv=cfg.runtime.attn_block_kv, q_offset=start)
        y = o.reshape(B, C, -1) @ blk["core"]["wo"]
        x, _ = _apply_mlp(blk, cfg, mlpk, x + y, None, no_drop=True)
        return x, c

    def mla_attn_chunk(blk, kind, mlpk, x, c):
        """One MLA chunk: write the chunk's latent rows into the decode
        cache, then attend the chunk's queries over the whole cache with
        the offset-causal mask.  The attention is NON-absorbed — per-head
        K/V are re-expanded from the cached latents via wk_b/wv_b, the
        exact contraction order :func:`attn.mla_train` uses in whole-prompt
        prefill — so the chunked residual stream is bitwise-compatible
        with whole-prompt admission (unwritten cache rows are exact zeros
        and causally invisible)."""
        m = cfg.mla
        p = blk["core"]
        h = rms_norm(x, blk["ln1"], cfg.norm_eps)
        q_nope, q_rope = attn._mla_q(p, cfg, h, pos)
        kv_a = h @ p["wkv_a"]
        ckv = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_norm"],
                       cfg.norm_eps)
        krope = rotate(cfg, kv_a[..., None, m.kv_lora_rank:], pos)[:, :, 0]
        ckv = jnp.where(valid[None, :, None], ckv, 0)
        krope = jnp.where(valid[None, :, None], krope, 0)
        c = dict(c)
        c["ckv"] = jax.lax.dynamic_update_slice_in_dim(
            c["ckv"], ckv.astype(c["ckv"].dtype), start, axis=1)
        c["krope"] = jax.lax.dynamic_update_slice_in_dim(
            c["krope"], krope.astype(c["krope"].dtype), start, axis=1)
        S = c["ckv"].shape[1]
        k_nope = jnp.einsum("bsr,hrd->bshd", c["ckv"], p["wk_b"])
        val = jnp.einsum("bsr,hrd->bshd", c["ckv"], p["wv_b"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(c["krope"][:, :, None],
                                      (B, S, cfg.n_heads,
                                       m.qk_rope_head_dim))], -1)
        q = jnp.concatenate([q_nope, q_rope], -1)
        scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        o = attn.blocked_attention(
            q * scale, k, val, causal=True,
            block_q=cfg.runtime.attn_block_q,
            block_kv=cfg.runtime.attn_block_kv, q_offset=start)
        y = o.reshape(B, C, cfg.n_heads * m.v_head_dim) @ p["wo"]
        x, _ = _apply_mlp(blk, cfg, mlpk, x + y, None, no_drop=True)
        return x, c

    def other_chunk(blk, kind, mlpk, x, c):
        dec = {"mamba": ssm_mod.mamba_decode,
               "mlstm": xlstm_mod.mlstm_decode,
               "slstm": xlstm_mod.slstm_decode}[kind]
        h = rms_norm(x, blk["ln1"], cfg.norm_eps)

        def step(st, inp):
            ht, keep = inp
            y, st2 = dec(blk["core"], cfg, ht[:, None], st)
            st2 = jax.tree.map(lambda n, o: jnp.where(keep, n, o), st2, st)
            return st2, y[:, 0]

        c2, ys = jax.lax.scan(step, c, (jnp.moveaxis(h, 1, 0), valid))
        x, _ = _apply_mlp(blk, cfg, mlpk, x + jnp.moveaxis(ys, 0, 1), None,
                          no_drop=True)
        return x, c2

    def block_chunk(blk, kind, mlpk, x, c):
        if kind.startswith("attn"):
            if cfg.mla is not None:
                return mla_attn_chunk(blk, kind, mlpk, x, c)
            return attn_chunk(blk, kind, mlpk, x, c)
        return other_chunk(blk, kind, mlpk, x, c)

    new_pro = []
    for blk, (idx, kind, mlpk), c in zip(params["prologue"], prologue,
                                         cache["prologue"]):
        x, c2 = block_chunk(blk, kind, mlpk, x, c or {})
        new_pro.append(c2 if c is not None else None)

    new_body = []
    if repeats:
        body_cache = tuple(cache["body"])

        def bstep(carry, layer_params):
            x, caches, li = carry
            new_cs = []
            for pi, (kind, mlpk) in enumerate(period):
                layer_cache = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, li, 0, keepdims=False), caches[pi])
                x, c2 = block_chunk(layer_params[pi], kind, mlpk, x,
                                    layer_cache)
                new_cs.append(c2)
            caches = tuple(
                jax.tree.map(
                    lambda a, u: jax.lax.dynamic_update_index_in_dim(
                        a, u.astype(a.dtype), li, 0), caches[pi], new_cs[pi])
                for pi in range(len(period)))
            return (x, caches, li + 1), None

        (x, new_caches, _), _ = jax.lax.scan(
            bstep, (x, body_cache, jnp.int32(0)), tuple(params["body"]))
        new_body = list(new_caches)

    # last valid row of THIS chunk (the final chunk's row is the prompt's
    # first-token logits; earlier chunks' logits are discarded)
    idx = jnp.clip(jnp.minimum(length, start + C) - 1 - start, 0, C - 1)
    logits = _logits(params, cfg,
                     jax.lax.dynamic_slice_in_dim(x, idx, 1, axis=1))[:, 0]
    return logits, {"prologue": new_pro, "body": new_body}


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def decode_step(params, cfg: ArchConfig, cache: Dict[str, Any],
                batch: Dict[str, jax.Array], length: jax.Array,
                ctx: DecodeCtx = LOCAL_CTX
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token for every sequence.  batch: {"token": (B,)} or
    {"embeds": (B, 1, d)}.  length: current cache fill (scalar int32)."""
    prologue, period, repeats = _layer_plan(cfg)
    if "token" in batch:
        x = jnp.take(params["embed"], batch["token"][:, None], axis=0)
    else:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    aux: Dict[str, jax.Array] = {}

    new_pro = []
    for blk, (idx, kind, mlpk), c in zip(params["prologue"], prologue,
                                         cache["prologue"]):
        x, c2, aux = _block_decode(blk, cfg, kind, mlpk, x, c or {}, length,
                                   layer_idx=idx, ctx=ctx, aux=aux)
        new_pro.append(c2 if c is not None else None)

    new_body = []
    if repeats:
        # The stacked cache rides in the scan CARRY (sliced/updated per
        # iteration) rather than as xs/ys — the ys path double-buffers the
        # whole multi-GiB cache, the carry path updates it in place.
        body_cache = tuple(cache["body"])

        def step(carry, layer_params):
            x, caches, li = carry
            new_cs = []
            for pi, (kind, mlpk) in enumerate(period):
                layer_cache = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, li, 0, keepdims=False), caches[pi])
                xx, c2, _ = _block_decode(layer_params[pi], cfg, kind, mlpk,
                                          x, layer_cache, length,
                                          layer_idx=10**6, ctx=ctx)
                x = xx
                new_cs.append(c2)
            caches = tuple(
                jax.tree.map(
                    lambda a, u: jax.lax.dynamic_update_index_in_dim(
                        a, u.astype(a.dtype), li, 0), caches[pi], new_cs[pi])
                for pi in range(len(period)))
            return (x, caches, li + 1), None

        (x, new_caches, _), _ = jax.lax.scan(
            step, (x, body_cache, jnp.int32(0)), tuple(params["body"]))
        new_body = list(new_caches)

    logits = _logits(params, cfg, x)[:, 0]
    return logits, {"prologue": new_pro, "body": new_body}
