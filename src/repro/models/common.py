"""Shared model building blocks (norms, rotary embeddings, activations)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (scale.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """NeoX-style rotation.  x: (..., S, H, hd), pos: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = pos.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(hd: int) -> Tuple[int, int, int]:
    """Qwen2-VL M-RoPE: (temporal, height, width) sections of hd/2."""
    half = hd // 2
    t = half // 4
    h = (half - t) // 2
    return (t, h, half - t - h)


def apply_mrope(x: jax.Array, pos3: jax.Array, theta: float) -> jax.Array:
    """M-RoPE.  x: (B, S, H, hd); pos3: (3, B, S) (t/h/w position ids)."""
    hd = x.shape[-1]
    secs = mrope_sections(hd)
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    # per-frequency choice of which positional stream rotates it
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(secs), total_repeat_length=hd // 2)
    pos = jnp.take(pos3, sec_id, axis=0)               # (hd/2, B, S)
    angles = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def positions_for(cfg, batch: int, seq: int, offset=0) -> jax.Array:
    """Default position ids; M-RoPE text-mode uses identical t/h/w streams."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope == "mrope":
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


def rotate(cfg, x: jax.Array, pos: jax.Array) -> jax.Array:
    if cfg.rope == "none":
        return x
    if cfg.rope == "mrope":
        return apply_mrope(x, pos, cfg.rope_theta)
    return apply_rope(x, pos, cfg.rope_theta)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  valid: Optional[jax.Array] = None,
                  z_weight: float = 0.0) -> Tuple[jax.Array, dict]:
    """Mean token cross-entropy in f32.  targets==-1 are ignored.

    The gold-logit extraction is written as a masked reduction (iota-compare
    + sum) rather than ``take_along_axis`` so the vocab dim can stay
    model-sharded end to end — a gather over a sharded dim would force XLA
    to all-gather the full-vocab logits (observed: 200+ GiB/device on the
    train_4k cells before this formulation).
    """
    logits = logits.astype(jnp.float32)
    mask = (targets >= 0) if valid is None else valid & (targets >= 0)
    safe_t = jnp.maximum(targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.iota(jnp.int32, logits.shape[-1])
    onehot = (vocab_iota == safe_t[..., None])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1)
    loss = nll.sum() / denom
    metrics = {"nll": loss, "tokens": denom}
    if z_weight:
        zl = z_weight * jnp.square(lse)
        loss = loss + (zl * mask).sum() / denom
    acc = (jnp.argmax(logits, -1) == targets) & mask
    metrics["accuracy"] = acc.sum() / denom
    return loss, metrics
