"""xLSTM blocks: mLSTM (matrix-memory, chunkwise-parallelizable) and sLSTM
(scalar-memory, strictly sequential) [arXiv:2405.04517].

Baseline train path runs the exact stabilized recurrences with ``lax.scan``
over time; the chunkwise-parallel mLSTM is a recorded §Perf hillclimb
candidate.  Decode carries fixed-size state — these archs have no KV cache,
so the paper's technique is N/A (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.params import ParamDef


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg: ArchConfig) -> Tuple[int, int]:
    d_in = 2 * cfg.d_model
    return d_in, d_in // cfg.n_heads


def mlstm_params(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    d_in, dh = _mlstm_dims(cfg)
    H = cfg.n_heads
    return {
        "in_proj": ParamDef((d, 2 * d_in), ("embed", "ffn")),
        "wq": ParamDef((d_in, d_in), ("ffn", None)),
        "wk": ParamDef((d_in, d_in), ("ffn", None)),
        "wv": ParamDef((d_in, d_in), ("ffn", None)),
        "w_if": ParamDef((d_in, 2 * H), ("ffn", None)),   # input+forget gates
        "b_if": ParamDef((2 * H,), (None,), init="zeros"),
        "out_proj": ParamDef((d_in, d), ("ffn", "embed")),
    }


def mlstm_cache_defs(cfg: ArchConfig, batch: int) -> Dict[str, ParamDef]:
    d_in, dh = _mlstm_dims(cfg)
    H = cfg.n_heads
    return {
        "C": ParamDef((batch, H, dh, dh), ("batch", "kv", None, None),
                      init="zeros", dtype="float32"),
        "n": ParamDef((batch, H, dh), ("batch", "kv", None),
                      init="zeros", dtype="float32"),
        "m": ParamDef((batch, H), ("batch", "kv"), init="zeros",
                      dtype="float32"),
    }


def _mlstm_qkvg(p, cfg: ArchConfig, x: jax.Array):
    """x: (..., d) -> q,k,v (..., H, dh), gates (..., H), z (..., d_in)."""
    d_in, dh = _mlstm_dims(cfg)
    H = cfg.n_heads
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    q = (xi @ p["wq"]).reshape(*xi.shape[:-1], H, dh)
    k = (xi @ p["wk"]).reshape(*xi.shape[:-1], H, dh) / jnp.sqrt(dh)
    v = (xi @ p["wv"]).reshape(*xi.shape[:-1], H, dh)
    gates = (xi @ p["w_if"] + p["b_if"]).astype(jnp.float32)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)                 # (..., H)
    return q, k, v, i_raw, f_raw, z


def _mlstm_step(carry, qkvif):
    """Stabilized mLSTM recurrence (paper eq. 19-27)."""
    C, n, m = carry
    q, k, v, i_raw, f_raw = qkvif
    logf = jax.nn.log_sigmoid(f_raw)                            # (B,H)
    m_new = jnp.maximum(logf + m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(logf + m - m_new)
    k32, v32, q32 = (a.astype(jnp.float32) for a in (k, v, q))
    C = f_g[..., None, None] * C + i_g[..., None, None] * (
        k32[..., :, None] * v32[..., None, :])                  # (B,H,dk,dv)
    n = f_g[..., None] * n + i_g[..., None] * k32
    num = jnp.einsum("bhkv,bhk->bhv", C, q32)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q32)), 1.0)
    h = num / den[..., None]
    return (C, n, m_new), h


def mlstm_train(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    d_in, dh = _mlstm_dims(cfg)
    H = cfg.n_heads
    q, k, v, i_raw, f_raw, z = _mlstm_qkvg(p, cfg, x)
    init = (jnp.zeros((B, H, dh, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.zeros((B, H), jnp.float32))
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, i_raw, f_raw))
    _, hs = jax.lax.scan(_mlstm_step, init, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d_in).astype(x.dtype)
    return (h * jax.nn.silu(z)) @ p["out_proj"]


def mlstm_decode(p, cfg: ArchConfig, x: jax.Array,
                 cache: Dict[str, jax.Array]):
    B, _, d = x.shape
    d_in, dh = _mlstm_dims(cfg)
    q, k, v, i_raw, f_raw, z = _mlstm_qkvg(p, cfg, x[:, 0])
    carry = (cache["C"], cache["n"], cache["m"])
    carry, h = _mlstm_step(carry, (q, k, v, i_raw, f_raw))
    h = h.reshape(B, d_in).astype(x.dtype)
    out = ((h * jax.nn.silu(z)) @ p["out_proj"])[:, None]
    return out, {"C": carry[0], "n": carry[1], "m": carry[2]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_params(cfg: ArchConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ffp = int(d * 4 / 3)
    return {
        "w_in": ParamDef((d, 4 * d), ("embed", "ffn")),          # z,i,f,o
        "r_in": ParamDef((H, dh, 4 * dh), ("kv", None, None)),   # block-diag R
        "b_in": ParamDef((4 * d,), (None,), init="zeros"),
        "ff_gate": ParamDef((d, ffp), ("embed", "ffn")),
        "ff_up": ParamDef((d, ffp), ("embed", "ffn")),
        "ff_down": ParamDef((ffp, d), ("ffn", "embed")),
    }


def slstm_cache_defs(cfg: ArchConfig, batch: int) -> Dict[str, ParamDef]:
    d = cfg.d_model
    return {nm: ParamDef((batch, d), ("batch", None), init="zeros",
                         dtype="float32") for nm in ("h", "c", "n", "m")}


def _slstm_step(p, cfg: ArchConfig, carry, x_t):
    """x_t: (B, d).  Stabilized sLSTM (paper eq. 8-18)."""
    h_prev, c_prev, n_prev, m_prev = carry
    B, d = x_t.shape
    H = cfg.n_heads
    dh = d // H
    rec = jnp.einsum("bhk,hkj->bhj", h_prev.reshape(B, H, dh).astype(x_t.dtype),
                     p["r_in"])                                  # (B,H,4*dh)
    # regroup per-head [z,i,f,o] blocks into gate-major (B, 4d) to match w_in
    rec = rec.reshape(B, H, 4, dh).transpose(0, 2, 1, 3).reshape(B, 4 * d)
    pre = (x_t @ p["w_in"] + p["b_in"]).astype(jnp.float32)
    pre = pre + rec.astype(jnp.float32)
    z_r, i_r, f_r, o_r = jnp.split(pre, 4, axis=-1)             # (B, d)
    logf = jax.nn.log_sigmoid(f_r)
    m_new = jnp.maximum(logf + m_prev, i_r)
    i_g = jnp.exp(i_r - m_new)
    f_g = jnp.exp(logf + m_prev - m_new)
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    c = f_g * c_prev + i_g * z
    n = f_g * n_prev + i_g
    h = o * c / jnp.maximum(n, 1.0)
    return (h, c, n, m_new), h


def slstm_train(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    init = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(4))
    step = lambda c, xt: _slstm_step(p, cfg, c, xt)
    _, hs = jax.lax.scan(step, init, jnp.moveaxis(x, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    g = jax.nn.gelu((x + h) @ p["ff_gate"], approximate=True)
    return (g * ((x + h) @ p["ff_up"])) @ p["ff_down"] + h


def slstm_decode(p, cfg: ArchConfig, x: jax.Array,
                 cache: Dict[str, jax.Array]):
    carry = (cache["h"], cache["c"], cache["n"], cache["m"])
    carry, h = _slstm_step(p, cfg, carry, x[:, 0])
    h = h[:, None].astype(x.dtype)
    g = jax.nn.gelu((x + h) @ p["ff_gate"], approximate=True)
    out = (g * ((x + h) @ p["ff_up"])) @ p["ff_down"] + h
    return out, dict(zip(("h", "c", "n", "m"), carry))
