"""Pure-jnp oracle for the chunk_bounds kernel."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def chunk_bounds_ref(q: jax.Array, kmax: jax.Array, kmin: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """q: (B, Hkv, G, hd); kmax/kmin: (B, Hkv, nc, hd) (f32).

    Returns (ub, lb): (B, Hkv, nc) — group-summed box bounds:
        ub = Σ_g Σ_d max(q_d·kmax_d, q_d·kmin_d)
           = Σ_g (q⁺·kmax + q⁻·kmin)
    """
    q = q.astype(jnp.float32)
    kmax = kmax.astype(jnp.float32)
    kmin = kmin.astype(jnp.float32)
    qp = jnp.maximum(q, 0.0)
    qn = jnp.minimum(q, 0.0)
    ub = (jnp.einsum("bkgd,bkcd->bkgc", qp, kmax)
          + jnp.einsum("bkgd,bkcd->bkgc", qn, kmin)).sum(axis=2)
    lb = (jnp.einsum("bkgd,bkcd->bkgc", qp, kmin)
          + jnp.einsum("bkgd,bkcd->bkgc", qn, kmax)).sum(axis=2)
    return ub, lb
