"""Pallas TPU kernel: chunk importance bounds from KV abstracts (LKA).

Grid: (B, Hkv, nc/TC).  Per step the kernel holds one query group
(G, hd) and one abstract tile (TC, hd) in VMEM and issues two MXU matmuls
per bound (the q⁺/q⁻ decomposition turns the per-coordinate corner rule
into dense dots; see repro.core.bounds).  TC is a multiple of the 128-lane
MXU; hd (128/192/256 across the assigned archs) is contiguous in lanes.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bounds_kernel(q_ref, kmax_ref, kmin_ref, ub_ref, lb_ref):
    q = q_ref[0, 0].astype(jnp.float32)                 # (G, hd)
    km = kmax_ref[0, 0].astype(jnp.float32)             # (TC, hd)
    kn = kmin_ref[0, 0].astype(jnp.float32)
    qp = jnp.maximum(q, 0.0)
    qn = jnp.minimum(q, 0.0)
    # (G, hd) x (hd, TC) on the MXU; group-sum afterwards
    hi = jnp.dot(qp, km.T, preferred_element_type=jnp.float32) \
        + jnp.dot(qn, kn.T, preferred_element_type=jnp.float32)
    lo = jnp.dot(qp, kn.T, preferred_element_type=jnp.float32) \
        + jnp.dot(qn, km.T, preferred_element_type=jnp.float32)
    ub_ref[0, 0] = jnp.sum(hi, axis=0)
    lb_ref[0, 0] = jnp.sum(lo, axis=0)


@functools.partial(jax.jit, static_argnames=("tile_c", "interpret"))
def chunk_bounds_pallas(q: jax.Array, kmax: jax.Array, kmin: jax.Array,
                        *, tile_c: int = 128, interpret: bool = False
                        ) -> Tuple[jax.Array, jax.Array]:
    """q: (B, Hkv, G, hd); kmax/kmin: (B, Hkv, nc, hd) -> (ub, lb) (B,Hkv,nc).

    nc is padded to a multiple of ``tile_c`` by the caller (ops.py).
    """
    B, Hkv, G, hd = q.shape
    nc = kmax.shape[2]
    assert nc % tile_c == 0, (nc, tile_c)
    grid = (B, Hkv, nc // tile_c)
    out_shape = [jax.ShapeDtypeStruct((B, Hkv, nc), jnp.float32),
                 jax.ShapeDtypeStruct((B, Hkv, nc), jnp.float32)]
    return pl.pallas_call(
        _bounds_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, tile_c, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, tile_c, hd), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, tile_c), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, tile_c), lambda b, h, c: (b, h, c)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(q, kmax, kmin)
