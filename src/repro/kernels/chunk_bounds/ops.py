"""Dispatching wrapper: Pallas on TPU, interpret-mode for validation,
jnp reference otherwise."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.chunk_bounds.chunk_bounds import chunk_bounds_pallas
from repro.kernels.chunk_bounds.ref import chunk_bounds_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def chunk_bounds(q: jax.Array, kmax: jax.Array, kmin: jax.Array, *,
                 impl: Optional[str] = None, tile_c: int = 128
                 ) -> Tuple[jax.Array, jax.Array]:
    """q: (B, Hkv, G, hd); kmax/kmin: (B, Hkv, nc, hd) -> (ub, lb).

    impl: None (auto) | "pallas" | "interpret" | "ref".
    """
    if impl is None:
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return chunk_bounds_ref(q, kmax, kmin)
    nc = kmax.shape[2]
    tile = min(tile_c, max(8, nc))
    pad = (-nc) % tile
    if pad:
        fill = jnp.zeros((*kmax.shape[:2], pad, kmax.shape[3]), kmax.dtype)
        kmax = jnp.concatenate([kmax, fill - 1e30], axis=2)
        kmin = jnp.concatenate([kmin, fill + 1e30], axis=2)
    ub, lb = chunk_bounds_pallas(q, kmax, kmin, tile_c=tile,
                                 interpret=(impl == "interpret"))
    if pad:
        ub, lb = ub[:, :, :nc], lb[:, :, :nc]
    return ub, lb
