from repro.kernels.pq.ops import (adc_chunk_scores, pq_assign, pq_decode,
                                  pq_encode, pq_train, pq_update)

__all__ = ["pq_assign", "pq_update", "pq_train", "pq_encode", "pq_decode",
           "adc_chunk_scores"]
