"""PQ abstract plane: dispatching kernel wrappers + store-facing helpers.

``pq_assign`` / ``pq_update`` dispatch like every kernel in this tree
(Pallas on TPU, interpret for validation, jnp reference otherwise).  On
top of them:

* :func:`pq_train` — deterministic online mini-batch k-means.  An
  untrained codebook initializes from strided batch rows and runs a few
  Lloyd iterations; a trained one takes a single running-mean merge
  (``c_k <- (c_k * n_k + sum_batch_k) / (n_k + cnt_batch_k)``), so
  per-layer codebooks keep adapting as new sequences ingest.  No RNG
  anywhere: two runs over the same ingest order produce byte-identical
  codebooks.
* :func:`pq_encode` / :func:`pq_decode` — uint8 codes per (token, kv
  head) key vector; decode is the centroid gather (the quantities the
  round-trip property tests bound).
* :func:`adc_chunk_scores` — the engine's asymmetric-distance path: one
  (B, Hkv, m, K) lookup table per round/layer (q·centroid dots), then a
  code gather + subspace sum + per-chunk max.  Replaces the min/max
  bounds matmul for chunks whose codes are fresh.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.pq.pq_kmeans import pq_assign_pallas, pq_update_pallas
from repro.kernels.pq.ref import pq_assign_ref, pq_update_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pq_assign(x: jax.Array, cb: jax.Array, *, impl: Optional[str] = None,
              tile_n: int = 256) -> jax.Array:
    """x: (m, N, dsub); cb: (m, K, dsub) -> codes (m, N) int32.

    impl: None (auto) | "pallas" | "interpret" | "ref".
    """
    if impl is None:
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return pq_assign_ref(x, cb)
    N = x.shape[1]
    tile = min(tile_n, max(8, N))
    pad = (-N) % tile
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((x.shape[0], pad, x.shape[2]), x.dtype)], axis=1)
    codes = pq_assign_pallas(x, cb, tile_n=tile,
                             interpret=(impl == "interpret"))
    return codes[:, :N] if pad else codes


def pq_update(x: jax.Array, codes: jax.Array, n_centroids: int, *,
              impl: Optional[str] = None, tile_n: int = 256
              ) -> Tuple[jax.Array, jax.Array]:
    """One Lloyd accumulation: (sums (m, K, dsub), counts (m, K))."""
    if impl is None:
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return pq_update_ref(x, codes, n_centroids)
    N = x.shape[1]
    tile = min(tile_n, max(8, N))
    pad = (-N) % tile
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((x.shape[0], pad, x.shape[2]), x.dtype)], axis=1)
        # padded rows carry the out-of-range sentinel K: all-zero one-hot
        codes = jnp.concatenate(
            [codes, jnp.full((codes.shape[0], pad), n_centroids,
                             codes.dtype)], axis=1)
    return pq_update_pallas(x, codes, n_centroids=n_centroids, tile_n=tile,
                            interpret=(impl == "interpret"))


def _subspaces(vecs: np.ndarray, m: int) -> np.ndarray:
    """(n, d) vectors -> (m, n, dsub) per-subspace rows (f32)."""
    n, d = vecs.shape
    return np.ascontiguousarray(
        vecs.reshape(n, m, d // m).transpose(1, 0, 2)).astype(np.float32)


def pq_train(vecs: np.ndarray, codebook: np.ndarray, counts: np.ndarray, *,
             iters: int = 4, impl: Optional[str] = None
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Online k-means step over one ingest batch.

    vecs: (n, d) raw key vectors; codebook: (m, K, dsub); counts: (m, K)
    running member counts (all-zero == untrained).  Returns the updated
    (codebook, counts) — numpy, ready for the store's RAM mirror.
    """
    cb = np.asarray(codebook, np.float32).copy()
    cnt = np.asarray(counts, np.float64).copy()
    m, K, _dsub = cb.shape
    n = int(vecs.shape[0])
    if n == 0:
        return cb, cnt
    x = _subspaces(np.asarray(vecs, np.float32), m)       # (m, n, dsub)
    xj = jnp.asarray(x)
    if cnt.sum() == 0:
        # deterministic strided-row init (no RNG); n < K duplicates rows,
        # leaving some clusters empty — they keep their seed value
        idx = (np.arange(K) * max(1, n // K)) % n
        cb = x[:, idx].copy()
        c = np.zeros((m, K), np.float64)
        for _ in range(max(1, iters)):
            codes = pq_assign(xj, jnp.asarray(cb), impl=impl)
            sums, cf = pq_update(xj, codes, K, impl=impl)
            sums, c = np.asarray(sums, np.float64), np.asarray(cf, np.float64)
            nz = c > 0
            cb[nz] = (sums[nz] / c[nz][:, None]).astype(np.float32)
        cnt = c
    else:
        codes = pq_assign(xj, jnp.asarray(cb), impl=impl)
        sums, cf = pq_update(xj, codes, K, impl=impl)
        sums, c = np.asarray(sums, np.float64), np.asarray(cf, np.float64)
        tot = cnt + c
        nz = tot > 0
        merged = (cb.astype(np.float64) * cnt[..., None] + sums)
        cb[nz] = (merged[nz] / tot[nz][:, None]).astype(np.float32)
        cnt = tot
    return cb, cnt


def pq_encode(vecs: np.ndarray, codebook: np.ndarray, *,
              impl: Optional[str] = None) -> np.ndarray:
    """(n, d) key vectors -> (n, m) uint8 nearest-centroid codes."""
    cb = np.asarray(codebook, np.float32)
    m, K, _dsub = cb.shape
    assert K <= 256, K
    x = _subspaces(np.asarray(vecs, np.float32), m)
    codes = np.asarray(pq_assign(jnp.asarray(x), jnp.asarray(cb), impl=impl))
    return np.ascontiguousarray(codes.T).astype(np.uint8)


def pq_decode(codes: np.ndarray, codebook: np.ndarray) -> np.ndarray:
    """(..., m) uint8 codes -> (..., d) reconstructed vectors (f32)."""
    cb = np.asarray(codebook, np.float32)
    m, _K, dsub = cb.shape
    flat = np.asarray(codes).reshape(-1, m).astype(np.int64)
    out = cb[np.arange(m)[None, :], flat]                 # (N, m, dsub)
    return out.reshape(np.asarray(codes).shape[:-1] + (m * dsub,))


@jax.jit
def _adc_scores_jit(q_sum: jax.Array, cb: jax.Array, codes: jax.Array,
                    lengths: jax.Array) -> jax.Array:
    B, Hkv, hd = q_sum.shape
    m, _K, dsub = cb.shape
    nc, chunk = codes.shape[1], codes.shape[2]
    lut = jnp.einsum("bhmd,mkd->bhmk",
                     q_sum.reshape(B, Hkv, m, dsub), cb)  # (B,Hkv,m,K)
    idx = codes.astype(jnp.int32).transpose(0, 3, 4, 1, 2) \
        .reshape(B, Hkv, m, nc * chunk)
    vals = jnp.take_along_axis(lut, idx, axis=3)          # (B,Hkv,m,nc*chunk)
    tok = vals.sum(2).reshape(B, Hkv, nc, chunk)
    pos = jnp.arange(nc * chunk).reshape(nc, chunk)
    live = pos[None] < lengths[:, None, None]             # (B, nc, chunk)
    tok = jnp.where(live[:, None], tok, -jnp.inf)
    return tok.max(-1)                                    # (B, Hkv, nc)


def adc_chunk_scores(q_sum: np.ndarray, codebook: np.ndarray,
                     codes: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    """Asymmetric-distance chunk scores off PQ codes.

    q_sum: (B, Hkv, hd) group-summed pre-scaled queries (the exact-logit
    analog of the bounds path's per-group sum); codebook: (m, K, dsub);
    codes: (B, nc, chunk, Hkv, m) uint8; lengths: (B,) live token counts
    (tokens at or past a sequence's length are masked out of the max).
    Returns (B, Hkv, nc) f32 — same layout as the bounds matmul's ub.
    """
    return np.asarray(_adc_scores_jit(
        jnp.asarray(q_sum, jnp.float32), jnp.asarray(codebook, jnp.float32),
        jnp.asarray(codes), jnp.asarray(np.asarray(lengths), jnp.int32)))
