"""jnp reference for the PQ k-means kernels.

Uses the SAME ``|c_k|^2 - 2 x.c_k`` distance expression as the Pallas
kernel so argmin tie-breaking (first minimal index) matches exactly —
the kernel tests compare codes with ``assert_array_equal``, not allclose.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def pq_assign_ref(x: jax.Array, cb: jax.Array) -> jax.Array:
    """x: (m, N, dsub); cb: (m, K, dsub) -> codes (m, N) int32."""
    x = jnp.asarray(x, jnp.float32)
    cb = jnp.asarray(cb, jnp.float32)
    d = jnp.sum(cb * cb, axis=-1)[:, None, :] \
        - 2.0 * jnp.einsum("mnd,mkd->mnk", x, cb)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def pq_update_ref(x: jax.Array, codes: jax.Array, n_centroids: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """x: (m, N, dsub); codes: (m, N) -> (sums (m, K, dsub), counts (m, K)).

    Out-of-range codes (the dispatcher's padding sentinel ``K``) match no
    centroid and contribute nothing, same as the kernel's one-hot.
    """
    x = jnp.asarray(x, jnp.float32)
    onehot = (jnp.asarray(codes, jnp.int32)[..., None]
              == jnp.arange(n_centroids)[None, None, :]).astype(jnp.float32)
    sums = jnp.einsum("mnk,mnd->mkd", onehot, x)
    counts = jnp.sum(onehot, axis=1)
    return sums, counts
