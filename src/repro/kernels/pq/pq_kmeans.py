"""Pallas TPU kernels: online product-quantization k-means (PQ abstracts).

Two kernels over per-subspace key vectors ``x: (m, N, dsub)`` (head_dim
split into ``m`` subvectors of ``dsub`` lanes) and a codebook
``cb: (m, K, dsub)``:

* **assign** — nearest-centroid codes.  Per grid step (subspace i, row
  tile n) the kernel holds one (TN, dsub) vector tile and the subspace's
  (K, dsub) codebook in VMEM and issues one MXU matmul:
  ``argmin_k |x - c_k|^2 == argmin_k (|c_k|^2 - 2 x.c_k)`` — the |x|^2
  term is constant per row, so the full distance never materializes.
* **update** — one k-means accumulation pass: per-centroid coordinate
  sums and member counts via a one-hot matmul, accumulated across row
  tiles (grid dim 1 revisits the same output block, the TPU-sequential
  reduction pattern).

Both run in interpret mode on CPU (how the tier-1 suite verifies them);
the jnp oracle in ``ref.py`` uses the SAME distance expression so argmin
tie-breaking matches bit-for-bit.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(x_ref, cb_ref, codes_ref):
    x = x_ref[0].astype(jnp.float32)                    # (TN, dsub)
    cb = cb_ref[0].astype(jnp.float32)                  # (K, dsub)
    # (TN, dsub) x (dsub, K) on the MXU; |c_k|^2 folded in afterwards
    d = jnp.sum(cb * cb, axis=1)[None, :] \
        - 2.0 * jnp.dot(x, cb.T, preferred_element_type=jnp.float32)
    codes_ref[0] = jnp.argmin(d, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def pq_assign_pallas(x: jax.Array, cb: jax.Array, *, tile_n: int = 256,
                     interpret: bool = False) -> jax.Array:
    """x: (m, N, dsub); cb: (m, K, dsub) -> codes (m, N) int32.

    N is padded to a multiple of ``tile_n`` by the caller (ops.py).
    """
    m, N, dsub = x.shape
    K = cb.shape[1]
    assert N % tile_n == 0, (N, tile_n)
    grid = (m, N // tile_n)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_n, dsub), lambda i, n: (i, n, 0)),
            pl.BlockSpec((1, K, dsub), lambda i, n: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i, n: (i, n)),
        out_shape=jax.ShapeDtypeStruct((m, N), jnp.int32),
        interpret=interpret,
    )(x, cb)


def _update_kernel(x_ref, codes_ref, sums_ref, counts_ref):
    # grid dim 1 revisits the same (subspace-indexed) output block: zero
    # it on the first tile, accumulate on every tile
    @pl.when(pl.program_id(1) == 0)
    def _init():
        sums_ref[0] = jnp.zeros_like(sums_ref[0])
        counts_ref[0] = jnp.zeros_like(counts_ref[0])

    x = x_ref[0].astype(jnp.float32)                    # (TN, dsub)
    codes = codes_ref[0]                                # (TN,)
    K = sums_ref.shape[1]
    # padded rows carry code == K (out of range): the one-hot row is all
    # zeros, so padding never perturbs sums or counts
    onehot = (codes[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0], K), 1)).astype(jnp.float32)
    sums_ref[0] += jnp.dot(onehot.T, x,
                           preferred_element_type=jnp.float32)
    counts_ref[0] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit,
                   static_argnames=("n_centroids", "tile_n", "interpret"))
def pq_update_pallas(x: jax.Array, codes: jax.Array, *, n_centroids: int,
                     tile_n: int = 256, interpret: bool = False
                     ) -> Tuple[jax.Array, jax.Array]:
    """x: (m, N, dsub); codes: (m, N) int32 -> (sums (m, K, dsub),
    counts (m, K)) — one accumulation pass of Lloyd's update."""
    m, N, dsub = x.shape
    assert N % tile_n == 0, (N, tile_n)
    grid = (m, N // tile_n)
    out_shape = [
        jax.ShapeDtypeStruct((m, n_centroids, dsub), jnp.float32),
        jax.ShapeDtypeStruct((m, n_centroids), jnp.float32),
    ]
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_n, dsub), lambda i, n: (i, n, 0)),
            pl.BlockSpec((1, tile_n), lambda i, n: (i, n)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_centroids, dsub), lambda i, n: (i, 0, 0)),
            pl.BlockSpec((1, n_centroids), lambda i, n: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(x, codes)
