"""Pallas TPU kernel: fused KV transit decompression (paper §4.4).

KV chunks arrive from the host tier int4/int8-packed (the DTP codec); this
kernel unpacks + rescales them on-chip so the decompression cost t(Dθ) the
paper's θ-balance trades against never touches HBM bandwidth twice — the
packed bytes are read once, bf16 output lands directly in VMEM for the
attention kernel.

Grid: one program per KV chunk; pure VPU (no MXU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dequant_int8_kernel(d_ref, s_ref, o_ref, *, out_dtype):
    d = d_ref[0].astype(jnp.float32)                    # (c, d)
    s = s_ref[0].astype(jnp.float32)                    # (1, d)
    o_ref[0] = (d * s).astype(out_dtype)


def _dequant_int4_kernel(d_ref, s_ref, o_ref, *, out_dtype):
    u = d_ref[0].astype(jnp.int32) & 0xFF               # (c, d//2)
    lo = u & 0xF
    hi = (u >> 4) & 0xF
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    c, half = u.shape
    q = jnp.stack([lo, hi], axis=-1).reshape(c, half * 2).astype(jnp.float32)
    s = s_ref[0].astype(jnp.float32)
    o_ref[0] = (q * s).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("codec", "out_dtype", "interpret"))
def kv_dequant_pallas(data: jax.Array, scale: jax.Array, *, codec: str,
                      out_dtype=jnp.bfloat16, interpret: bool = False
                      ) -> jax.Array:
    """data: (N, c, dp) int8 (dp = d or d//2); scale: (N, d) f32."""
    N, c, dp = data.shape
    d = scale.shape[-1]
    kern = (_dequant_int4_kernel if codec == "int4" else _dequant_int8_kernel)
    return pl.pallas_call(
        functools.partial(kern, out_dtype=out_dtype),
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, c, dp), lambda n: (n, 0, 0)),
            pl.BlockSpec((1, d), lambda n: (n, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, d), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, c, d), out_dtype),
        interpret=interpret,
    )(data, scale)
