"""Pure-jnp oracle for the kv_dequant kernel (int4/int8 transit codec)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dequant_int8_ref(data: jax.Array, scale: jax.Array,
                     dtype=jnp.bfloat16) -> jax.Array:
    """data: (N, c, d) int8; scale: (N, d) f32 -> (N, c, d)."""
    return (data.astype(jnp.float32) * scale[:, None, :]).astype(dtype)


def dequant_int4_ref(data: jax.Array, scale: jax.Array,
                     dtype=jnp.bfloat16) -> jax.Array:
    """data: (N, c, d//2) int8 packed nibbles; scale: (N, d) f32 -> (N, c, d).

    Packing: byte = lo | (hi << 4); values are 4-bit two's complement.
    """
    u = data.astype(jnp.uint8)
    lo = (u & 0xF).astype(jnp.int8)
    hi = ((u >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    q = jnp.stack([lo, hi], axis=-1).reshape(*data.shape[:-1],
                                             data.shape[-1] * 2)
    return (q.astype(jnp.float32) * scale[:, None, :]).astype(dtype)
