"""Dispatching wrapper for KV transit decompression."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.kv_quant.kv_quant import kv_dequant_pallas
from repro.kernels.kv_quant.ref import dequant_int4_ref, dequant_int8_ref


def kv_dequant(data: jax.Array, scale: jax.Array, *, codec: str = "int4",
               out_dtype=jnp.bfloat16, impl: Optional[str] = None) -> jax.Array:
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        fn = dequant_int4_ref if codec == "int4" else dequant_int8_ref
        return fn(data, scale, out_dtype)
    return kv_dequant_pallas(data, scale, codec=codec, out_dtype=out_dtype,
                             interpret=(impl == "interpret"))
