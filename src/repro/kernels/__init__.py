# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """Version shim over the pinned JAX's Pallas-TPU compiler params.

    Newer JAX exposes ``pltpu.CompilerParams``; the pinned 0.4.x series
    calls the same dataclass ``TPUCompilerParams``.  Every kernel in this
    package routes through this helper instead of naming either directly.
    """
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams")
    return cls(**kwargs)
