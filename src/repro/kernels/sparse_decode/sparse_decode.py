"""Pallas TPU kernel: LeoAM sparse decode attention.

The selected chunk ids are a **scalar-prefetch** operand: the BlockSpec
index_map reads ``ids[b, h, j]`` to DMA exactly the selected KV chunks
HBM→VMEM — the gather never materializes in HBM.  Flash accumulators
(num/den/m) live in VMEM scratch across the sequential ``nsel`` grid dim;
invalid tail tokens (beyond ``length``) are masked with -inf.

Grid: (B, Hkv, nsel) — (parallel, parallel, arbitrary).
Block shapes: q (G, hd) resident per (b, h); KV chunks (chunk, hd), chunk a
multiple of the 128 MXU lanes for the score matmul.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG = float("-inf")


def _decode_kernel(ids_ref, len_ref, q_ref, k_ref, v_ref,
                   num_ref, den_ref, m_ref,
                   acc, den_s, m_s, *, chunk: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)
    nsel = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        den_s[...] = jnp.zeros_like(den_s)
        m_s[...] = jnp.full_like(m_s, NEG)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
    kc = k_ref[0, :, 0].astype(jnp.float32)              # (chunk, hd)
    vc = v_ref[0, :, 0].astype(jnp.float32)

    cid = ids_ref[b, h, j]
    pos = cid * chunk + jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
    valid = pos < len_ref[0]                             # (1, chunk)

    s = jnp.dot(q, kc.T, preferred_element_type=jnp.float32)  # (G, chunk)
    s = jnp.where(valid, s, NEG)

    m_prev = m_s[...]                                    # (G, 128) lane-pad
    m_cur = jnp.max(s, axis=-1, keepdims=True)           # (G, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    scale = jnp.where(jnp.isfinite(m_prev),
                      jnp.exp(m_prev - m_safe), 0.0)     # (G, 1)
    e = jnp.where(valid, jnp.exp(s - m_safe), 0.0)       # (G, chunk)
    acc[...] = acc[...] * scale[:, :1] + jnp.dot(
        e, vc, preferred_element_type=jnp.float32)
    den_s[...] = den_s[...] * scale + jnp.sum(e, axis=-1, keepdims=True)
    m_s[...] = m_new

    @pl.when(j == nsel - 1)
    def _out():
        num_ref[0, 0] = acc[...]
        den_ref[0, 0] = den_s[:, 0]
        m_ref[0, 0] = m_s[:, 0]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def sparse_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                         ids: jax.Array, length: jax.Array, *, chunk: int,
                         interpret: bool = False
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """q: (B,Hkv,G,hd) scaled; k/v: (B,S,Hkv,hd); ids: (B,Hkv,nsel) int32;
    length: () int32 -> (num, den, m) partial-softmax triple."""
    B, Hkv, G, hd = q.shape
    S = k.shape[1]
    nsel = ids.shape[-1]
    assert S % chunk == 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, nsel),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, ids, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, chunk, 1, hd),
                         lambda b, h, j, ids, ln: (b, ids[b, h, j], h, 0)),
            pl.BlockSpec((1, chunk, 1, hd),
                         lambda b, h, j, ids, ln: (b, ids[b, h, j], h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, ids, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, j, ids, ln: (b, h, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, j, ids, ln: (b, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out_shape = [
        jax.ShapeDtypeStruct((B, Hkv, G, hd), jnp.float32),
        jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
        jax.ShapeDtypeStruct((B, Hkv, G), jnp.float32),
    ]
    kernel = functools.partial(_decode_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ids, length.reshape(1), q, k, v)
