"""Dispatching wrapper for sparse decode attention."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.sparse_decode.ref import sparse_decode_ref
from repro.kernels.sparse_decode.sparse_decode import sparse_decode_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sparse_decode(q: jax.Array, k: jax.Array, v: jax.Array, ids: jax.Array,
                  length, *, chunk: int, impl: Optional[str] = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Partial-softmax sparse decode.  See ref.py for the contract."""
    if impl is None:
        impl = "pallas" if _on_tpu() else "ref"
    length = jnp.asarray(length, jnp.int32).reshape(())
    if impl == "ref":
        return sparse_decode_ref(q, k, v, ids, length, chunk=chunk)
    return sparse_decode_pallas(q, k, v, ids.astype(jnp.int32), length,
                                chunk=chunk, interpret=(impl == "interpret"))
