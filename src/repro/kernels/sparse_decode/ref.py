"""Pure-jnp oracle for the sparse_decode kernel (gather + flash decode)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG = float("-inf")


def sparse_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      ids: jax.Array, length: jax.Array, *, chunk: int
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """q: (B, Hkv, G, hd) scaled; k/v: (B, S, Hkv, hd); ids: (B, Hkv, nsel);
    length: scalar valid token count.

    Returns partial-softmax triple (num, den, m):
      num (B, Hkv, G, hd) f32; den/m (B, Hkv, G).
    """
    B, Hkv, G, hd = q.shape
    S = k.shape[1]
    tok = ids[..., None] * chunk + jnp.arange(chunk)        # (B,Hkv,nsel,c)
    tok = tok.reshape(B, Hkv, -1)
    tok_c = jnp.minimum(tok, S - 1)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    kg = jnp.take_along_axis(kt, tok_c[..., None], axis=2).astype(jnp.float32)
    vg = jnp.take_along_axis(vt, tok_c[..., None], axis=2).astype(jnp.float32)
    s = jnp.einsum("bkgd,bktd->bkgt", q.astype(jnp.float32), kg)
    valid = (tok < length) & (tok < S)
    s = jnp.where(valid[:, :, None], s, NEG)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.where(valid[:, :, None], jnp.exp(s - m_safe[..., None]), 0.0)
    den = jnp.sum(e, axis=-1)
    num = jnp.einsum("bkgt,bktd->bkgd", e, vg)
    return num, den, m
