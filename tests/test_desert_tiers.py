"""Eq.(2) chunk-size policy, desert-rate statistics, and tier placement."""

import numpy as np
import pytest

from repro.core.desert import (chunk_size_schedule, desert_rate, eval_cost,
                               optimal_chunk_count, optimal_chunk_size)
from repro.core.tiers import (AccessTable, TierSpec, abstract_overhead,
                              kv_bytes, lka_transfer_ratio, plan_placement)


def test_eval_cost_token_level_limit():
    assert eval_cost(1024, 1024, 0.1) == 1024.0


def test_dense_layers_get_finer_chunks():
    """Insight 2: high ρ (dense early layers) → more initial chunks."""
    m_dense = optimal_chunk_count(4096, 0.5)
    m_sparse = optimal_chunk_count(4096, 0.05)
    assert m_dense >= m_sparse
    s_dense = optimal_chunk_size(4096, 0.5)
    s_sparse = optimal_chunk_size(4096, 0.05)
    assert s_dense <= s_sparse


def test_chunk_size_schedule_shape():
    sched = chunk_size_schedule(32768, 32, early_layers=2)
    assert len(sched) == 32
    assert sched[0] <= sched[-1]
    assert all(s & (s - 1) == 0 for s in sched)   # powers of two


def test_desert_rate_on_planted():
    s = np.zeros(1024)
    s[100:110] = 1.0
    s[800:820] = 2.0
    rate = desert_rate(s + 1e-9 * np.arange(1024), chunk=16, rate=0.03)
    assert rate > 0.9


def test_lka_ratio_formula():
    assert lka_transfer_ratio(0.1, 32) == pytest.approx(0.1 + 2 / 32)
    # paper's example: alpha=0.1, n'=32 -> r = 13.25% ... of two-sided KV
    assert lka_transfer_ratio(0.1, 32) == pytest.approx(0.1625)


def test_abstract_overhead_matches_paper():
    """§6.5: <1.6% storage overhead at chunk 64."""
    assert abstract_overhead(64) == pytest.approx(0.015625)


def test_placement_respects_capacity_and_early_rule():
    kv = kv_bytes(32768, 8, 128)
    spec = TierSpec(gpu_bytes=4 * kv * 0.2, cpu_bytes=10 * kv * 0.5)
    pl = plan_placement(kv, 32, spec, early_layers=2, importance_rate=0.1)
    assert len(pl) == 32
    for p in pl[:2]:
        assert p.disk_frac == 0.0            # early layers never on disk
    total_gpu = sum(p.gpu_frac for p in pl) * kv
    assert total_gpu <= spec.gpu_bytes * 1.01
    assert any(p.disk_frac > 0 for p in pl[2:])


def test_access_table_hot_pinning():
    t = AccessTable(64)
    for _ in range(10):
        t.record(np.array([3, 3, 7]))
    hot = set(t.hot_tokens(0.05).tolist())
    assert 3 in hot
