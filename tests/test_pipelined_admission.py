"""Pipelined admission path (PR 3): write-behind prefill ingest behind a
completion fence, admission under decode, pool-aware scheduler admission,
and packed int4 disk replicas — parity, billing and drain guarantees."""

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import compression
from repro.core.pipeline import PrefillLayerCost, prefill_schedule
from repro.serving.offload import DEVICE, DISK, HOST, TieredKVStore
from repro.serving.scheduler import ContinuousBatcher, Request, SchedulerCfg

_SETUP = {}


def _setup():
    """Module-lazy smoke model (the hypothesis shim can't take fixtures)."""
    if not _SETUP:
        import jax
        from repro.configs import get_config
        from repro.models import lm
        cfg = get_config("longchat-7b-32k", smoke=True)
        cfg = dataclasses.replace(
            cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                           importance_rate=0.4,
                                           early_rate=0.6,
                                           min_seq_for_sparse=32))
        _SETUP["cfg"] = cfg
        _SETUP["params"] = lm.init(cfg, jax.random.PRNGKey(1))
        rng = np.random.RandomState(7)
        _SETUP["prompts"] = [rng.randint(2, cfg.vocab_size, n)
                             for n in (48, 57, 64, 50)]
    return _SETUP["cfg"], _SETUP["params"], _SETUP["prompts"]


def _ecfg(**kw):
    from repro.serving.engine import EngineCfg
    return EngineCfg(max_len=128, selection="tree", **kw)


def _drive(order, *, overlap, max_new=4, scfg_kw=None, ecfg_kw=None):
    """Run the continuous batcher over ``prompts[i] for i in order``;
    returns ({rid: tokens}, stats, engine-before-close pool stats)."""
    from repro.serving.engine import BatchedLeoAMEngine
    cfg, params, prompts = _setup()
    eng = BatchedLeoAMEngine(
        cfg, params, _ecfg(overlap_ingest=overlap, **(ecfg_kw or {})),
        max_seqs=2)
    b = ContinuousBatcher(
        cfg=SchedulerCfg(max_active=2, chunk=16, overlap_admission=overlap,
                         **(scfg_kw or {})),
        engine=eng)
    for i in order:
        b.submit(Request(i, prompts[i], max_new=max_new))
    out = {r.rid: r.out for r in b.run()}
    stats = b.stats()
    ps = eng.pool_stats()
    eng.store.close()
    return out, stats, ps


_SERIAL_REF = {}


def _serial_reference(max_new=4):
    if max_new not in _SERIAL_REF:
        _SERIAL_REF[max_new] = _drive(range(4), overlap=False,
                                      max_new=max_new)[0]
    return _SERIAL_REF[max_new]


def test_overlap_ingest_token_identical():
    """Write-behind ingest (layer-streamed, fenced) decodes exactly the
    serial-ingest token streams; the admit profile records the overlap."""
    from repro.serving.engine import BatchedLeoAMEngine
    cfg, params, prompts = _setup()
    streams = {}
    for overlap in (False, True):
        eng = BatchedLeoAMEngine(cfg, params,
                                 _ecfg(overlap_ingest=overlap), max_seqs=2)
        toks = {}
        for p in prompts[:2]:
            sid, tok = eng.add_sequence(p)
            toks[sid] = tok
        outs = {sid: [t] for sid, t in toks.items()}
        for _ in range(4):
            toks = eng.decode_round(toks)
            for sid, t in toks.items():
                outs[sid].append(t)
        assert all(p["overlapped"] == float(overlap)
                   for p in eng.admit_profiles)
        streams[overlap] = [outs[s] for s in sorted(outs)]
        eng.store.close()
    assert streams[True] == streams[False]


@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_admission_under_decode_arrival_order_parity(seed):
    """Property: overlapped admission + write-behind ingest produce
    token-identical outputs to the serial path for EVERY queue arrival
    order — admission timing and batch composition move residency and
    latency, never values."""
    order = list(np.random.RandomState(seed).permutation(4))
    got, _, _ = _drive(order, overlap=True)
    assert got == _serial_reference(), (order, got)


def test_release_drains_inflight_writes_before_slot_reuse():
    """A retired sequence's write-behind ingest is drained by release();
    the slot's next occupant decodes exactly as on a fresh engine."""
    from repro.serving.engine import BatchedLeoAMEngine
    cfg, params, prompts = _setup()

    def gen(eng, p, n=4):
        sid, tok = eng.add_sequence(p)
        out = [tok]
        toks = {sid: tok}
        for _ in range(n):
            toks = eng.decode_round(toks)
            out.append(toks[sid])
        return sid, out

    fresh = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=1)
    _, want = gen(fresh, prompts[1])
    fresh.store.close()

    eng = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=1)
    sid, _tok = eng.add_sequence(prompts[0])
    eng.release(sid)                  # cold writes may still be in flight
    sid2, got = gen(eng, prompts[1])
    assert sid2 == sid                # the slot really was recycled
    assert got == want
    eng.store.close()


def test_oversized_prompt_rejected_without_slot_leak():
    """Prompt-length validation runs BEFORE the slot pop: a rejected
    oversized request must not eat a sequence slot (sync or async).
    The guard raises ValueError (admission input validation survives
    ``python -O``, unlike the old assert)."""
    from repro.serving.engine import BatchedLeoAMEngine
    cfg, params, _prompts_unused = _setup()
    eng = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=1)
    too_long = np.arange(2, 200, dtype=np.int64) % cfg.vocab_size
    for add in (eng.add_sequence, eng.add_sequence_async):
        with pytest.raises(ValueError, match="max_len"):
            add(too_long)
        assert eng.free_slots == 1
    eng.store.close()


def test_ingest_fence_orders_cold_writes(rng):
    """The completion fence: abstracts/replicas written behind a slow
    executor are invisible until ingest_fence returns, complete after."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    st_ = TieredKVStore(1, 4, 16, 2, 8, n_seqs=1, transit_codec=None)
    ex = ThreadPoolExecutor(max_workers=1)
    gate = threading.Event()
    ex.submit(gate.wait)              # stall the queue behind a gate
    st_.ingest(0, k, k, {c: DISK for c in range(4)}, executor=ex)
    assert st_.log.total(kind="kv_replica") == 0.0   # still queued
    assert np.all(np.isinf(st_._abs_km[0, 0, 0]))
    gate.set()
    st_.ingest_fence(0)
    assert st_.log.total(kind="kv_replica") == 4 * st_.chunk_bytes
    km, _ = st_.read_abstracts(0, [0])
    np.testing.assert_array_equal(km[0], k[:16].max(0))
    # fencing again is a no-op; the disk payload is complete
    st_.ingest_fence(0)
    ks, _ = st_.fetch_chunks(0, [0, 1, 2, 3])
    np.testing.assert_array_equal(ks.reshape(64, 2, 8), k)
    st_.close()
    ex.shutdown()


def test_sidecar_promotion_bytes_and_values(rng):
    """Packed int4/int8 disk replicas: replica writes AND disk→host
    promotions bill exactly chunk_bytes × codec_ratio(codec, chunk); the
    promoted values match fp16 within the symmetric-quantization bound.
    The fp16 replica stays the lossless fallback behind the flag."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    v = rng.randn(64, 2, 8).astype(np.float16)
    for codec in ("int4", "int8"):
        st_ = TieredKVStore(1, 4, 16, 2, 8, n_seqs=1, transit_codec=codec,
                            use_pool=True, real_codec=True,
                            disk_sidecar=True)
        packed = st_.chunk_bytes * compression.codec_ratio(codec,
                                                           group=st_.chunk)
        # the compression-module identity the sidecar layout relies on
        assert 2 * compression.packed_chunk_bytes(codec, 16, 16) == packed
        st_.ingest(0, k, v, {c: DISK for c in range(4)})
        assert st_.log.total(kind="kv_replica") == pytest.approx(4 * packed)
        _, _, fst = st_.fetch_chunks_pooled(0, {0: [0, 1, 2, 3]}, theta=0.0)
        assert fst.disk_reads == 4
        assert fst.disk_bytes == pytest.approx(4 * packed)
        assert st_.log.bytes[(DISK, HOST, "kv")] == pytest.approx(4 * packed)
        # promoted values: per-chunk symmetric quantization error bound
        _, scale_k = compression.quantize_chunks(k.reshape(4, 16, 2, 8),
                                                 codec)
        got = np.stack([st_._host_k[(0, 0, c)] for c in range(4)])
        err = np.abs(got.astype(np.float32)
                     - k.reshape(4, 16, 2, 8).astype(np.float32))
        assert np.all(err <= scale_k.reshape(4, 1, 2, 8) / 2 + 2e-3)
        st_.close()

    # lossless fallback flag: reads bypass the sidecar, bill full fp16
    st_ = TieredKVStore(1, 4, 16, 2, 8, n_seqs=1, transit_codec="int4",
                        use_pool=True, disk_sidecar=True,
                        sidecar_lossless=True)
    st_.ingest(0, k, v, {c: DISK for c in range(4)})
    _, _, fst = st_.fetch_chunks_pooled(0, {0: [0, 1]})
    assert fst.disk_bytes == pytest.approx(2 * float(st_.chunk_bytes))
    np.testing.assert_array_equal(
        np.stack([st_._host_k[(0, 0, c)] for c in range(2)]).reshape(
            32, 2, 8), k[:32])
    st_.close()


def test_sidecar_append_invalidates_chunk(rng):
    """A decode append stales the chunk's per-chunk scales: the sidecar is
    invalidated and the next promotion reads the lossless fp16 replica
    (full bytes, exact values — including the appended row)."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    st_ = TieredKVStore(1, 8, 16, 2, 8, n_seqs=1, transit_codec="int4",
                        use_pool=True, disk_sidecar=True)
    st_.ingest(0, k, k, {c: DISK for c in range(4)})
    assert bool(st_._sidecar_valid[0, 0, 3])
    newk = rng.randn(2, 8).astype(np.float16)
    st_.append_token(0, 63, newk, newk)         # last row of chunk 3
    assert not st_._sidecar_valid[0, 0, 3]
    assert bool(st_._sidecar_valid[0, 0, 2])    # untouched chunks keep it
    _, _, fst = st_.fetch_chunks_pooled(0, {0: [3]})
    assert fst.disk_bytes == pytest.approx(float(st_.chunk_bytes))
    np.testing.assert_array_equal(st_._host_k[(0, 0, 3)][15], newk)
    st_.close()


def test_deferred_pool_placement_folds_unbilled(rng):
    """Admission under decode defers device placements (the decode thread
    owns the slab); the next pooled fetch folds them into its slab update
    with ZERO H2D billing — same semantics as the synchronous prefill
    placement, whose KV was produced on device."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    st_ = TieredKVStore(1, 4, 16, 2, 8, n_seqs=1, transit_codec=None,
                        use_pool=True)
    st_.ingest(0, k, k, {0: DEVICE, 1: DEVICE, 2: HOST, 3: HOST},
               pool_place=False)
    assert (0, 0) in st_.pools[0].pending_place
    assert st_.tier[0, 0, 0] == HOST          # host-tiered until folded
    st_.fetch_chunks_pooled(0, {0: [2]})
    assert not st_.pools[0].pending_place
    assert (0, 0) in st_.pools[0].slot_of and (0, 1) in st_.pools[0].slot_of
    assert st_.tier[0, 0, 0] == DEVICE
    # only the SELECTED chunk's upload was billed; the folds were free
    assert st_.log.bytes.get((HOST, DEVICE, "kv"), 0.0) == st_.chunk_bytes
    kv = np.asarray(st_.pools[0].kv)
    np.testing.assert_array_equal(kv[st_.pools[0].slot_of[(0, 0)], 0],
                                  k[:16])
    # a later selection of the folded chunk is a pool hit: still no bytes
    st_.fetch_chunks_pooled(0, {0: [0, 1]})
    assert st_.log.bytes.get((HOST, DEVICE, "kv"), 0.0) == st_.chunk_bytes
    st_.close()


def test_pool_aware_admission_beats_analytic_budget():
    """Pool-aware admission charges live per-round working sets against
    the actual slab, not max_len worst cases: a budget that admits ONE
    request analytically runs TWO concurrently on the pooled engine."""
    from repro.serving.engine import BatchedLeoAMEngine
    cfg, params, prompts = _setup()
    # analytic: ceil((48..64 + 4) / 16) = 4 chunks per request -> a budget
    # of 6 admits one.  pool-aware: per-round need is charged against the
    # pool's real slot count instead.
    scfg = SchedulerCfg(max_active=2, chunk=16, device_chunk_budget=6)
    eng = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=2)
    need = eng.admission_need_chunks(64, 4)
    assert 2 * need <= eng.pool_stats()["slots"]
    batched = ContinuousBatcher(cfg=scfg, engine=eng)
    for rid in range(3):
        batched.submit(Request(rid, prompts[rid], max_new=4))
    batched.step()
    assert len(batched.active) == 2           # analytic budget would say 1
    done = batched.run()
    assert len(done) == 3
    eng.store.close()

    analytic = ContinuousBatcher(
        cfg=dataclasses.replace(scfg, pool_aware=False),
        engine=BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=2))
    for rid in range(2):
        analytic.submit(Request(rid, prompts[rid], max_new=4))
    analytic.step()
    assert len(analytic.active) == 1
    analytic.run()
    analytic.engine.store.close()


def test_scheduler_stats_percentiles_out_of_order():
    """stats() reports p50/p95 TTFT and per-request decode tok/s, and the
    span guard survives requests finishing out of submit order (the first
    submit finishes LAST here)."""
    _, stats, _ = _drive([0, 1, 2], overlap=False, max_new=4)
    for key in ("p50_ttft_s", "p95_ttft_s", "mean_ttft_s",
                "p50_decode_tok_s", "p95_decode_tok_s",
                "mean_decode_tok_s", "throughput_tok_s"):
        assert key in stats and stats[key] > 0, key
    assert stats["p95_ttft_s"] >= stats["p50_ttft_s"]
    assert stats["p95_decode_tok_s"] >= stats["p50_decode_tok_s"]

    # synthetic out-of-order finish: early submitter done last
    b = ContinuousBatcher(make_engine=lambda: None)
    t = time.perf_counter()
    a = Request(0, np.arange(4), max_new=3)
    a.t_submit, a.t_first, a.t_done = t, t + 2.0, t + 5.0
    a.out = [1, 2, 3]
    c = Request(1, np.arange(4), max_new=1)
    c.t_submit, c.t_first, c.t_done = t + 1.0, t + 1.5, t + 1.5
    c.out = [1]                      # 1-token request: never decoded
    b.finished = [a, c]
    s = b.stats()
    assert s["requests"] == 2
    assert s["throughput_tok_s"] > 0
    assert s["mean_decode_tok_s"] == pytest.approx(2 / 3.0)


def test_prefill_schedule_write_behind_hides_tier_writes():
    """Analytic admission model: write-behind TTFT equals the compute
    chain; serial admission pays every tier write in line."""
    layers = [PrefillLayerCost(compute=1.0, replica_bytes=2e9)
              for _ in range(4)]
    bw = 4e9                          # 0.5 s of writes per layer
    serial = prefill_schedule(layers, bw, write_behind=False)
    wb = prefill_schedule(layers, bw, write_behind=True)
    # serial: 4 computes + the 3 preceding writes stall the chain, and the
    # last write lands after the final compute
    assert serial.compute[-1][1] == pytest.approx(4 * 1.0 + 3 * 0.5)
    assert serial.makespan == pytest.approx(4 * 1.5)
    assert wb.compute[-1][1] == pytest.approx(4 * 1.0)   # TTFT: compute only
    assert wb.makespan < serial.makespan
    # the fence window: writes finish after the compute chain ends
    assert wb.transfer[-1][1] >= wb.compute[-1][1]
