"""Chaos property tests: seeded fault injection against the full engine.

The I6 containment contract (docs/INVARIANTS.md) at the engine/scheduler
boundary, proven under the runtime sync-sanitizer:

- a seeded :class:`FaultPlan` (disk I/O errors, latency spikes, sidecar
  bit-flips, worker exceptions) may degrade or fail individual
  sequences, but every request that finishes CLEAN (no error, not
  degraded) must be **token-identical** to the fault-free reference run
  — recovery is exact, and one sequence's fault never perturbs another;
- no resource leaks survive a chaotic run: every engine slot returns to
  the free list, every ingest future is drained, every pool slot is
  reclaimed (`pool_stats`), request accounting balances;
- deterministic instances of each containment path: replica-loss
  recompute (token-identical), ingest-failure containment (one seq
  fails, the other's stream is untouched), deadline cancellation at the
  queued stage, and bounded-queue structured rejection.

Marked ``chaos`` (the dedicated CI job runs ``-m chaos``); the fuzz run
is bounded and seeded like the stress tests.
"""

import dataclasses
from concurrent.futures import Future

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as hst

from repro.serving import sanitizer
from repro.serving.faults import FaultPlan
from repro.serving.offload import DISK

_SETUP = {}


def _setup():
    if not _SETUP:
        import jax
        from repro.configs import get_config
        from repro.models import lm
        cfg = get_config("longchat-7b-32k", smoke=True)
        cfg = dataclasses.replace(
            cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                           importance_rate=0.4,
                                           early_rate=0.6,
                                           min_seq_for_sparse=32))
        _SETUP["cfg"] = cfg
        _SETUP["params"] = lm.init(cfg, jax.random.PRNGKey(1))
        rng = np.random.RandomState(7)
        _SETUP["prompts"] = [rng.randint(2, cfg.vocab_size, n)
                             for n in (48, 57, 64)]
    return _SETUP["cfg"], _SETUP["params"], _SETUP["prompts"]


def _engine(cfg, params, *, plan=None, debug_sync=True, **ecfg_kw):
    from repro.serving.engine import BatchedLeoAMEngine, EngineCfg
    return BatchedLeoAMEngine(
        cfg, params,
        EngineCfg(max_len=128, selection="tree", overlap_ingest=True,
                  disk_sidecar=True, debug_sync=debug_sync,
                  fault_plan=plan, io_backoff_s=0.0, **ecfg_kw),
        max_seqs=2)


def _drive(plan=None, *, debug_sync=True, max_new=3, scfg_kw=None,
           req_kw=None, ecfg_kw=None):
    """Run 3 requests through the batched scheduler; returns
    (finished+rejected requests, engine) with the store still open so the
    caller can leak-check before close()."""
    from repro.serving.scheduler import (ContinuousBatcher, Request,
                                         SchedulerCfg)
    cfg, params, prompts = _setup()
    eng = _engine(cfg, params, plan=plan, debug_sync=debug_sync,
                  **(ecfg_kw or {}))
    kw = dict(max_active=2, chunk=16, overlap_admission=True)
    kw.update(scfg_kw or {})
    b = ContinuousBatcher(cfg=SchedulerCfg(**kw), engine=eng)
    for i, p in enumerate(prompts):
        b.submit(Request(i, p, max_new=max_new, **((req_kw or {}).get(i, {}))))
    finished = b.run()
    return list(finished) + list(b.rejected), b, eng


def _assert_no_leaks(b, eng):
    assert sorted(eng._free) == list(range(eng.max_seqs))
    assert not eng.seqs
    assert all(not futs for futs in eng.store._ingest_futs.values())
    ps = eng.store.pool_stats()
    if ps.get("slots"):
        assert ps["free_slots"] == ps["slots"], ps
    if hasattr(eng.store, "prefix_stats"):
        # every seq is retired: no shared-arena chunk may still be
        # referenced (resident rows with zero refs are fine — cache)
        assert eng.store.prefix_stats().get("shared_refs", 0) == 0
    stats = b.stats()
    assert stats["requests_cancelled"] == float(b._requests_cancelled)
    assert stats["requests_rejected"] == float(b._requests_rejected)


_REF = {}


def _reference():
    if "out" not in _REF:
        reqs, b, eng = _drive(None)
        assert all(r.error is None and not r.degraded for r in reqs)
        _assert_no_leaks(b, eng)
        eng.store.close()
        _REF["out"] = {r.rid: list(r.out) for r in reqs}
    return _REF["out"]


# ---------------------------------------------------------------------------
# the chaos property
# ---------------------------------------------------------------------------

@pytest.mark.chaos
@settings(max_examples=5, deadline=None)
@given(hst.integers(min_value=0, max_value=31))
def test_chaos_fault_containment(seed):
    """Seeded fault schedules against the sanitizer engine: every request
    reaches a terminal state, clean non-degraded requests are
    token-identical to the fault-free reference, and nothing leaks."""
    ref = _reference()
    plan = FaultPlan.from_seed(seed, rate=0.04, horizon=300,
                               latency_s=1e-3)
    was_active = sanitizer.active()
    reqs, b, eng = _drive(plan)
    try:
        assert {r.rid for r in reqs} == set(ref)
        # a bitflip's victim row (event key[0]) marks that sequence
        # AFFECTED: replica flips on CRC-valid chunks recover exactly and
        # sidecar flips degrade visibly, but a flip on an append-dirtied
        # replica chunk is served unverified by design (INVARIANTS I6 —
        # the requant sweep revalidates it later), so only UNAFFECTED
        # sequences owe token-identity.  io_error/latency/exception never
        # silently perturb values.
        hit_rows = {ev.key[0] for ev in plan.fired_events()
                    if ev.kind == "bitflip" and ev.key is not None}
        for r in reqs:
            assert r.t_done is not None     # terminal, one way or another
            if r.error is None and not r.degraded and r.sid not in hit_rows:
                assert list(r.out) == ref[r.rid], \
                    (seed, r.rid, plan.fired_events())
        _assert_no_leaks(b, eng)
        fs = eng.fault_stats()
        # every fired io_error/exception left a counter or terminal-state
        # trace (latency is timing-only; a bitflip on a dirty chunk is
        # invisible until the requant sweep revalidates)
        value_faults = [e for e in plan.fired_events()
                        if e.kind in ("io_error", "exception")]
        if value_faults:
            assert (fs["io_retries"] + fs["checksum_failures"]
                    + fs["seqs_failed"] + eng.ingest_errors) > 0, \
                (seed, value_faults, fs)
    finally:
        eng.store.close()
    assert sanitizer.active() == was_active


# ---------------------------------------------------------------------------
# deterministic containment instances
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_replica_loss_recovers_token_identical():
    """Corrupting a prompt-span disk replica mid-stream triggers the
    checksum -> ChunkLostError -> recompute-from-prompt path; the decode
    stream of EVERY sequence (including the recovered one) stays
    token-identical to the fault-free run."""
    cfg, params, prompts = _setup()
    # dense selection so the corrupted chunk is fetched every round
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, min_seq_for_sparse=256))

    def run(corrupt):
        eng = _engine(cfg, params)
        toks = {}
        for p in prompts[:2]:
            sid, tok = eng.add_sequence(p)
            toks[sid] = tok
        out = {sid: [] for sid in toks}
        for rnd in range(4):
            if rnd == 1 and corrupt:
                st = eng.store
                for li in range(len(eng.attn_layers)):
                    st._disk[0, li, 0, 0].reshape(-1)[0] += np.float16(1.0)
                    st._sidecar_valid[0, li, 0] = False
                    st._host_k.pop((0, li, 0), None)
                    st._host_v.pop((0, li, 0), None)
                    st.tier[0, li, 0] = DISK
                    pool = st.pools[li] if st.use_pool else None
                    if pool is not None:
                        slot = pool.slot_of.pop((0, 0), None)
                        if slot is not None:
                            pool.free.append(slot)
            toks = eng.decode_round(toks)
            for sid, t in toks.items():
                out[sid].append(t)
        fs = eng.fault_stats()
        eng.store.close()
        return out, fs

    want, fs0 = run(corrupt=False)
    got, fs1 = run(corrupt=True)
    assert got == want
    assert fs0["chunks_recomputed"] == 0 and fs0["seqs_failed"] == 0
    assert fs1["chunks_recomputed"] >= 1, fs1
    assert fs1["seqs_failed"] == 0 and fs1["disk_lost"] == 0


@pytest.mark.chaos
def test_ingest_failure_contained_to_one_seq():
    """A failed cold-ingest future surfaces as ONE sequence's terminal
    state at its fence; the other live sequence's stream is untouched."""
    cfg, params, prompts = _setup()

    def run(poison):
        eng = _engine(cfg, params)
        sids = []
        toks = {}
        for p in prompts[:2]:
            sid, tok = eng.add_sequence(p)
            sids.append(sid)
            toks[sid] = tok
        out = {sid: [] for sid in sids}
        for rnd in range(3):
            if rnd == 1 and poison:
                f = Future()
                f.set_exception(RuntimeError("worker died mid-ingest"))
                with eng.store._futs_lock:
                    eng.store._ingest_futs[sids[0]].append(f)
            toks = eng.decode_round(toks)
            for sid, t in toks.items():
                out[sid].append(t)
        state = (dict(eng.failed), eng.seqs_failed, sorted(eng._free))
        for sid in list(toks):
            eng.release(sid)
        eng.store.close()
        return out, state

    want, _ = run(poison=False)
    got, (failed, n_failed, free_mid) = run(poison=True)
    sid0, sid1 = sorted(want)
    assert got[sid1] == want[sid1]            # survivor: token-identical
    assert got[sid0] == want[sid0][:1]        # failed after round 1
    assert sid0 in failed and "worker died" in failed[sid0]
    assert n_failed == 1
    assert sid0 in free_mid                   # slot recycled immediately


@pytest.mark.chaos
def test_release_survives_failed_ingest():
    """REGRESSION: release() used to call ingest_fence raw, so a failed
    write-behind ingest leaked the slot (the raise skipped clear_seq and
    the free-list append).  It must drain, count, and recycle."""
    cfg, params, prompts = _setup()
    eng = _engine(cfg, params)
    sid, _ = eng.add_sequence(prompts[0])
    f = Future()
    f.set_exception(RuntimeError("disk died"))
    with eng.store._futs_lock:
        eng.store._ingest_futs[sid].append(f)
    eng.release(sid)                          # must not raise
    assert eng.ingest_errors == 1
    assert sid in eng._free and sid not in eng.seqs
    sid2, _ = eng.add_sequence(prompts[1])    # slot is reusable
    eng.release(sid2)
    eng.store.close()


@pytest.mark.chaos
def test_failed_seq_releases_prefix_refcounts():
    """Containment must drop a failed sequence's shared-prefix arena
    references (I5 refcount rule survives the failure path): two
    admissions of the same prompt share arena chunks; failing one must
    decref only its holds, and releasing the other drains them to zero."""
    cfg, params, prompts = _setup()
    eng = _engine(cfg, params, prefix_cache=True, prefill_chunk_tokens=64)
    prompt = prompts[2]
    sid0, t0 = eng.add_sequence(prompt)
    sid1, t1 = eng.add_sequence(prompt)         # adopts by reference
    assert eng.store.prefix_stats()["shared_refs"] > 0
    f = Future()
    f.set_exception(RuntimeError("cold ingest died"))
    with eng.store._futs_lock:
        eng.store._ingest_futs[sid1].append(f)
    toks = eng.decode_round({sid0: t0, sid1: t1})
    assert sid1 not in toks and sid0 in toks    # contained to sid1
    eng.release(sid0)
    assert eng.store.prefix_stats()["shared_refs"] == 0
    assert sorted(eng._free) == list(range(eng.max_seqs))
    eng.store.close()


def _ledger_balanced(eng):
    """Shared traffic log == Σ live seq_logs + Σ retired_logs, key by key
    (docs/INVARIANTS.md I3 — degradation paths must keep billing exact)."""
    from collections import defaultdict
    want = defaultdict(float)
    for lg in list(eng.store.seq_logs.values()) + list(eng.store.retired_logs):
        for key, v in lg.bytes.items():
            want[key] += v
    got = eng.store.log.bytes
    assert set(got) == set(want)
    for key in want:
        assert got[key] == pytest.approx(want[key]), key


@pytest.mark.chaos
def test_pq_read_io_errors_degrade_bitwise_to_minmax():
    """Persistent ``pq_read`` io_errors exhaust the retry budget every
    round; ADC selection degrades to the min/max bounds path (ISSUE-10 /
    INVARIANTS I8) so the PQ engine's streams are token-identical to the
    min/max reference engine — selection is an estimator, a dead code
    plane never fails a request.  Degradations are billed ``abstract``,
    with the ledger exactly balanced and zero slot leaks."""
    ref = _reference()
    plan = FaultPlan(schedule={"pq_read": {i: "io_error"
                                           for i in range(4000)}})
    reqs, b, eng = _drive(plan, ecfg_kw={"pq_abstracts": True})
    try:
        assert {r.rid for r in reqs} == set(ref)
        for r in reqs:
            assert r.error is None and not r.degraded, (r.rid, r.error)
            assert list(r.out) == ref[r.rid], r.rid
        fs = eng.fault_stats()
        assert fs["pq_fallbacks"] > 0, fs
        # every degraded disk read was billed as a min/max ``abstract``
        # transfer, never ``pq_codes_read`` — the ledger shows the fault
        assert eng.store.log.total(kind="pq_codes_read") == 0.0
        _ledger_balanced(eng)
        _assert_no_leaks(b, eng)
    finally:
        eng.store.close()


@pytest.mark.chaos
def test_pq_read_bitflips_quarantined_no_leaks():
    """``pq_read`` bitflips corrupt stored code bytes; the CRC layer must
    quarantine each victim chunk (min/max serves it) without failing or
    degrading any request — PQ codes only steer selection, never values —
    and without leaking slots, futures, or ledger bytes."""
    plan = FaultPlan(schedule={"pq_read": {i: "bitflip"
                                           for i in range(0, 40, 2)}})
    reqs, b, eng = _drive(plan, ecfg_kw={"pq_abstracts": True})
    try:
        for r in reqs:
            assert r.t_done is not None
            assert r.error is None and not r.degraded, (r.rid, r.error)
        fired = [e for e in plan.fired_events() if e.kind == "bitflip"]
        assert fired                       # the schedule actually landed
        fs = eng.fault_stats()
        assert fs["checksum_failures"] > 0, fs
        assert fs["pq_fallbacks"] > 0, fs
        _ledger_balanced(eng)
        _assert_no_leaks(b, eng)
    finally:
        eng.store.close()


@pytest.mark.chaos
def test_deadline_cancels_queued_request():
    req_kw = {2: {"deadline_s": 1e-4}}
    reqs, b, eng = _drive(None, scfg_kw={"max_active": 1},
                          req_kw=req_kw)
    try:
        by_rid = {r.rid: r for r in reqs}
        assert "deadline" in (by_rid[2].error or "")
        assert by_rid[0].error is None and by_rid[1].error is None
        assert b._requests_cancelled == 1
        _assert_no_leaks(b, eng)
    finally:
        eng.store.close()


@pytest.mark.chaos
def test_bounded_queue_rejects_structured():
    from repro.serving.scheduler import (ContinuousBatcher, Request,
                                         SchedulerCfg)
    cfg, params, prompts = _setup()
    eng = _engine(cfg, params)
    b = ContinuousBatcher(
        cfg=SchedulerCfg(max_active=1, chunk=16, max_queue=1), engine=eng)
    oks = [b.submit(Request(i, p, max_new=2))
           for i, p in enumerate(prompts)]
    try:
        assert oks == [True, False, False]
        assert len(b.rejected) == 2 and b._requests_rejected == 2
        assert all("max_queue" in (r.error or "") for r in b.rejected)
        done = b.run()
        assert [r.rid for r in done] == [0] and done[0].error is None
        _assert_no_leaks(b, eng)
    finally:
        eng.store.close()
