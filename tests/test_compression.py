"""Transit-codec properties (paper §4.4 dynamic compression)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.compression import (codec_ratio, dequantize, dequantize_chunks,
                                    quantize, quantize_chunks,
                                    quantization_rmse)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["int8", "int4"]))
def test_roundtrip_error_bound(seed, codec):
    """Per-channel symmetric quantization error <= scale/2 elementwise."""
    rng = np.random.RandomState(seed)
    x = (rng.randn(2, 128, 32) * rng.uniform(0.1, 5)).astype(np.float32)
    q = quantize(jnp.asarray(x), codec, group=64)
    xq = np.asarray(dequantize(q, group=64, dtype=jnp.float32))
    qmax = 127.0 if codec == "int8" else 7.0
    scale = np.asarray(q.scale)                      # (2, 2, 32)
    bound = scale.repeat(64, axis=1)[:, :128] / 2 + 1e-6
    assert np.all(np.abs(xq - x) <= bound)


def test_int4_packing_halves_bytes(rng):
    x = jnp.asarray(rng.randn(2, 64, 64).astype(np.float32))
    q8 = quantize(x, "int8", group=64)
    q4 = quantize(x, "int4", group=64)
    assert q4.data.size * 2 == q8.data.size
    assert codec_ratio("int4") < codec_ratio("int8") < 1.0


def test_rmse_ordering(rng):
    x = rng.randn(4, 128, 64).astype(np.float32)
    assert quantization_rmse(x, "int8") < quantization_rmse(x, "int4") < 0.2


@pytest.mark.parametrize("codec", ["int4", "int8"])
def test_quantize_chunks_payload_matches_codec_ratio_exactly(rng, codec):
    """The transit payload of a chunk stack is EXACTLY chunk_bytes ×
    codec_ratio(codec, group=chunk) — the identity the store's byte
    ledger relies on."""
    n, c, H, hd = 5, 16, 2, 8
    k = rng.randn(n, c, H, hd).astype(np.float16)
    data, scale = quantize_chunks(k, codec)
    payload = data.nbytes + scale.nbytes
    fp16 = n * c * H * hd * 2
    assert payload == fp16 * codec_ratio(codec, group=c)
    # K+V per store chunk: both tensors scale identically
    assert codec_ratio(codec, group=c) == pytest.approx(
        {"int4": 0.25, "int8": 0.5}[codec] + 2.0 / c)


@pytest.mark.parametrize("codec", ["int4", "int8"])
def test_quantize_chunks_roundtrip_bound(rng, codec):
    """Chunk-grouped transit roundtrip obeys the scale/2 elementwise
    bound of symmetric quantization."""
    n, c, H, hd = 4, 32, 2, 8
    k = (rng.randn(n, c, H, hd) * rng.uniform(0.1, 4)).astype(np.float16)
    data, scale = quantize_chunks(k, codec)
    kq = dequantize_chunks(data, scale, codec, H, hd, dtype=np.float32)
    bound = scale.reshape(n, 1, H, hd) / 2 + 2e-3   # + fp16 storage noise
    assert np.all(np.abs(kq - k.astype(np.float32)) <= bound)
