"""Transit-codec properties (paper §4.4 dynamic compression)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.compression import (codec_ratio, dequantize, quantize,
                                    quantization_rmse)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["int8", "int4"]))
def test_roundtrip_error_bound(seed, codec):
    """Per-channel symmetric quantization error <= scale/2 elementwise."""
    rng = np.random.RandomState(seed)
    x = (rng.randn(2, 128, 32) * rng.uniform(0.1, 5)).astype(np.float32)
    q = quantize(jnp.asarray(x), codec, group=64)
    xq = np.asarray(dequantize(q, group=64, dtype=jnp.float32))
    qmax = 127.0 if codec == "int8" else 7.0
    scale = np.asarray(q.scale)                      # (2, 2, 32)
    bound = scale.repeat(64, axis=1)[:, :128] / 2 + 1e-6
    assert np.all(np.abs(xq - x) <= bound)


def test_int4_packing_halves_bytes(rng):
    x = jnp.asarray(rng.randn(2, 64, 64).astype(np.float32))
    q8 = quantize(x, "int8", group=64)
    q4 = quantize(x, "int4", group=64)
    assert q4.data.size * 2 == q8.data.size
    assert codec_ratio("int4") < codec_ratio("int8") < 1.0


def test_rmse_ordering(rng):
    x = rng.randn(4, 128, 64).astype(np.float32)
    assert quantization_rmse(x, "int8") < quantization_rmse(x, "int4") < 0.2
