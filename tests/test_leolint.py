"""leolint checker tests: each pass fires on its seeded fixture violation,
waivers suppress (and reason-less waivers are reported), and the merged
tree stays clean under ``--strict``."""

import os
import subprocess
import sys

import pytest

from repro.analysis import run_passes
from repro.analysis.__main__ import main as leolint_main

FIXTURES = os.path.join(os.path.dirname(__file__), "analysis_fixtures")
SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _fx(name):
    return os.path.join(FIXTURES, name)


def _live(findings, pass_id):
    return [f for f in findings if f.pass_id == pass_id and not f.waived]


# ----------------------------------------------------------------------
# locklint
# ----------------------------------------------------------------------
def test_locklint_flags_jit_and_dispatch_under_lock():
    findings, _ = run_passes([_fx("fixture_lock.py")], ["locklint"])
    live = _live(findings, "locklint")
    msgs = {f.line: f.message for f in live}
    assert any("JAX" in m or "jnp.stack" in m for m in msgs.values()), msgs
    assert any("jitted" in m and "_jitted_helper" in m
               for m in msgs.values()), msgs


def test_locklint_flags_fence_and_wait_under_lock():
    findings, _ = run_passes([_fx("fixture_lock.py")], ["locklint"])
    live = _live(findings, "locklint")
    assert any("ingest_fence" in f.message for f in live)
    assert any(".result()" in f.message for f in live)
    assert any(".block_until_ready()" in f.message for f in live)


def test_locklint_flags_indirect_dispatch_at_call_site():
    findings, _ = run_passes([_fx("fixture_lock.py")], ["locklint"])
    live = _live(findings, "locklint")
    hits = [f for f in live
            if "_helper" in f.message and "_jitted_helper" not in f.message]
    assert hits, [f.message for f in live]
    # anchored at the call site inside indirect_dispatch, not in _helper
    src = open(_fx("fixture_lock.py")).readlines()
    assert "self._helper()" in src[hits[0].line - 1]


def test_locklint_detects_lock_order_cycle():
    findings, _ = run_passes([_fx("fixture_lock.py")], ["locklint"])
    cyc = [f for f in _live(findings, "locklint")
           if "cycle" in f.message]
    assert cyc and "ABBA" in cyc[0].message


# ----------------------------------------------------------------------
# threadlint
# ----------------------------------------------------------------------
def test_threadlint_flags_worker_reaching_decode_only():
    findings, _ = run_passes([_fx("fixture_thread.py")], ["threadlint"])
    live = _live(findings, "threadlint")
    assert any("ingest_worker" in f.message and "scatter" in f.message
               for f in live), [f.message for f in live]
    # indirect path via helper is also caught, with the chain named
    assert any("indirect_worker" in f.message and "_place" in f.message
               for f in live)
    # executor.submit() first-arg entries count without any decorator
    assert any("_submitted" in f.message for f in live)
    # the clean any-thread read path stays quiet
    assert not any("clean_worker" in f.message for f in live)


# ----------------------------------------------------------------------
# billlint
# ----------------------------------------------------------------------
def test_billlint_flags_unbilled_write_and_read():
    findings, _ = run_passes([_fx("fixture_bill.py")], ["billlint"])
    live = _live(findings, "billlint")
    assert any("bad_write" in f.message for f in live)
    assert any("bad_sidecar_write" in f.message for f in live)
    assert any("bad_read" in f.message for f in live)
    assert not any("good_write" in f.message for f in live)
    assert not any("good_read" in f.message for f in live)


def test_billlint_flags_unknown_transfer_kind():
    findings, _ = run_passes([_fx("fixture_bill.py")], ["billlint"])
    live = _live(findings, "billlint")
    assert any("mystery_bytes" in f.message for f in live)


# ----------------------------------------------------------------------
# jitlint
# ----------------------------------------------------------------------
def test_jitlint_flags_impure_traced_functions():
    findings, _ = run_passes([_fx("fixture_jit.py")], ["jitlint"])
    live = _live(findings, "jitlint")
    msgs = [f.message for f in live]
    assert any("clock" in m or "time.perf_counter" in m for m in msgs), msgs
    assert any("RNG" in m for m in msgs)
    assert any("lock" in m for m in msgs)
    # mutation reached through a callee of the jitted root
    assert any("bump" in m or "self.calls" in m for m in msgs)
    # factory pattern: jax.jit(make_step(...)) roots the nested def
    assert any("step.count" in m for m in msgs)
    # the pure lambda root stays quiet
    assert not any("tanh" in m for m in msgs)


# ----------------------------------------------------------------------
# waivers
# ----------------------------------------------------------------------
def test_waiver_with_reason_suppresses_finding():
    findings, _ = run_passes([_fx("fixture_waive.py")], ["locklint"])
    waived = [f for f in findings if f.waived]
    assert waived and "decode thread only touches this path" \
        in waived[0].reason
    # the badly-waived line stays a LIVE finding...
    live = _live(findings, "locklint")
    assert len(live) == 1
    # ...and the reason-less pragma is itself reported
    assert any(f.pass_id == "waiver" and "reason" in f.message
               for f in findings)


def test_waiver_on_def_line_covers_whole_function():
    # locate fetch_chunks_pooled's span from the source instead of
    # hardcoding line numbers (the file grows across PRs)
    path = os.path.join(SRC, "repro", "serving", "offload.py")
    with open(path) as fh:
        src_lines = fh.readlines()
    start = next(i for i, l in enumerate(src_lines, 1)
                 if l.lstrip().startswith("def fetch_chunks_pooled"))
    end = next((i for i, l in enumerate(src_lines[start:], start + 1)
                if l.startswith("    def ")), len(src_lines))
    findings, _ = run_passes(
        [path, os.path.join(SRC, "repro", "core", "compression.py")],
        ["locklint"])
    pooled = [f for f in findings if start <= f.line < end]
    assert pooled and all(f.waived for f in pooled)


# ----------------------------------------------------------------------
# CLI / merged tree
# ----------------------------------------------------------------------
def test_cli_strict_clean_on_src():
    """Acceptance gate: the merged tree has zero unexplained findings."""
    assert leolint_main(["--strict", SRC]) == 0


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(
        "import threading\nimport jax.numpy as jnp\n"
        "class S:\n"
        "    def __init__(self):\n"
        "        self._lock = threading.Lock()\n"
        "    def f(self, x):\n"
        "        with self._lock:\n"
        "            return jnp.stack([x])\n")
    assert leolint_main([str(bad)]) == 1
    # subprocess entry (what CI runs) agrees
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    r = subprocess.run([sys.executable, "-m", "repro.analysis",
                        str(bad)], env=env, capture_output=True)
    assert r.returncode == 1


def test_unknown_pass_rejected():
    with pytest.raises(SystemExit):
        leolint_main(["--passes", "nosuchpass", SRC])
