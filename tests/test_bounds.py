"""Soundness of the LKA chunk bounds (paper §4.3) — property-based."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.abstracts import build_pyramid, chunk_minmax, update_pyramid
from repro.core.bounds import (chunk_bounds_gqa, chunk_bounds_gqa_matmul,
                               chunk_bounds_mla)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([8, 16, 32]),
       st.sampled_from([(4, 2), (8, 4), (6, 1)]))
def test_bounds_contain_true_scores(seed, chunk, heads):
    """For every chunk: lb <= (group-summed) q·k <= ub for all its tokens."""
    H, Hkv = heads
    rng = np.random.RandomState(seed)
    B, S, hd = 2, 4 * chunk, 16
    q = rng.randn(B, H, hd).astype(np.float32)
    k = (rng.randn(B, S, Hkv, hd) * rng.uniform(0.5, 3)).astype(np.float32)
    kmax, kmin = chunk_minmax(jnp.asarray(k), chunk)
    ub, lb = chunk_bounds_gqa(jnp.asarray(q), kmax, kmin)
    G = H // Hkv
    scores = np.einsum("bkgd,bskd->bkgs", q.reshape(B, Hkv, G, hd), k).sum(2)
    per_chunk = scores.reshape(B, Hkv, S // chunk, chunk)
    assert np.all(np.asarray(ub)[..., None] >= per_chunk - 1e-3)
    assert np.all(np.asarray(lb)[..., None] <= per_chunk + 1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_matmul_form_equals_corner_form(seed):
    rng = np.random.RandomState(seed)
    B, Hkv, G, hd, nc = 2, 3, 2, 8, 5
    q = jnp.asarray(rng.randn(B, Hkv * G, hd).astype(np.float32))
    km = jnp.asarray(rng.randn(B, nc, Hkv, hd).astype(np.float32))
    kn = km - jnp.asarray(np.abs(rng.randn(B, nc, Hkv, hd)).astype(np.float32))
    ub1, lb1 = chunk_bounds_gqa(q, km, kn)
    ub2, lb2 = chunk_bounds_gqa_matmul(q, km, kn)
    np.testing.assert_allclose(ub1, ub2, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(lb1, lb2, rtol=1e-5, atol=1e-4)


def test_mla_bounds_sound(rng):
    B, H, r, rr, S, chunk = 2, 4, 32, 8, 128, 16
    q_lat = rng.randn(B, H, r).astype(np.float32)
    q_rope = rng.randn(B, H, rr).astype(np.float32)
    ckv = rng.randn(B, S, r).astype(np.float32)
    krope = rng.randn(B, S, rr).astype(np.float32)
    cm, cn = chunk_minmax(jnp.asarray(ckv[:, :, None]), chunk)
    rm, rn = chunk_minmax(jnp.asarray(krope[:, :, None]), chunk)
    ub, lb = chunk_bounds_mla(jnp.asarray(q_lat), jnp.asarray(q_rope),
                              cm[:, :, 0], cn[:, :, 0], rm[:, :, 0], rn[:, :, 0])
    scores = (np.einsum("bhr,bsr->bhs", q_lat, ckv)
              + np.einsum("bhr,bsr->bhs", q_rope, krope)).sum(1)
    per_chunk = scores.reshape(B, S // chunk, chunk)
    assert np.all(np.asarray(ub)[..., None] >= per_chunk - 1e-3)
    assert np.all(np.asarray(lb)[..., None] <= per_chunk + 1e-3)


def test_pyramid_parents_contain_children(rng):
    B, S, Hkv, hd, chunk = 2, 256, 2, 8, 16
    k = jnp.asarray(rng.randn(B, S, Hkv, hd).astype(np.float32))
    pyr = build_pyramid(k, chunk, 3)
    assert pyr.levels == 3
    for lvl in range(pyr.levels - 1):
        km, kn = np.asarray(pyr.kmax[lvl]), np.asarray(pyr.kmin[lvl])
        pm, pn = np.asarray(pyr.kmax[lvl + 1]), np.asarray(pyr.kmin[lvl + 1])
        child_max = km.reshape(B, -1, 2, Hkv, hd).max(2)
        child_min = kn.reshape(B, -1, 2, Hkv, hd).min(2)
        np.testing.assert_allclose(pm, child_max)
        np.testing.assert_allclose(pn, child_min)


def test_incremental_update_matches_rebuild(rng):
    B, S, Hkv, hd, chunk = 1, 64, 2, 8, 8
    k = rng.randn(B, S, Hkv, hd).astype(np.float32)
    length = 37
    pyr = build_pyramid(jnp.asarray(k), chunk, 3, length=length)
    k_new = rng.randn(B, Hkv, hd).astype(np.float32)
    k2 = k.copy()
    k2[:, length] = k_new
    pyr_inc = update_pyramid(pyr, jnp.asarray(k_new), jnp.int32(length), chunk)
    pyr_re = build_pyramid(jnp.asarray(k2), chunk, 3, length=length + 1)
    for a, b in zip(pyr_inc.kmax, pyr_re.kmax):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    for a, b in zip(pyr_inc.kmin, pyr_re.kmin):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
