"""Seeded billlint violations (unbilled replica write / promotion)."""

import numpy as np

DEVICE, HOST, DISK = "device", "host", "disk"


class Log:
    def record(self, src, dst, kind, nbytes):
        pass


class BadBilling:
    def __init__(self):
        self._disk = np.zeros((4, 2, 8))
        self._disk_q = np.zeros((4, 2, 8), np.int8)
        self.log = Log()

    def _record(self, seq, src, dst, kind, nbytes):
        self.log.record(src, dst, kind, nbytes)

    def good_write(self, seq, rows):
        self._disk[seq] = rows
        self._record(seq, HOST, DISK, "kv_replica", rows.nbytes)

    def bad_write(self, seq, rows):
        self._disk[seq] = rows                # SEED: unbilled replica write

    def bad_sidecar_write(self, seq, packed):
        self._disk_q[seq] = packed            # SEED: unbilled sidecar write

    def good_read(self, seq):
        out = np.array(self._disk[seq])
        self._record(seq, DISK, HOST, "kv", out.nbytes)
        return out

    def bad_read(self, seq):
        return np.array(self._disk[seq])      # SEED: unbilled promotion

    def bad_kind(self, seq, rows):
        self._disk[seq] = rows
        self._record(seq, HOST, DISK, "mystery_bytes",  # SEED: unknown kind
                     rows.nbytes)
