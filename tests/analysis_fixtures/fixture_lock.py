"""Seeded locklint violations.  NOT collected by pytest (no test_ prefix);
test_leolint.py feeds this file to the analyzer by path and asserts each
seeded violation fires."""

import threading

import jax
import jax.numpy as jnp


@jax.jit
def _jitted_helper(x):
    return x * 2


class BadStore:
    def __init__(self):
        self._lock = threading.RLock()
        self._futs_lock = threading.Lock()
        self._futs = []

    def jit_under_lock(self, x):
        with self._lock:
            return jnp.stack([x, x])          # SEED: jax dispatch under lock

    def jitted_call_under_lock(self, x):
        with self._lock:
            return _jitted_helper(x)          # SEED: jitted callee under lock

    def sync_under_lock(self, x):
        with self._lock:
            x.block_until_ready()             # SEED: device sync under lock

    def ingest_fence(self, seq):
        for f in list(self._futs):
            f.result()

    def fence_under_lock(self):
        with self._lock:
            self.ingest_fence(0)              # SEED: fence under store lock

    def wait_under_lock(self, fut):
        with self._lock:
            return fut.result()               # SEED: future wait under lock

    def indirect_dispatch(self):
        with self._lock:
            self._helper()                    # SEED: callee dispatches JAX

    def _helper(self):
        return jnp.zeros((2,))

    def bad_order_a(self):
        with self._lock:
            with self._futs_lock:             # edge _lock -> _futs_lock
                pass

    def bad_order_b(self):
        with self._futs_lock:
            with self._lock:                  # SEED: reverse order (cycle)
                pass

    def clean_metadata_update(self, key, val):
        with self._lock:                      # fine: cheap host work only
            self._futs.append((key, val))
