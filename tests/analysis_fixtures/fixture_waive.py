"""Waiver-pragma behavior: a reasoned waiver suppresses; a reason-less
waiver is itself reported."""

import threading

import jax.numpy as jnp


class WaivedStore:
    def __init__(self):
        self._lock = threading.RLock()

    def waived_dispatch(self, x):
        with self._lock:
            # leolint: waive[locklint] reason=decode thread only touches this path; workers never contend for this fixture lock
            return jnp.stack([x, x])

    def badly_waived_dispatch(self, x):
        with self._lock:
            # leolint: waive[locklint]
            return jnp.stack([x, x, x])       # SEED: waive without reason=
