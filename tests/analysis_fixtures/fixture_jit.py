"""Seeded jitlint violations (impure traced functions)."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

_lock = threading.Lock()


@jax.jit
def clock_in_jit(x):
    t = time.perf_counter()                   # SEED: clock in traced fn
    return x * t


@jax.jit
def rng_in_jit(x):
    noise = np.random.randn(*x.shape)         # SEED: python RNG in traced fn
    return x + noise


@jax.jit
def lock_in_jit(x):
    with _lock:                               # SEED: lock inside traced fn
        return x * 2


class Stateful:
    def __init__(self):
        self.calls = 0

    def bump(self, x):
        self.calls += 1                       # SEED: attr mutation, reached
        return x + 1                          # from a jitted caller


_state = Stateful()


@jax.jit
def mutation_via_callee(x):
    return _state.bump(x)


def make_step(scale):
    def step(x):
        step.count = 1                        # SEED: factory-pattern root
        return x * scale
    return step


step_fn = jax.jit(make_step(2.0))

pure_fn = jax.jit(lambda x: jnp.tanh(x))      # fine: pure lambda root
