"""Seeded threadlint violations (wrong-thread pool mutation)."""

from concurrent.futures import ThreadPoolExecutor

from repro.serving.sanitizer import decode_thread_only, worker_thread


class Pool:
    @decode_thread_only
    def scatter(self, slots, kv):
        self.kv = kv

    def lookup(self, key):
        return None


class Store:
    def __init__(self):
        self.pool = Pool()
        self._exec = ThreadPoolExecutor(1)

    @worker_thread
    def ingest_worker(self, kv):
        self.pool.scatter([0], kv)            # SEED: worker -> decode-only

    @worker_thread
    def indirect_worker(self, kv):
        self._place(kv)                       # SEED: reaches scatter via helper

    def _place(self, kv):
        self.pool.scatter([1], kv)

    def kick(self, kv):
        self._exec.submit(self._submitted, kv)

    def _submitted(self, kv):                 # entry via .submit(...)
        self.pool.scatter([2], kv)            # SEED: submitted work -> decode-only

    @worker_thread
    def clean_worker(self, kv):
        return self.pool.lookup((0, 0))       # fine: any-thread read
