"""Batched tiered decoding: token-for-token parity with independent
single-sequence engines, exact shared-store accounting, scheduler drive,
device-pool delta uploads, real transit codec, async DTP pipelining."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import compression
from repro.models import lm
from repro.serving.engine import BatchedLeoAMEngine, EngineCfg, LeoAMEngine
from repro.serving.offload import DEVICE, DISK, HOST, TieredKVStore
from repro.serving.scheduler import ContinuousBatcher, Request, SchedulerCfg


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("longchat-7b-32k", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.4, early_rate=0.6,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _ecfg(**kw):
    return EngineCfg(max_len=128, selection="tree", **kw)


def test_batched_matches_independent_engines(setup, rng):
    """B ragged sequences decoded together == B single-sequence engines,
    token for token (padding + masking is FP-exact by construction)."""
    cfg, params = setup
    prompts = [rng.randint(2, cfg.vocab_size, n) for n in (48, 64, 57)]
    n_new = 6

    # independent single-sequence engines (each its own store)
    ref_streams = []
    for p in prompts:
        eng = LeoAMEngine(cfg, params, _ecfg())
        ref_streams.append(eng.generate(p, n_new))
        eng.store.close()

    # one batched engine, one shared store
    beng = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=len(prompts))
    toks = {}
    streams = {}
    for i, p in enumerate(prompts):
        sid, tok = beng.add_sequence(p)
        toks[sid] = tok
        streams[sid] = [tok]
    sids = sorted(streams)
    for _ in range(n_new - 1):
        toks = beng.decode_round(toks)
        for sid in sids:
            streams[sid].append(toks[sid])

    got = [streams[sid] for sid in sids]
    assert got == ref_streams, (got, ref_streams)
    beng.store.close()


def test_shared_log_is_sum_of_seq_logs(setup, rng):
    """Every byte in the shared TrafficLog is attributed to exactly one
    sequence: shared == sum over per-seq mirrors, key by key."""
    cfg, params = setup
    beng = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=3)
    toks = {}
    for n in (48, 64, 57):
        sid, tok = beng.add_sequence(rng.randint(2, cfg.vocab_size, n))
        toks[sid] = tok
    for _ in range(4):
        toks = beng.decode_round(toks)

    # retire one sequence: its log moves to retired_logs, invariant holds
    beng.release(sorted(toks)[0])
    logs = list(beng.store.seq_logs.values()) + beng.store.retired_logs
    assert len(beng.store.retired_logs) == 1
    keys = set(beng.store.log.bytes)
    for log in logs:
        keys |= set(log.bytes)
    for key in keys:
        total = sum(log.bytes.get(key, 0.0) for log in logs)
        assert beng.store.log.bytes.get(key, 0.0) == pytest.approx(total), key
        ops = sum(log.ops.get(key, 0) for log in logs)
        assert beng.store.log.ops.get(key, 0) == ops, key
    beng.store.close()


def test_scheduler_batched_mode_matches_legacy(setup, rng):
    """The batched-engine scheduler produces the same token streams as the
    legacy per-request-engine scheduler (continuous batching with staggered
    admission exercises ragged rounds)."""
    cfg, params = setup
    prompts = [rng.randint(2, cfg.vocab_size, n) for n in (48, 57, 64, 50)]
    scfg = SchedulerCfg(max_active=2, device_chunk_budget=64, chunk=16)

    legacy = ContinuousBatcher(
        lambda: LeoAMEngine(cfg, params, _ecfg()), scfg)
    for rid, p in enumerate(prompts):
        legacy.submit(Request(rid, p, max_new=4))
    ref = {r.rid: r.out for r in legacy.run()}

    beng = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=scfg.max_active)
    batched = ContinuousBatcher(cfg=scfg, engine=beng)
    for rid, p in enumerate(prompts):
        batched.submit(Request(rid, p, max_new=4))
    got = {r.rid: r.out for r in batched.run()}

    assert len(got) == len(prompts)
    assert got == ref, (got, ref)
    st = batched.stats()
    assert st["requests"] == len(prompts)
    assert st["throughput_tok_s"] > 0
    beng.store.close()


def test_single_engine_reprefill_resets(setup, rng):
    """The B=1 wrapper can be reused across prompts like the old
    per-request engine (prefill releases the previous sequence)."""
    cfg, params = setup
    eng = LeoAMEngine(cfg, params, _ecfg())
    a = eng.generate(rng.randint(2, cfg.vocab_size, 48), 3)
    b = eng.generate(rng.randint(2, cfg.vocab_size, 57), 3)
    assert len(a) == len(b) == 3
    assert eng.length == 57 + 2
    eng.store.close()


def test_store_coalesced_fetch_matches_sequential(rng):
    """fetch_chunks_batch returns the same payloads and bills the same
    bytes as per-seq fetch_chunks; disk I/O is one gather per layer."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    v = rng.randn(64, 2, 8).astype(np.float16)
    sel = {0: [0, 2, 3], 1: [1, 2]}

    seq_store = TieredKVStore(1, 4, 16, 2, 8, n_seqs=2, transit_codec=None)
    bat_store = TieredKVStore(1, 4, 16, 2, 8, n_seqs=2, transit_codec=None)
    for st in (seq_store, bat_store):
        for s in (0, 1):
            st.ingest(0, k, v, {c: DISK for c in range(4)}, seq=s)

    kg, vg, nsel = bat_store.fetch_chunks_batch(0, sel)
    assert list(nsel) == [3, 2]
    for i, (s, chunks) in enumerate(sel.items()):
        ks, vs = seq_store.fetch_chunks(0, chunks, seq=s)
        np.testing.assert_array_equal(kg[i, :len(chunks)], ks)
        np.testing.assert_array_equal(vg[i, :len(chunks)], vs)
    # padding rows are zero
    assert not np.any(kg[1, 2:])
    assert bat_store.log.bytes == seq_store.log.bytes
    # coalesced path: one disk->host op per chunk billed, but only ONE
    # python-level memmap gather was issued (smoke-check via ops parity)
    assert bat_store.log.ops == seq_store.log.ops
    seq_store.close()
    bat_store.close()


def _decode_streams(cfg, params, prompts, ecfg, n_new=5):
    """Token streams + the engine's store after n_new rounds."""
    eng = BatchedLeoAMEngine(cfg, params, ecfg, max_seqs=len(prompts))
    toks, streams = {}, {}
    for p in prompts:
        sid, tok = eng.add_sequence(p)
        toks[sid] = tok
        streams[sid] = [tok]
    per_round_h2d = []
    per_round_uploads = []
    for _ in range(n_new - 1):
        h0 = eng.store.log.total(kind="kv")
        h2d0 = eng.store.log.bytes.get((HOST, DEVICE, "kv"), 0.0)
        up0 = sum(p.uploads for p in eng.store.pools if p is not None)
        toks = eng.decode_round(toks)
        per_round_h2d.append(
            eng.store.log.bytes.get((HOST, DEVICE, "kv"), 0.0) - h2d0)
        per_round_uploads.append(
            sum(p.uploads for p in eng.store.pools if p is not None) - up0)
        del h0
        for sid in sorted(streams):
            streams[sid].append(toks[sid])
    out = [streams[s] for s in sorted(streams)]
    return out, eng, per_round_h2d, per_round_uploads


def test_pooled_pipelined_matches_pr1_synchronous(setup, rng):
    """The tentpole parity guarantee: the device-pool + async-DTP engine
    decodes token-identical to the PR-1 synchronous full-re-upload engine
    (speculation only moves residency; the pool holds exact fp16)."""
    cfg, params = setup
    prompts = [rng.randint(2, cfg.vocab_size, n) for n in (48, 64, 57)]
    legacy, e0, _, _ = _decode_streams(
        cfg, params, prompts, _ecfg(pooled=False, pipeline=False))
    pooled, e1, _, _ = _decode_streams(
        cfg, params, prompts, _ecfg(pooled=True, pipeline=False))
    piped, e2, _, _ = _decode_streams(
        cfg, params, prompts, _ecfg(pooled=True, pipeline=True))
    assert pooled == legacy, (pooled, legacy)
    assert piped == legacy, (piped, legacy)
    # the pipelined engine actually hit its speculative abstract cache
    assert e2.store.pool_stats()["hits"] > 0
    for e in (e0, e1, e2):
        e.store.close()


def test_h2d_bytes_shrink_to_promoted_delta(setup, rng):
    """Once chunks are pool-resident, per-round HOST→DEVICE "kv" bytes are
    exactly the newly-promoted delta — uploads × per-chunk transit bytes —
    and after warm-up that is well below the full working-set re-upload."""
    cfg, params = setup
    prompts = [rng.randint(2, cfg.vocab_size, n) for n in (48, 64)]
    _, eng, h2d, uploads = _decode_streams(
        cfg, params, prompts, _ecfg(pooled=True, pipeline=True), n_new=6)
    per_chunk = eng.store._transit_bytes()
    for round_bytes, round_up in zip(h2d, uploads):
        assert round_bytes == pytest.approx(round_up * per_chunk)
    # warm-up: later rounds upload (much) less than the first round, and
    # far less than re-uploading every selected chunk would cost
    sel_chunks = sum(s.stats[-1].fetched_chunks for s in eng.seqs.values())
    full_reupload = sel_chunks * per_chunk
    assert h2d[-1] < 0.5 * full_reupload
    assert sum(uploads[2:]) < sum(uploads[:2])
    eng.store.close()


def test_store_pooled_real_codec_values_and_ledger(rng):
    """Real transit codec: pooled uploads carry actual packed payloads —
    device values match fp16 within the symmetric-quantization bound and
    HOST→DEVICE bytes equal chunk_bytes × codec_ratio(codec, chunk)
    EXACTLY (θ=1), or full fp16 bytes (θ=0)."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    v = rng.randn(64, 2, 8).astype(np.float16)
    for theta, codec in ((1.0, "int4"), (1.0, "int8"), (0.0, "int4")):
        st = TieredKVStore(1, 4, 16, 2, 8, n_seqs=1, transit_codec=codec,
                           use_pool=True, real_codec=True)
        st.ingest(0, k, v, {c: HOST for c in range(4)})
        slots, nsel, fst = st.fetch_chunks_pooled(
            0, {0: [0, 1, 2, 3]}, theta=theta)
        assert list(nsel) == [4]
        billed = st.log.bytes[(HOST, DEVICE, "kv")]
        if theta == 1.0:
            assert fst.compressed == 4
            assert billed == 4 * st.chunk_bytes * compression.codec_ratio(
                codec, group=st.chunk)
        else:
            assert fst.compressed == 0
            assert billed == 4 * float(st.chunk_bytes)
        kv_slab = np.asarray(st.pools[0].kv)
        kd = kv_slab[np.asarray(slots)[0], 0]            # (4, 16, 2, 8)
        vd = kv_slab[np.asarray(slots)[0], 1]
        if theta == 0.0:
            np.testing.assert_array_equal(kd.reshape(64, 2, 8), k)
        else:
            _, scale_k = compression.quantize_chunks(
                k.reshape(4, 16, 2, 8), codec)
            bound = scale_k.reshape(4, 1, 2, 8) / 2 + 2e-3
            err = np.abs(kd.astype(np.float32)
                         - k.reshape(4, 16, 2, 8).astype(np.float32))
            assert np.all(err <= bound)
            assert np.any(vd != v.reshape(4, 16, 2, 8))  # really quantized
        # second fetch: fully resident, zero new bytes
        before = st.log.bytes[(HOST, DEVICE, "kv")]
        st.fetch_chunks_pooled(0, {0: [0, 1, 2, 3]}, theta=theta)
        assert st.log.bytes[(HOST, DEVICE, "kv")] == before
        st.close()


def test_real_codec_engine_ledger_is_exact(setup, rng):
    """Live real-codec engine: total H2D "kv" bytes == packed uploads ×
    packed bytes + plain uploads × fp16 bytes, exactly."""
    cfg, params = setup
    prompts = [rng.randint(2, cfg.vocab_size, 48)]
    _, eng, _, _ = _decode_streams(
        cfg, params, prompts, _ecfg(pooled=True, pipeline=True,
                                    real_codec=True), n_new=4)
    st = eng.store
    billed = st.log.bytes.get((HOST, DEVICE, "kv"), 0.0)
    expect = (st.codec_uploads * st._packed_bytes()
              + st.plain_uploads * float(st.chunk_bytes))
    assert billed == pytest.approx(expect, rel=0, abs=1e-6)
    assert st.codec_uploads + st.plain_uploads > 0
    st.close()


def test_stage_host_prevents_double_disk_read(rng):
    """Speculative staging re-tiers chunks HOST, so the true fetch finds
    the copy and bills NO second disk read — without that, DTP prefetch
    would double the disk ledger and hide nothing."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    st = TieredKVStore(1, 4, 16, 2, 8, n_seqs=1, transit_codec=None,
                       use_pool=True)
    st.ingest(0, k, k, {c: DISK for c in range(4)})
    assert st.stage_host(0, {0: [0, 1]}) == 2
    d0 = st.log.bytes[(DISK, HOST, "kv")]
    assert d0 == 2 * st.chunk_bytes
    _, _, fst = st.fetch_chunks_pooled(0, {0: [0, 1]})
    assert fst.disk_reads == 0
    assert st.log.bytes[(DISK, HOST, "kv")] == d0
    # staging twice is also idempotent
    assert st.stage_host(0, {0: [0, 1]}) == 0
    st.close()


def test_attend_masks_unwritten_tail_row(rng):
    """The grid mask is strict (`pos < length`): the not-yet-appended row
    at pos == length must not leak into attention — garbage there (e.g. a
    released sequence's stale KV in a reused slot) cannot change output."""
    import jax.numpy as jnp
    from repro.serving.engine import _attend_pooled
    B, nmax, c, Hkv, hd, H = 1, 1, 16, 2, 8, 4
    length = 9                                    # mid-chunk tail
    q = jnp.asarray(rng.randn(B, 1, H, hd).astype(np.float32))
    k_new = jnp.asarray(rng.randn(B, 1, Hkv, hd).astype(np.float32))
    v_new = jnp.asarray(rng.randn(B, 1, Hkv, hd).astype(np.float32))
    wo = jnp.asarray(rng.randn(H * hd, 16).astype(np.float32))
    slab = rng.randn(2, 2, c, Hkv, hd).astype(np.float16)
    slab[0, :, length:] = 0.0                     # rows past the cache tail
    slots = jnp.zeros((B, nmax), jnp.int32)
    ids = jnp.zeros((B, nmax), jnp.int32)
    lens = jnp.full((B,), length, jnp.int32)
    y0 = np.asarray(_attend_pooled(q, jnp.asarray(slab), slots, ids, lens,
                                   k_new, v_new, wo, attn_softcap=None))
    slab[0, :, length] = 999.0                    # garbage at pos == length
    y1 = np.asarray(_attend_pooled(q, jnp.asarray(slab), slots, ids, lens,
                                   k_new, v_new, wo, attn_softcap=None))
    np.testing.assert_array_equal(y0, y1)


def test_device_pool_lru_eviction_order(rng):
    """Pool eviction is LRU over (seq, chunk) with O(1) OrderedDict ops:
    touching a resident chunk saves it; the least-recently-used non-pinned
    resident is evicted and its tier label returns to host."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    st = TieredKVStore(1, 8, 16, 2, 8, n_seqs=1, transit_codec=None,
                       use_pool=True, pool_slots=3)
    st.ingest(0, np.tile(k, (2, 1, 1)), np.tile(k, (2, 1, 1)),
              {c: HOST for c in range(8)})
    st.fetch_chunks_pooled(0, {0: [0, 1, 2]})     # residency: 0, 1, 2
    st.fetch_chunks_pooled(0, {0: [0]})           # touch 0 → LRU is 1
    st.fetch_chunks_pooled(0, {0: [3]})           # evicts 1
    assert set(st.pools[0].slot_of) == {(0, 0), (0, 2), (0, 3)}
    assert st.tier[0, 0, 1] == HOST
    assert st.tier[0, 0, 3] == DEVICE
    st.fetch_chunks_pooled(0, {0: [4]})           # evicts 2 (next LRU)
    assert set(st.pools[0].slot_of) == {(0, 0), (0, 3), (0, 4)}
    st.close()


def test_legacy_device_lru_eviction_order(rng):
    """Legacy dict-tier eviction is LRU too (OrderedDict front pop — the
    old min-scan was O(n) per demotion)."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    st = TieredKVStore(1, 4, 16, 2, 8, n_seqs=1, transit_codec=None,
                       device_budget=3)
    st.ingest(0, k, k, {c: HOST for c in range(4)})
    st.fetch_chunks(0, [0, 1, 2])
    st.fetch_chunks(0, [0])                       # touch 0 → LRU is 1
    st.fetch_chunks(0, [3])                       # evicts 1, not 0
    assert set(st._dev_k) == {(0, 0, 0), (0, 0, 2), (0, 0, 3)}
    assert st.tier[0, 0, 1] == HOST
    st.close()


def test_read_abstracts_batch_matches_per_seq(rng):
    """Vectorized abstract stack: same values and same per-seq abstract
    billing as the per-sequence read_abstracts loop."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    a = TieredKVStore(1, 4, 16, 2, 8, n_seqs=2, transit_codec=None)
    b = TieredKVStore(1, 4, 16, 2, 8, n_seqs=2, transit_codec=None)
    for st in (a, b):
        st.ingest(0, k, k, {0: HOST, 1: DISK, 2: DISK, 3: HOST}, seq=0)
        st.ingest(0, k, k, {c: DISK for c in range(4)}, seq=1)
    sel = {0: [0, 1, 2, 3], 1: [1, 3]}
    km, kn, billed = a.read_abstracts_batch(0, sel)
    for i, (s, chunks) in enumerate(sel.items()):
        km_ref, kn_ref = b.read_abstracts(0, chunks, seq=s)
        np.testing.assert_array_equal(km[i, :len(chunks)], km_ref)
        np.testing.assert_array_equal(kn[i, :len(chunks)], kn_ref)
        assert billed[s] == b.seq_logs[s].total(src=DISK, kind="abstract")
    assert a.log.total(src=DISK, kind="abstract") == \
        b.log.total(src=DISK, kind="abstract")
    a.close()
    b.close()


def test_append_tokens_batch_matches_sequential(rng):
    """Batched decode-append == per-token appends: disk replica, abstract,
    host mirrors and byte billing all line up."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    a = TieredKVStore(1, 8, 16, 2, 8, n_seqs=2, transit_codec=None)
    b = TieredKVStore(1, 8, 16, 2, 8, n_seqs=2, transit_codec=None)
    for st in (a, b):
        for s in (0, 1):
            st.ingest(0, k, k, {c: HOST for c in range(4)}, seq=s)
    newk = rng.randn(2, 2, 8).astype(np.float16)
    newv = rng.randn(2, 2, 8).astype(np.float16)
    a.append_tokens_batch(0, np.array([64, 70]), newk, newv, seqs=[0, 1])
    b.append_token(0, 64, newk[0], newv[0], seq=0)
    b.append_token(0, 70, newk[1], newv[1], seq=1)
    np.testing.assert_array_equal(np.asarray(a._disk), np.asarray(b._disk))
    np.testing.assert_array_equal(a._abs_km, b._abs_km)
    np.testing.assert_array_equal(a._abs_kn, b._abs_kn)
    assert a.log.bytes == b.log.bytes
    a.close()
    b.close()


def test_store_device_budget_lru(rng):
    """Shared device budget: promotions past the cap demote LRU chunks to
    host for free (no extra traffic kinds, device residency bounded)."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    st = TieredKVStore(1, 4, 16, 2, 8, n_seqs=2, transit_codec=None,
                       device_budget=3)
    for s in (0, 1):
        st.ingest(0, k, k, {c: HOST for c in range(4)}, seq=s)
    st.fetch_chunks(0, [0, 1, 2], seq=0)
    assert len(st._dev_k) == 3
    st.fetch_chunks(0, [0, 1], seq=1)            # evicts seq 0's LRU chunks
    assert len(st._dev_k) == 3
    assert (1, 0, 0) in st._dev_k and (1, 0, 1) in st._dev_k
    # evicted chunks are host-resident again, re-fetch costs host->device only
    before = st.log.total(src=DISK, kind="kv")
    st.fetch_chunks(0, [0], seq=0)
    assert st.log.total(src=DISK, kind="kv") == before
    st.close()
