"""Batched tiered decoding: token-for-token parity with independent
single-sequence engines, exact shared-store accounting, scheduler drive."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serving.engine import BatchedLeoAMEngine, EngineCfg, LeoAMEngine
from repro.serving.offload import DISK, HOST, TieredKVStore
from repro.serving.scheduler import ContinuousBatcher, Request, SchedulerCfg


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("longchat-7b-32k", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.4, early_rate=0.6,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _ecfg(**kw):
    return EngineCfg(max_len=128, selection="tree", **kw)


def test_batched_matches_independent_engines(setup, rng):
    """B ragged sequences decoded together == B single-sequence engines,
    token for token (padding + masking is FP-exact by construction)."""
    cfg, params = setup
    prompts = [rng.randint(2, cfg.vocab_size, n) for n in (48, 64, 57)]
    n_new = 6

    # independent single-sequence engines (each its own store)
    ref_streams = []
    for p in prompts:
        eng = LeoAMEngine(cfg, params, _ecfg())
        ref_streams.append(eng.generate(p, n_new))
        eng.store.close()

    # one batched engine, one shared store
    beng = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=len(prompts))
    toks = {}
    streams = {}
    for i, p in enumerate(prompts):
        sid, tok = beng.add_sequence(p)
        toks[sid] = tok
        streams[sid] = [tok]
    sids = sorted(streams)
    for _ in range(n_new - 1):
        toks = beng.decode_round(toks)
        for sid in sids:
            streams[sid].append(toks[sid])

    got = [streams[sid] for sid in sids]
    assert got == ref_streams, (got, ref_streams)
    beng.store.close()


def test_shared_log_is_sum_of_seq_logs(setup, rng):
    """Every byte in the shared TrafficLog is attributed to exactly one
    sequence: shared == sum over per-seq mirrors, key by key."""
    cfg, params = setup
    beng = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=3)
    toks = {}
    for n in (48, 64, 57):
        sid, tok = beng.add_sequence(rng.randint(2, cfg.vocab_size, n))
        toks[sid] = tok
    for _ in range(4):
        toks = beng.decode_round(toks)

    # retire one sequence: its log moves to retired_logs, invariant holds
    beng.release(sorted(toks)[0])
    logs = list(beng.store.seq_logs.values()) + beng.store.retired_logs
    assert len(beng.store.retired_logs) == 1
    keys = set(beng.store.log.bytes)
    for log in logs:
        keys |= set(log.bytes)
    for key in keys:
        total = sum(log.bytes.get(key, 0.0) for log in logs)
        assert beng.store.log.bytes.get(key, 0.0) == pytest.approx(total), key
        ops = sum(log.ops.get(key, 0) for log in logs)
        assert beng.store.log.ops.get(key, 0) == ops, key
    beng.store.close()


def test_scheduler_batched_mode_matches_legacy(setup, rng):
    """The batched-engine scheduler produces the same token streams as the
    legacy per-request-engine scheduler (continuous batching with staggered
    admission exercises ragged rounds)."""
    cfg, params = setup
    prompts = [rng.randint(2, cfg.vocab_size, n) for n in (48, 57, 64, 50)]
    scfg = SchedulerCfg(max_active=2, device_chunk_budget=64, chunk=16)

    legacy = ContinuousBatcher(
        lambda: LeoAMEngine(cfg, params, _ecfg()), scfg)
    for rid, p in enumerate(prompts):
        legacy.submit(Request(rid, p, max_new=4))
    ref = {r.rid: r.out for r in legacy.run()}

    beng = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=scfg.max_active)
    batched = ContinuousBatcher(cfg=scfg, engine=beng)
    for rid, p in enumerate(prompts):
        batched.submit(Request(rid, p, max_new=4))
    got = {r.rid: r.out for r in batched.run()}

    assert len(got) == len(prompts)
    assert got == ref, (got, ref)
    st = batched.stats()
    assert st["requests"] == len(prompts)
    assert st["throughput_tok_s"] > 0
    beng.store.close()


def test_single_engine_reprefill_resets(setup, rng):
    """The B=1 wrapper can be reused across prompts like the old
    per-request engine (prefill releases the previous sequence)."""
    cfg, params = setup
    eng = LeoAMEngine(cfg, params, _ecfg())
    a = eng.generate(rng.randint(2, cfg.vocab_size, 48), 3)
    b = eng.generate(rng.randint(2, cfg.vocab_size, 57), 3)
    assert len(a) == len(b) == 3
    assert eng.length == 57 + 2
    eng.store.close()


def test_store_coalesced_fetch_matches_sequential(rng):
    """fetch_chunks_batch returns the same payloads and bills the same
    bytes as per-seq fetch_chunks; disk I/O is one gather per layer."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    v = rng.randn(64, 2, 8).astype(np.float16)
    sel = {0: [0, 2, 3], 1: [1, 2]}

    seq_store = TieredKVStore(1, 4, 16, 2, 8, n_seqs=2, transit_codec=None)
    bat_store = TieredKVStore(1, 4, 16, 2, 8, n_seqs=2, transit_codec=None)
    for st in (seq_store, bat_store):
        for s in (0, 1):
            st.ingest(0, k, v, {c: DISK for c in range(4)}, seq=s)

    kg, vg, nsel = bat_store.fetch_chunks_batch(0, sel)
    assert list(nsel) == [3, 2]
    for i, (s, chunks) in enumerate(sel.items()):
        ks, vs = seq_store.fetch_chunks(0, chunks, seq=s)
        np.testing.assert_array_equal(kg[i, :len(chunks)], ks)
        np.testing.assert_array_equal(vg[i, :len(chunks)], vs)
    # padding rows are zero
    assert not np.any(kg[1, 2:])
    assert bat_store.log.bytes == seq_store.log.bytes
    # coalesced path: one disk->host op per chunk billed, but only ONE
    # python-level memmap gather was issued (smoke-check via ops parity)
    assert bat_store.log.ops == seq_store.log.ops
    seq_store.close()
    bat_store.close()


def test_store_device_budget_lru(rng):
    """Shared device budget: promotions past the cap demote LRU chunks to
    host for free (no extra traffic kinds, device residency bounded)."""
    k = rng.randn(64, 2, 8).astype(np.float16)
    st = TieredKVStore(1, 4, 16, 2, 8, n_seqs=2, transit_codec=None,
                       device_budget=3)
    for s in (0, 1):
        st.ingest(0, k, k, {c: HOST for c in range(4)}, seq=s)
    st.fetch_chunks(0, [0, 1, 2], seq=0)
    assert len(st._dev_k) == 3
    st.fetch_chunks(0, [0, 1], seq=1)            # evicts seq 0's LRU chunks
    assert len(st._dev_k) == 3
    assert (1, 0, 0) in st._dev_k and (1, 0, 1) in st._dev_k
    # evicted chunks are host-resident again, re-fetch costs host->device only
    before = st.log.total(src=DISK, kind="kv")
    st.fetch_chunks(0, [0], seq=0)
    assert st.log.total(src=DISK, kind="kv") == before
    st.close()
