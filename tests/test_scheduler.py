"""Continuous-batching scheduler over live LeoAM engines."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serving.engine import EngineCfg, LeoAMEngine
from repro.serving.scheduler import ContinuousBatcher, Request, SchedulerCfg


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("longchat-7b-32k", smoke=True)
    cfg = dataclasses.replace(
        cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                       importance_rate=0.3,
                                       min_seq_for_sparse=32))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_continuous_batching_completes_all(setup):
    cfg, params = setup
    rng = np.random.RandomState(0)
    batcher = ContinuousBatcher(
        lambda: LeoAMEngine(cfg, params,
                            EngineCfg(max_len=128, selection="flat")),
        SchedulerCfg(max_active=2, device_chunk_budget=64, chunk=16))
    for rid in range(5):
        batcher.submit(Request(rid, rng.randint(2, cfg.vocab_size, 48),
                               max_new=4))
    done = batcher.run()
    assert len(done) == 5
    assert all(len(r.out) == 4 for r in done)
    st = batcher.stats()
    assert st["requests"] == 5
    assert st["throughput_tok_s"] > 0


def test_admission_respects_budget(setup):
    cfg, params = setup
    rng = np.random.RandomState(1)
    batcher = ContinuousBatcher(
        lambda: LeoAMEngine(cfg, params,
                            EngineCfg(max_len=128, selection="flat")),
        SchedulerCfg(max_active=8, device_chunk_budget=8, chunk=16))
    for rid in range(3):
        batcher.submit(Request(rid, rng.randint(2, cfg.vocab_size, 48),
                               max_new=2))
    batcher.step()
    # each request needs ceil((48+2)/16)=4 chunks; budget 8 -> at most 2 active
    assert len(batcher.active) <= 2
    done = batcher.run()
    assert len(done) == 3
