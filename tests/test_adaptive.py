"""IAKM selection: exactness, evaluation counts, pyramid recall (paper §4.2,
Fig. 10)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.abstracts import build_pyramid
from repro.core.adaptive import (flat_chunk_select, flat_select_chunks,
                                 pyramid_eval_count, pyramid_select_gqa,
                                 tree_select, tree_select_chunks)


def clustered_scores(rng, n, n_clusters=4, width=24):
    """Paper-like pattern: contiguous deserts + few dense islands."""
    s = np.abs(rng.randn(n)) * 0.01
    for _ in range(n_clusters):
        c = rng.randint(0, n - width)
        s[c:c + width] += np.abs(rng.randn(width)) * 3 + 1
    return s + rng.rand(n) * 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([16, 32, 64]))
def test_tree_select_exact_topk(seed, chunk):
    rng = np.random.RandomState(seed)
    n = 1024
    scores = clustered_scores(rng, n)
    budget = 96
    res = tree_select(scores, budget, chunk)
    assert len(res.selected) == budget
    np.testing.assert_allclose(np.sort(scores[res.selected]),
                               np.sort(scores)[-budget:])
    # full transfer precision by construction (exact-size segments)
    assert res.transfer_ratio >= 0.99


def test_tree_beats_token_level_on_clustered(rng):
    """The paper's core claim: far fewer evaluations than token-level, with
    exact selection (Fig. 10: 12 evals vs 32).  Budget is within the
    clustered important mass — the paper's operating regime (Insight 1)."""
    n, chunk = 2048, 64
    evals = []
    for seed in range(10):
        s = clustered_scores(np.random.RandomState(seed), n,
                             n_clusters=6, width=24)
        res = tree_select(s, budget=96, chunk=chunk)
        evals.append(res.evaluations)
    assert np.mean(evals) < 0.30 * n, np.mean(evals)   # >3.3x cheaper


def test_paper_fig10_example():
    """32 tokens, 8 initial chunks of 4, 6 important tokens: the tree should
    need far fewer than 32 token evaluations and reach transfer ratio 1.0
    (the fixed-chunk baseline gets 62.5%)."""
    scores = np.zeros(32)
    scores[[1, 9, 10, 28, 29, 30]] = [5, 7, 6, 9, 8, 7]   # clustered islands
    scores += np.arange(32) * 1e-9
    res = tree_select(scores, 6, 4)
    assert set(res.selected) == {1, 9, 10, 28, 29, 30}
    assert res.evaluations < 32
    assert res.transfer_ratio == 1.0
    flat = flat_chunk_select(scores, 6, 4)
    assert flat.transfer_ratio < 0.80


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([4, 16, 64]),
       st.booleans())
def test_tree_select_chunks_matches_token_path(seed, chunk, with_ties):
    """The engine's chunk-level fast path selects EXACTLY the chunks (and
    counts exactly the evaluations) of tree_select on the repeated
    per-token scores — including score ties across chunks."""
    rng = np.random.RandomState(seed)
    n_chunks = rng.randint(2, 24)
    length = rng.randint((n_chunks - 1) * chunk + 1, n_chunks * chunk + 1)
    if with_ties:   # few distinct values force heap tie-breaking
        chunk_ub = rng.choice([0.5, 1.0, 2.0], n_chunks).astype(np.float32)
    else:
        chunk_ub = rng.randn(n_chunks).astype(np.float32)
    budget = rng.randint(1, length + 1)
    per_chunk = chunk_ub / chunk
    per_tok = np.repeat(per_chunk, chunk)[:length]
    ref = tree_select(per_tok, budget, chunk)
    ref_chunks = sorted({int(t) // chunk for t in ref.selected})
    got_chunks, got_evals = tree_select_chunks(per_chunk, length, budget,
                                               chunk)
    assert got_chunks == ref_chunks
    assert got_evals == ref.evaluations


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([4, 16, 64]))
def test_flat_select_chunks_matches_token_path(seed, chunk):
    """Flat (Quest-like) fast path: same chunk set and evaluation count as
    the per-token baseline on continuous scores."""
    rng = np.random.RandomState(seed)
    n_chunks = rng.randint(2, 24)
    length = rng.randint((n_chunks - 1) * chunk + 1, n_chunks * chunk + 1)
    chunk_ub = rng.randn(n_chunks).astype(np.float32)
    budget = rng.randint(1, length + 1)
    per_chunk = chunk_ub / chunk
    per_tok = np.repeat(per_chunk, chunk)[:length]
    ref = flat_chunk_select(per_tok, budget, chunk)
    ref_chunks = sorted({int(t) // chunk for t in ref.selected})
    got_chunks, got_evals = flat_select_chunks(per_chunk, length, budget,
                                               chunk)
    assert got_chunks == ref_chunks
    assert got_evals == ref.evaluations


def test_pyramid_recall_on_planted(rng):
    """Device-side pyramid descent finds the planted hot chunks."""
    B, S, H, Hkv, hd, chunk = 2, 1024, 8, 4, 32, 32
    nc = S // chunk
    q = rng.randn(B, H, hd).astype(np.float32)
    k = rng.randn(B, S, Hkv, hd).astype(np.float32) * 0.1
    planted = {}
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd).mean(2)
    for b in range(B):
        for h in range(Hkv):
            cs = rng.choice(nc - 4, 3, replace=False) + 2
            planted[(b, h)] = set(int(c) for c in cs)
            for c in cs:
                k[b, c * chunk:(c + 1) * chunk, h] += (
                    2.5 * qg[b, h] / np.linalg.norm(qg[b, h]) * np.sqrt(hd))
    pyr = build_pyramid(jnp.asarray(k), chunk, 3)
    ids = np.asarray(pyramid_select_gqa(jnp.asarray(q), pyr, budget=8))
    for b in range(B):
        for h in range(Hkv):
            got = set(ids[b, h].tolist())
            missing = planted[(b, h)] - got
            assert not missing, (b, h, planted[(b, h)], got)


def test_pyramid_select_includes_sink_and_recent(rng):
    B, S, H, Hkv, hd, chunk = 1, 512, 4, 2, 16, 16
    k = rng.randn(B, S, Hkv, hd).astype(np.float32)
    q = rng.randn(B, H, hd).astype(np.float32)
    pyr = build_pyramid(jnp.asarray(k), chunk, 3)
    nc = S // chunk
    ids = np.asarray(pyramid_select_gqa(jnp.asarray(q), pyr, budget=6,
                                        sink_chunks=1, recent_chunks=2))
    for h in range(Hkv):
        got = set(ids[0, h].tolist())
        assert 0 in got
        assert {nc - 1, nc - 2} <= got


def test_pyramid_eval_count_scaling():
    """Adaptive evaluation count ~O(budget·log) vs O(nc) flat scoring."""
    nc0, budget = 8192, 128
    adaptive = pyramid_eval_count(4, nc0, budget)
    assert adaptive < 0.5 * nc0, adaptive
