"""LeoAM sparse decode attention: exactness at full budget, fidelity on
skewed caches, cross-shard partial-softmax combination."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abstracts import build_pyramid
from repro.core.sparse_attention import (Partials, _finish, dense_decode_gqa,
                                         dense_decode_mla, leoam_decode_shard,
                                         sparse_decode_gqa, sparse_decode_mla)


def make_cache(rng, B, S, Hkv, hd, scale=1.0):
    k = rng.randn(B, S, Hkv, hd).astype(np.float32) * scale
    v = rng.randn(B, S, Hkv, hd).astype(np.float32)
    return jnp.asarray(k), jnp.asarray(v)


def test_full_budget_equals_dense(rng):
    B, S, H, Hkv, hd, chunk = 2, 256, 8, 4, 32, 16
    k, v = make_cache(rng, B, S, Hkv, hd)
    q = jnp.asarray(rng.randn(B, H, hd).astype(np.float32) / np.sqrt(hd))
    nc = S // chunk
    ids = jnp.broadcast_to(jnp.arange(nc, dtype=jnp.int32), (B, Hkv, nc))
    ps = sparse_decode_gqa(q, k, v, ids, chunk, length=S)
    pd = dense_decode_gqa(q, k, v, length=S)
    np.testing.assert_allclose(_finish(ps), _finish(pd), rtol=1e-5, atol=1e-5)


def test_partial_length_masking(rng):
    B, S, H, Hkv, hd, chunk = 1, 128, 4, 2, 16, 16
    k, v = make_cache(rng, B, S, Hkv, hd)
    q = jnp.asarray(rng.randn(B, H, hd).astype(np.float32))
    length = 75  # mid-chunk
    nc = S // chunk
    ids = jnp.broadcast_to(jnp.arange(nc, dtype=jnp.int32), (B, Hkv, nc))
    ps = sparse_decode_gqa(q, k, v, ids, chunk, length=length)
    pd = dense_decode_gqa(q, k, v, length=length)
    np.testing.assert_allclose(_finish(ps), _finish(pd), rtol=1e-5, atol=1e-5)


def test_skewed_cache_fidelity(rng):
    """<=1% output error at 25% chunk budget when attention is concentrated."""
    B, S, H, Hkv, hd, chunk = 2, 512, 8, 4, 32, 16
    G = H // Hkv
    q = rng.randn(B, H, hd).astype(np.float32) / np.sqrt(hd)
    k = rng.randn(B, S, Hkv, hd).astype(np.float32) * 0.3
    v = rng.randn(B, S, Hkv, hd).astype(np.float32)
    qg = q.reshape(B, Hkv, G, hd).mean(2)
    for b in range(B):
        for h in range(Hkv):
            for c in np.random.RandomState(b * 7 + h).choice(S // chunk, 3,
                                                             replace=False):
                k[b, c * chunk:(c + 1) * chunk, h] += (
                    3.0 * qg[b, h] / np.linalg.norm(qg[b, h]) * np.sqrt(hd))
    kj, vj, qj = jnp.asarray(k), jnp.asarray(v), jnp.asarray(q)
    pyr = build_pyramid(kj, chunk, 3)
    ps = leoam_decode_shard(qj, kj, vj, pyr, chunk=chunk, budget=8, length=S)
    pd = dense_decode_gqa(qj, kj, vj, length=S)
    err = float(jnp.linalg.norm(_finish(ps) - _finish(pd))
                / jnp.linalg.norm(_finish(pd)))
    assert err < 0.01, err


def test_manual_shard_combine_equals_dense(rng):
    """Partial-softmax triples from sequence shards merge exactly."""
    B, S, H, Hkv, hd = 2, 128, 4, 2, 16
    k, v = make_cache(rng, B, S, Hkv, hd)
    q = jnp.asarray(rng.randn(B, H, hd).astype(np.float32))
    n_shards = 4
    Sl = S // n_shards
    parts = [dense_decode_gqa(q, k[:, i * Sl:(i + 1) * Sl],
                              v[:, i * Sl:(i + 1) * Sl], length=Sl)
             for i in range(n_shards)]
    gm = jnp.max(jnp.stack([p.m for p in parts]), 0)
    num = sum(p.num * jnp.exp(p.m - gm)[..., None] for p in parts)
    den = sum(p.den * jnp.exp(p.m - gm) for p in parts)
    merged = num / den[..., None]
    pd = dense_decode_gqa(q, k, v, length=S)
    np.testing.assert_allclose(merged, _finish(pd), rtol=1e-5, atol=1e-5)


def test_mla_latent_decode_matches_dense(rng):
    B, S, H, r, rr, chunk = 2, 256, 4, 32, 8, 16
    q_lat = jnp.asarray(rng.randn(B, H, r).astype(np.float32) / np.sqrt(r))
    q_rope = jnp.asarray(rng.randn(B, H, rr).astype(np.float32))
    ckv = jnp.asarray(rng.randn(B, S, r).astype(np.float32))
    krope = jnp.asarray(rng.randn(B, S, rr).astype(np.float32))
    nc = S // chunk
    ids = jnp.broadcast_to(jnp.arange(nc, dtype=jnp.int32), (B, 1, nc))
    ps = sparse_decode_mla(q_lat, q_rope, ckv, krope, ids, chunk, length=S)
    pd = dense_decode_mla(q_lat, q_rope, ckv, krope, length=S)
    np.testing.assert_allclose(_finish(ps), _finish(pd), rtol=1e-5, atol=1e-5)


def test_window_masking(rng):
    B, S, H, Hkv, hd, window = 1, 128, 4, 2, 16, 32
    k, v = make_cache(rng, B, S, Hkv, hd)
    q = jnp.asarray(rng.randn(B, H, hd).astype(np.float32))
    pw = dense_decode_gqa(q, k, v, length=S, window=window, query_pos=S)
    # reference: mask positions <= S - window
    km = np.asarray(k)
    km2 = km.copy()
    km2[:, : S - window] = 0
    scores = np.einsum("bkgd,bskd->bkgs",
                       np.asarray(q).reshape(B, Hkv, 2, hd), km)
    mask = np.arange(S) > (S - window)
    scores = np.where(mask[None, None, None], scores, -np.inf)
    e = np.exp(scores - scores.max(-1, keepdims=True))
    probs = e / e.sum(-1, keepdims=True)
    ref = np.einsum("bkgs,bskd->bkgd", probs,
                    np.asarray(v)).reshape(B, H, hd)
    np.testing.assert_allclose(_finish(pw), ref, rtol=1e-4, atol=1e-4)
