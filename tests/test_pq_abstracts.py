"""PQ abstract plane properties (ISSUE-10 tentpole gates).

Three layers of guarantees, matching how the plane is wired:

* **codec** — encode/decode round-trips are nearest-centroid optimal and
  deterministic, and the engine's ADC scoring path is EXACTLY the dot
  product against the decoded codes (the lookup table is an identity,
  not an approximation, given the codes);
* **selection quality** — on cluster-structured keys whose runs are
  shorter than a chunk (the regime the paper's min/max boxes handle
  worst), ADC ranking recovers the exact-attention top-k at least as
  well as the min/max upper bounds, seed for seed;
* **staleness / fallback (I8)** — an append invalidates the chunk's
  codes; until the requant sweep re-encodes them the store serves the
  chunk's min/max box BITWISE (same km/kn bytes the minmax path reads,
  so `np.where(valid, adc, ub)` reproduces the minmax score exactly),
  billing `abstract` instead of `pq_codes_read`; after the sweep the
  codes equal a fresh encode of the current replica.  At the engine
  level, a PQ store whose code reads *always* fail degrades to a token
  stream identical to the pq-disabled engine.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.pq import (adc_chunk_scores, pq_decode, pq_encode,
                              pq_train)
from repro.serving.faults import FaultPlan
from repro.serving.offload import DISK, TieredKVStore


def _clustered(rng, S, Hkv, hd, n_clusters=8, span=8, noise=0.25):
    """Keys with cluster runs of ``span`` tokens (temporal locality
    shorter than a chunk): min/max boxes over a chunk mix clusters and
    go loose, while per-token PQ codes stay tight."""
    centers = rng.randn(n_clusters, hd).astype(np.float32) * 2.0
    assign = rng.randint(0, n_clusters, (S // span, Hkv))
    assign = np.repeat(assign[:, None, :], span, 1).reshape(S, Hkv)
    return centers[assign] + rng.randn(S, Hkv, hd).astype(np.float32) * noise


def _trained(vecs, m, K):
    cb0 = np.zeros((m, K, vecs.shape[-1] // m), np.float32)
    cnt0 = np.zeros((m, K), np.float64)
    return pq_train(vecs, cb0, cnt0, iters=4)


# ---------------------------------------------------------------------------
# codec properties
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1, 2, 4]),
       st.sampled_from([4, 16, 64]))
def test_pq_roundtrip_nearest_centroid_optimal(seed, m, K):
    """decode(encode(x)) picks, per subspace, the closest centroid in the
    trained codebook — no other code could reconstruct better — and the
    encode is deterministic (byte-identical on a second call)."""
    rng = np.random.RandomState(seed)
    hd = 16
    vecs = _clustered(rng, 64, 2, hd).reshape(-1, hd)
    cb, cnt = _trained(vecs, m, K)
    # running counts carry the LAST Lloyd pass: each vector lands in
    # exactly one cluster per subspace
    assert cnt.sum() == vecs.shape[0] * m
    codes = pq_encode(vecs, cb)
    assert codes.dtype == np.uint8 and codes.shape == (vecs.shape[0], m)
    np.testing.assert_array_equal(codes, pq_encode(vecs, cb))
    dec = pq_decode(codes, cb)
    dsub = hd // m
    xs = vecs.reshape(-1, m, dsub)
    got = ((xs - dec.reshape(-1, m, dsub)) ** 2).sum(-1)       # (n, m)
    best = ((xs[:, :, None, :] - cb[None]) ** 2).sum(-1).min(-1)
    np.testing.assert_allclose(got, best, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_adc_scores_equal_decoded_dot(seed):
    """The engine's ADC path (LUT + code gather + subspace sum + live-token
    max) is exactly max over live tokens of q_sum · decode(codes)."""
    rng = np.random.RandomState(seed)
    B, Hkv, hd, nc, chunk, m, K = 2, 2, 16, 4, 8, 2, 16
    cb = rng.randn(m, K, hd // m).astype(np.float32)
    codes = rng.randint(0, K, (B, nc, chunk, Hkv, m)).astype(np.uint8)
    q = rng.randn(B, Hkv, hd).astype(np.float32)
    lengths = np.asarray([nc * chunk, nc * chunk - chunk // 2])
    got = adc_chunk_scores(q, cb, codes, lengths)
    dec = pq_decode(codes, cb)                        # (B,nc,chunk,Hkv,hd)
    tok = np.einsum("bhd,bcshd->bhcs", q, dec)
    pos = np.arange(nc * chunk).reshape(nc, chunk)
    tok = np.where(pos[None, None] < lengths[:, None, None, None],
                   tok, -np.inf)
    np.testing.assert_allclose(got, tok.max(-1), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# selection quality: overlap@k vs exact attention ranking
# ---------------------------------------------------------------------------

def _overlaps(seed, *, S=256, chunk=16, Hkv=2, hd=16, k=4, m=2, K=16,
              n_queries=8):
    """(minmax, pq) overlap@k against the exact chunk ranking, mirroring
    the engine's score convention (max over tokens, then kv heads),
    averaged over ``n_queries`` query draws on one key layout — a single
    overlap@4 sample only has five possible values, so the average is
    what makes a paired per-seed comparison meaningful."""
    rng = np.random.RandomState(seed)
    nc = S // chunk
    keys = _clustered(rng, S, Hkv, hd)
    kc = keys.reshape(nc, chunk, Hkv, hd)
    cb, _ = _trained(keys.reshape(-1, hd), m, K)
    codes = pq_encode(keys.reshape(-1, hd), cb) \
        .reshape(1, nc, chunk, Hkv, m)
    ov_mm = ov_pq = 0.0
    for _ in range(n_queries):
        q = rng.randn(Hkv, hd).astype(np.float32)
        tok = np.einsum("hd,shd->hs", q, keys)
        exact = tok.reshape(Hkv, nc, chunk).max(-1).max(0)
        ub = np.maximum(q[None] * kc.max(1), q[None] * kc.min(1)) \
            .sum(-1).max(-1)
        adc = adc_chunk_scores(q[None], cb, codes, np.asarray([S]))[0].max(0)
        te = set(np.argsort(-exact)[:k])
        ov = lambda s: len(set(np.argsort(-s)[:k]) & te) / k  # noqa: E731
        ov_mm += ov(ub)
        ov_pq += ov(adc)
    return ov_mm / n_queries, ov_pq / n_queries


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_selection_overlap_pq_gated_at_minmax(seed):
    """Seed for seed (paired: same keys, same queries), ADC top-k overlap
    with the exact ranking matches or beats the min/max upper-bound
    ranking on sub-chunk-clustered keys, up to ONE rank across the query
    panel (1/(k*n_queries)) — overlap@k is quantized, so a single
    boundary tie must not fail the property."""
    mm, pq = _overlaps(seed)
    assert pq >= mm - 1.0 / (4 * 8) - 1e-9, (seed, mm, pq)


def test_selection_overlap_pq_beats_minmax_on_average():
    """Across a fixed seed panel, ADC recovers clearly more of the exact
    top-k than min/max — the fig14 gate's offline form."""
    mm, pq = zip(*[_overlaps(s) for s in range(16)])
    assert np.mean(pq) >= np.mean(mm) + 0.1, (np.mean(mm), np.mean(pq))
    assert np.mean(pq) >= 0.6


# ---------------------------------------------------------------------------
# staleness / fallback through the store (I8)
# ---------------------------------------------------------------------------

def _pq_store(**kw):
    kw.setdefault("transit_codec", None)
    return TieredKVStore(1, 4, 8, 2, 16, n_seqs=1, abstract_kind="pq", **kw)


def _ub_scores(q, km, kn):
    """The engine's bounds-matmul score, max over kv heads — np mirror."""
    return np.maximum(q[None] * km, q[None] * kn).sum(-1).max(-1)


def test_append_invalidates_then_reencodes_bitwise():
    rng = np.random.RandomState(3)
    st_ = _pq_store()
    try:
        S, Hkv, hd = 32, 2, 16
        k = rng.randn(S, Hkv, hd).astype(np.float32)
        v = rng.randn(S, Hkv, hd).astype(np.float32)
        st_.ingest(0, k, v)
        st_.demote(0, range(4), to=DISK)
        km0, kn0, codes0, valid0, cb0, billed0 = \
            st_.read_abstracts_pq_batch(0, {0: [0, 1, 2, 3]})
        assert valid0.all()
        assert billed0[0] == 4 * st_.pq_bytes
        # codes on disk are a fresh encode of the replica bytes
        rep = np.asarray(st_._disk[0, 0, :, 0], np.float32)  # (4,chunk,Hkv,hd)
        np.testing.assert_array_equal(
            codes0[0], pq_encode(rep.reshape(-1, hd), cb0)
            .reshape(4, st_.chunk, Hkv, st_.pq_m))

        # one decode append lands in chunk 1 -> its codes go stale
        st_.append_token(0, 8, k[8] + 1.0, v[8])
        km1, kn1, codes1, valid1, cb1, billed1 = \
            st_.read_abstracts_pq_batch(0, {0: [0, 1, 2, 3]})
        assert list(valid1[0]) == [True, False, True, True]
        assert billed1[0] == 3 * st_.pq_bytes + st_.abstract_bytes
        # the dirty chunk's km/kn are byte-identical to the minmax path,
        # so the engine's np.where merge reproduces the minmax score
        km_mm, kn_mm, _ = st_.read_abstracts_batch(0, {0: [0, 1, 2, 3]})
        np.testing.assert_array_equal(km1, km_mm)
        np.testing.assert_array_equal(kn1, kn_mm)
        q = rng.randn(Hkv, hd).astype(np.float32)
        adc = adc_chunk_scores(q[None], cb1, codes1,
                               np.asarray([32]))[0].max(0)
        merged = np.where(valid1[0], adc, _ub_scores(q, km1[0], kn1[0]))
        assert merged[1] == _ub_scores(q, km_mm[0], kn_mm[0])[1]

        # two quiet sweep rounds re-encode the chunk off the CURRENT bytes
        assert st_.requant_sweep() == 0      # registered this round: skip
        assert st_.requant_sweep() == 1
        km2, kn2, codes2, valid2, cb2, _ = \
            st_.read_abstracts_pq_batch(0, {0: [0, 1, 2, 3]})
        assert valid2.all() and st_.pq_reencodes == 1
        rep1 = np.asarray(st_._disk[0, 0, 1, 0], np.float32)
        np.testing.assert_array_equal(
            codes2[0, 1], pq_encode(rep1.reshape(-1, hd), cb2)
            .reshape(st_.chunk, Hkv, st_.pq_m))
        # ledger knows both planes: codebook + 4 ingests + 1 re-encode
        wrote = st_.log.total(kind="pq_codes_write")
        assert wrote == 5 * st_.pq_bytes + 4.0 * st_.pq_m * \
            st_.pq_centroids * (st_.head_dim // st_.pq_m)
    finally:
        st_.close()


def test_pq_read_faults_degrade_to_minmax_billing():
    """Persistent pq_read io_errors exhaust the retry budget and the whole
    gather serves min/max boxes — valid all-False, `abstract` billing,
    pq_fallbacks counted, and no error escapes the read."""
    plan = FaultPlan(schedule={
        "pq_read": {i: "io_error" for i in range(64)}})
    st_ = _pq_store(faults=plan, io_retries=2, io_backoff_s=0.0)
    try:
        rng = np.random.RandomState(5)
        k = rng.randn(32, 2, 16).astype(np.float32)
        st_.ingest(0, k, k)
        st_.demote(0, range(4), to=DISK)
        km, kn, codes, valid, cb, billed = \
            st_.read_abstracts_pq_batch(0, {0: [0, 1, 2, 3]})
        assert not valid.any() and not codes.any()
        assert billed[0] == 4 * st_.abstract_bytes
        assert st_.fault_counters["pq_fallbacks"] == 4
        km_mm, kn_mm, _ = st_.read_abstracts_batch(0, {0: [0, 1, 2, 3]})
        np.testing.assert_array_equal(km, km_mm)
        np.testing.assert_array_equal(kn, kn_mm)
    finally:
        st_.close()


def test_pq_bitflip_caught_by_crc_and_requeued():
    """A flipped code byte fails CRC: the chunk quarantines (min/max
    serves), the sweep re-encodes it, and the next read is valid again."""
    plan = FaultPlan(schedule={"pq_read": {0: "bitflip"}})
    st_ = _pq_store(faults=plan, io_backoff_s=0.0)
    try:
        rng = np.random.RandomState(6)
        k = rng.randn(32, 2, 16).astype(np.float32)
        st_.ingest(0, k, k)
        _, _, _, valid, _, _ = st_.read_abstracts_pq_batch(0, {0: [0, 1]})
        assert list(valid[0]) == [False, True]
        assert st_.fault_counters["checksum_failures"] == 1
        assert st_.fault_counters["pq_fallbacks"] == 1
        st_.requant_sweep()
        st_.requant_sweep()
        _, _, _, valid2, _, _ = st_.read_abstracts_pq_batch(0, {0: [0, 1]})
        assert valid2.all() and st_.pq_reencodes == 1
    finally:
        st_.close()


# ---------------------------------------------------------------------------
# engine token identity (config gate + degraded-PQ equivalence)
# ---------------------------------------------------------------------------

_SETUP = {}


def _setup():
    if not _SETUP:
        import dataclasses

        import jax
        from repro.configs import get_config
        from repro.models import lm
        cfg = get_config("longchat-7b-32k", smoke=True)
        cfg = dataclasses.replace(
            cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                           importance_rate=0.4,
                                           early_rate=0.6,
                                           min_seq_for_sparse=32))
        _SETUP["cfg"] = cfg
        _SETUP["params"] = lm.init(cfg, jax.random.PRNGKey(1))
        rng = np.random.RandomState(11)
        _SETUP["prompts"] = [rng.randint(2, cfg.vocab_size, n)
                             for n in (48, 57)]
    return _SETUP["cfg"], _SETUP["params"], _SETUP["prompts"]


def _run_engine(pq, plan=None, rounds=4):
    from repro.serving.engine import BatchedLeoAMEngine, EngineCfg
    cfg, params, prompts = _setup()
    eng = BatchedLeoAMEngine(
        cfg, params,
        EngineCfg(max_len=128, selection="tree", disk_sidecar=False,
                  pq_abstracts=pq, fault_plan=plan, io_backoff_s=0.0),
        max_seqs=2)
    toks = {}
    for p in prompts:
        sid, tok = eng.add_sequence(p)
        toks[sid] = tok
    out = {sid: [] for sid in toks}
    for _ in range(rounds):
        toks = eng.decode_round(toks)
        for sid, t in toks.items():
            out[sid].append(t)
    fs = eng.fault_stats()
    store = eng.store
    pq_billed = store.log.total(kind="pq_codes_read") \
        + store.log.total(kind="pq_codes_write")
    store.close()
    return out, fs, pq_billed


def test_engine_pq_disabled_is_pure_minmax():
    """Config gate: pq_abstracts=False builds a minmax-only store — no PQ
    arrays, no PQ billing kinds, and the run is deterministic."""
    out0, _, billed0 = _run_engine(pq=False)
    out1, _, billed1 = _run_engine(pq=False)
    assert out0 == out1
    assert billed0 == billed1 == 0.0


def test_engine_degraded_pq_token_identical_to_minmax():
    """With EVERY pq_read failing persistently, the PQ engine's selection
    degrades chunk-for-chunk to the bitwise min/max score — the token
    streams match the pq-disabled engine exactly."""
    ref, _, _ = _run_engine(pq=False)
    plan = FaultPlan(schedule={
        "pq_read": {i: "io_error" for i in range(100_000)}})
    got, fs, billed = _run_engine(pq=True, plan=plan)
    assert got == ref
    assert fs["pq_fallbacks"] > 0 and fs["io_retries"] > 0
    assert billed > 0            # ingest still wrote codes + codebook


@pytest.mark.slow
def test_engine_pq_enabled_runs_and_reencodes():
    """PQ-on happy path: codes serve (or re-encode after appends) and the
    run completes with PQ write/read billing in the ledger."""
    out, fs, billed = _run_engine(pq=True, rounds=6)
    assert all(len(v) == 6 for v in out.values())
    assert fs["pq_fallbacks"] == 0
    assert billed > 0
