"""DTP (paper §4.4): θ-balance solver + three-tier pipeline timeline."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pipeline import (LayerCost, TierBW, optimal_theta, schedule,
                                 transfer_time)


@settings(max_examples=50, deadline=None)
@given(st.floats(1e6, 1e9), st.floats(1e9, 5e10), st.floats(0.1, 0.9),
       st.floats(0.0, 0.05), st.floats(1e-4, 0.05))
def test_theta_balances_or_clamps(D, B, delta, T0, Tc):
    kappa = 1.0 / 80e9
    th = optimal_theta(D, B, delta, T0, Tc, kappa)
    assert 0.0 <= th <= 1.0
    lhs = T0 + transfer_time(D, th, delta, B)
    rhs = Tc + kappa * D * th
    if 0.0 < th < 1.0:                     # interior => exact balance
        assert abs(lhs - rhs) < 1e-6 * max(1.0, rhs)
    elif th == 0.0:                        # no compression needed
        assert T0 + D / B <= Tc + 1e-9
    else:                                  # even full compression can't hide
        assert lhs >= rhs - 1e-9


def test_theta_monotone_in_transfer_size():
    ths = [optimal_theta(D, 16e9, 0.28, 0.002, 0.003, 1 / 80e9)
           for D in (1e6, 1e7, 1e8, 1e9)]
    assert all(a <= b + 1e-12 for a, b in zip(ths, ths[1:]))


def _layers(n=8):
    return [LayerCost(compute=0.003, eval_cpu=0.0005, abstract_bytes=2e6,
                      kv_bytes_cpu=3e7, kv_bytes_disk=1e7)] * n


def test_pipeline_strictly_helps():
    bw = TierBW()
    serial = schedule(_layers(), bw, pipelined=False).makespan
    pipe = schedule(_layers(), bw, pipelined=True,
                    dynamic_compression=False).makespan
    dyn = schedule(_layers(), bw, pipelined=True,
                   dynamic_compression=True).makespan
    assert dyn < pipe < serial
    assert dyn < 0.6 * serial              # paper-scale improvement


def test_pipeline_gpu_idle_reduced():
    bw = TierBW()
    pipe = schedule(_layers(), bw, pipelined=True, dynamic_compression=False)
    dyn = schedule(_layers(), bw, pipelined=True, dynamic_compression=True)
    assert dyn.gpu_idle <= pipe.gpu_idle + 1e-9
    assert all(0.0 <= t <= 1.0 for t in dyn.thetas)


def test_compute_bound_pipeline_has_no_bubble():
    """When transfers are tiny, makespan ~= sum of compute."""
    layers = [LayerCost(compute=0.01, eval_cpu=1e-5, abstract_bytes=1e3,
                        kv_bytes_cpu=1e4, kv_bytes_disk=0.0)] * 4
    tl = schedule(layers, TierBW(), pipelined=True, dynamic_compression=True)
    assert tl.makespan < 0.0401 * 1.1
