"""Overload robustness: preemption transparency, watermark policy, and
the trace-driven load harness.

The I7 contract (docs/INVARIANTS.md):

- **preemption transparency** — suspending a sequence (whole working set
  demoted to the disk replica, slot parked) and resuming it later yields
  a token stream identical to a never-preempted run, for ANY seeded
  interleaving of suspend/resume/decode across the batch;
- **no starvation** — a preempted request's deadline clock pauses while
  swapped out, aging lets it out-rank sustained-yellow victims, and the
  scheduler force-resumes when nothing else can make progress;
- **terminal accounting** — every submitted request lands in exactly one
  of {completed, shed, failed}; red-pressure shedding is structured
  (:class:`RejectedOverload`), never silent.

The chaos case combines preemption with seeded disk faults under the
runtime sync-sanitizer (the dedicated CI job runs ``-m chaos``).
"""

import dataclasses
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as hst

from repro.serving import sanitizer
from repro.serving.faults import FaultPlan, RejectedOverload
from repro.serving.trace import Arrival, TraceCfg, gen_trace

_SETUP = {}


def _setup():
    if not _SETUP:
        import jax
        from repro.configs import get_config
        from repro.models import lm
        cfg = get_config("longchat-7b-32k", smoke=True)
        cfg = dataclasses.replace(
            cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                           importance_rate=0.4,
                                           early_rate=0.6,
                                           min_seq_for_sparse=32))
        _SETUP["cfg"] = cfg
        _SETUP["params"] = lm.init(cfg, jax.random.PRNGKey(1))
        rng = np.random.RandomState(11)
        _SETUP["prompts"] = [rng.randint(2, cfg.vocab_size, n)
                             for n in (48, 57, 64)]
    return _SETUP["cfg"], _SETUP["params"], _SETUP["prompts"]


def _engine(cfg, params, *, plan=None, max_seqs=2, **ecfg_kw):
    from repro.serving.engine import BatchedLeoAMEngine, EngineCfg
    return BatchedLeoAMEngine(
        cfg, params,
        EngineCfg(max_len=128, selection="tree", overlap_ingest=True,
                  disk_sidecar=True, debug_sync=True, fault_plan=plan,
                  io_backoff_s=0.0, **ecfg_kw),
        max_seqs=max_seqs)


def _assert_engine_clean(eng):
    """Post-release leak audit: slots, futures, pool, swap ledger."""
    assert sorted(eng._free) == list(range(eng.max_seqs))
    assert not eng.seqs and not eng.suspended
    assert not eng.store._swapped
    assert all(not futs for futs in eng.store._ingest_futs.values())
    ps = eng.store.pool_stats()
    if ps.get("slots"):
        assert ps["free_slots"] == ps["slots"], ps
    if hasattr(eng.store, "prefix_stats"):
        assert eng.store.prefix_stats().get("shared_refs", 0) == 0


# ---------------------------------------------------------------------------
# trace generator
# ---------------------------------------------------------------------------

def test_trace_deterministic_and_bounded():
    cfg = TraceCfg(n_requests=48, scenario="mixed", min_prompt=32,
                   max_prompt=512, priorities=(0, 1), deadline_s=9.0)
    a = gen_trace(cfg, seed=7)
    b = gen_trace(cfg, seed=7)
    assert a == b                       # same (cfg, seed) -> same trace
    assert a != gen_trace(cfg, seed=8)
    assert len(a) == 48
    ts = [x.t for x in a]
    assert ts == sorted(ts) and ts[0] > 0.0
    assert all(32 <= x.prompt_len <= 512 for x in a)
    assert all(x.priority in (0, 1) for x in a)
    assert all(x.deadline_s == 9.0 for x in a)


def test_trace_scenarios_shape_lengths():
    lo, hi = 64, 1024
    mk = lambda sc: gen_trace(TraceCfg(n_requests=64, scenario=sc,
                                       min_prompt=lo, max_prompt=hi),
                              seed=3)
    chat = [a.prompt_len for a in mk("chat")]
    doc = [a.prompt_len for a in mk("longdoc")]
    assert max(chat) <= hi // 4         # chat stays in the bottom band
    assert min(doc) >= hi // 2          # longdoc stays in the top band
    # zipfian: the modal chat length is the short end of its band
    assert sorted(chat)[len(chat) // 2] < hi // 8


def test_trace_cfg_validation():
    with pytest.raises(ValueError):
        TraceCfg(scenario="video")
    with pytest.raises(ValueError):
        TraceCfg(zipf_a=1.0)
    with pytest.raises(ValueError):
        TraceCfg(min_prompt=64, max_prompt=32)


def test_trace_burst_state_raises_local_rate():
    """The MMPP burst state must actually change local arrival density:
    with a hot burst rate the densest observed window beats the calm
    rate's expectation by a wide margin."""
    cfg = TraceCfg(n_requests=200, base_rate=2.0, burst_rate=64.0,
                   calm_dwell_s=1.0, burst_dwell_s=1.0)
    ts = [a.t for a in gen_trace(cfg, seed=1)]
    gaps = np.diff(ts)
    win = 8
    dens = [win / (ts[i + win] - ts[i]) for i in range(len(ts) - win)]
    assert max(dens) > 4 * cfg.base_rate
    assert np.median(gaps) < 1.0 / cfg.base_rate


# ---------------------------------------------------------------------------
# pressure monitor (duck-typed engine: no model needed)
# ---------------------------------------------------------------------------

class _FakeStore:
    def __init__(self, host=0, root=None):
        self._host = host
        self._root = root

    def host_bytes(self):
        return self._host


class _FakeEngine:
    def __init__(self, free=8, slots=8, host=0):
        self._free, self._slots = free, slots
        self.store = _FakeStore(host)

    def pool_stats(self):
        return {"slots": self._slots, "free_slots": self._free,
                "hits": 0, "misses": 0, "hit_rate": 0.0, "resident": 0}


def test_monitor_green_by_default():
    from repro.serving.overload import GREEN, PressureMonitor, WatermarkCfg
    mon = PressureMonitor(_FakeEngine(), WatermarkCfg(),
                          disk_free_fn=lambda: 1 << 40)
    state, reasons = mon.sample(queue_depth=0)
    assert state == GREEN and not reasons
    assert mon.state_counts[GREEN] == 1


def test_monitor_watermarks_per_signal():
    from repro.serving.overload import (RED, YELLOW, PressureMonitor,
                                        WatermarkCfg)
    cfg = WatermarkCfg(pool_free_yellow=0.5, pool_free_red=0.125,
                       host_bytes_yellow=100, host_bytes_red=1000,
                       disk_free_yellow=1 << 20, disk_free_red=1 << 10,
                       queue_yellow=4, queue_red=16)
    big = 1 << 40
    mk = lambda eng, disk=big: PressureMonitor(eng, cfg,
                                               disk_free_fn=lambda: disk)
    assert mk(_FakeEngine(free=3, slots=8)).sample(0) == (YELLOW, {"pool"})
    assert mk(_FakeEngine(free=0, slots=8)).sample(0) == (RED, {"pool"})
    assert mk(_FakeEngine(host=500)).sample(0) == (YELLOW, {"host"})
    assert mk(_FakeEngine(host=5000)).sample(0) == (RED, {"host"})
    assert mk(_FakeEngine(), disk=1 << 15).sample(0) == (YELLOW, {"disk"})
    assert mk(_FakeEngine(), disk=1 << 5).sample(0) == (RED, {"disk"})
    assert mk(_FakeEngine()).sample(8) == (YELLOW, {"queue"})
    assert mk(_FakeEngine()).sample(64) == (RED, {"queue"})
    # worst state wins, reasons accumulate
    st, why = mk(_FakeEngine(free=0, slots=8)).sample(8)
    assert st == RED and why == {"pool", "queue"}


def test_monitor_fault_site_forces_transitions():
    from repro.serving.overload import RED, YELLOW, PressureMonitor, \
        WatermarkCfg
    plan = FaultPlan(schedule={"pressure": {0: "latency", 1: "io_error"}})
    mon = PressureMonitor(_FakeEngine(), WatermarkCfg(), fault_plan=plan,
                          disk_free_fn=lambda: 1 << 40)
    assert mon.sample(0) == (YELLOW, {"forced"})
    assert mon.sample(0) == (RED, {"forced"})
    assert mon.sample(0)[0] == "green"   # schedule exhausted
    assert mon.forced == 2
    assert [e.site for e in plan.fired_events()] == ["pressure"] * 2


# ---------------------------------------------------------------------------
# I7 property: preemption transparency (engine level)
# ---------------------------------------------------------------------------

def _drive_interleaved(seed, n_tokens=5):
    """Decode two sequences to exactly ``n_tokens`` each while a seeded
    interleaving of suspend/resume ops (seed None = never preempt)
    perturbs which subset decodes each round."""
    cfg, params, prompts = _setup()
    eng = _engine(cfg, params)
    rng = None if seed is None else np.random.RandomState(seed)
    toks, out, parked = {}, {}, {}
    for p in prompts[:2]:
        sid, tok = eng.add_sequence(p)
        toks[sid], out[sid] = tok, []
    swaps = 0
    for _ in range(200):
        if all(len(v) >= n_tokens for v in out.values()):
            break
        if rng is not None:
            op = rng.randint(4)
            if op == 0 and toks:
                sid = sorted(toks)[rng.randint(len(toks))]
                eng.suspend_sequence(sid)
                parked[sid] = toks.pop(sid)
                swaps += 1
            elif op == 1 and parked:
                sid = sorted(parked)[rng.randint(len(parked))]
                eng.resume_sequence(sid)
                toks[sid] = parked.pop(sid)
        live = {s: t for s, t in toks.items() if len(out[s]) < n_tokens}
        if not live:
            if not parked:
                continue               # all done, loop exits next pass
            sid = sorted(parked)[0]    # progress guarantee: force-resume
            eng.resume_sequence(sid)
            toks[sid] = parked.pop(sid)
            continue
        got = eng.decode_round(live)
        for sid, t in got.items():
            out[sid].append(t)
            toks[sid] = t
    for sid in sorted(parked):
        eng.resume_sequence(sid)
    for sid in sorted(out):
        eng.release(sid)
    _assert_engine_clean(eng)
    so, si = eng.store.seq_swapouts, eng.store.seq_swapins
    eng.store.close()
    assert so == si == swaps           # every swap-out had its swap-in
    return {sid: v[:n_tokens] for sid, v in out.items()}


_REF = {}


def _reference_tokens():
    if "out" not in _REF:
        _REF["out"] = _drive_interleaved(None)
    return _REF["out"]


@settings(max_examples=4, deadline=None)
@given(hst.integers(min_value=0, max_value=63))
def test_preemption_transparent_any_interleaving(seed):
    """I7: ANY seeded interleaving of suspend/resume/decode yields token
    streams identical to the never-preempted run, and no slot, pool,
    future, or swap-ledger state leaks."""
    assert _drive_interleaved(seed) == _reference_tokens()


def test_suspend_resume_guards():
    cfg, params, prompts = _setup()
    eng = _engine(cfg, params)
    with pytest.raises(KeyError):
        eng.suspend_sequence(0)        # not live
    sid, _ = eng.add_sequence(prompts[0])
    eng.suspend_sequence(sid)
    with pytest.raises(KeyError):
        eng.suspend_sequence(sid)      # already suspended
    eng.resume_sequence(sid)
    with pytest.raises(KeyError):
        eng.resume_sequence(sid)       # not suspended
    eng.release(sid)
    _assert_engine_clean(eng)
    eng.store.close()


def test_release_reclaims_suspended_slot():
    """engine.release on a suspended sid drops the parked state AND the
    store's swap ledger — the deadline-expiry-while-preempted path."""
    cfg, params, prompts = _setup()
    eng = _engine(cfg, params)
    sid, _ = eng.add_sequence(prompts[0])
    eng.suspend_sequence(sid)
    assert eng.store._swapped
    eng.release(sid)
    _assert_engine_clean(eng)
    eng.store.close()


def test_swap_bills_zero_out_chunkbytes_in():
    """kv_swapout is a zero-byte audit op (the write-through replica is
    already current); kv_swapin bills exactly the chunk bytes it
    re-stages — billed == crossed, I3."""
    cfg, params, prompts = _setup()
    eng = _engine(cfg, params)
    st = eng.store
    sid, _ = eng.add_sequence(prompts[0])
    n_out = st.swap_out_seq(sid)
    assert n_out > 0
    log = st.seq_logs[sid]
    outs = [k for k in log.ops if k[2] == "kv_swapout"]
    assert outs
    assert all(log.bytes[k] == 0 and log.ops[k] > 0 for k in outs)
    n_in = st.swap_in_seq(sid)
    assert n_in == n_out
    from repro.serving.offload import DISK, HOST
    ins = [k for k in log.ops if k[2] == "kv_swapin"]
    assert ins == [(DISK, HOST, "kv_swapin")]
    k = ins[0]
    assert log.bytes[k] == n_in * st.chunk_bytes and log.ops[k] == n_in
    eng.release(sid)
    eng.store.close()


# ---------------------------------------------------------------------------
# scheduler policy (deterministic)
# ---------------------------------------------------------------------------

def _batcher(eng, mon, **kw):
    from repro.serving.scheduler import ContinuousBatcher, SchedulerCfg
    cfg = dict(max_active=1, chunk=16)
    cfg.update(kw)
    return ContinuousBatcher(cfg=SchedulerCfg(**cfg), engine=eng,
                             monitor=mon)


def test_priority_preemption_and_aging_resume():
    """Queue-only yellow: a strictly higher class preempts the weakest
    victim, runs to completion first, and the victim resumes and
    finishes — suspended time tracked, nothing leaks."""
    from repro.serving.overload import PressureMonitor, WatermarkCfg
    from repro.serving.scheduler import Request
    cfg, params, prompts = _setup()
    eng = _engine(cfg, params, max_seqs=3)
    mon = PressureMonitor(eng, WatermarkCfg(queue_yellow=0, queue_red=99),
                          disk_free_fn=lambda: 1 << 40)
    b = _batcher(eng, mon)
    b.submit(Request(0, prompts[0], max_new=6, priority=0))
    b.step()
    assert 0 in b.active
    b.submit(Request(1, prompts[1], max_new=3, priority=5))
    b.step()
    assert 0 in b._suspended           # victim preempted for the VIP
    done = b.run()
    by = {r.rid: r for r in done}
    assert by[0].error is None and by[1].error is None
    assert len(by[0].out) == 6 and len(by[1].out) == 3
    assert by[1].t_done < by[0].t_done
    assert by[0].suspended_s > 0 and by[0].t_suspend is None
    st = b.stats()
    assert st["suspensions"] >= 1 and st["resumes"] >= 1
    assert st["requests_unaccounted"] == 0.0
    assert not b._suspended
    _assert_engine_clean(eng)
    eng.store.close()


def test_equal_priority_never_preempts():
    from repro.serving.overload import PressureMonitor, WatermarkCfg
    from repro.serving.scheduler import Request
    cfg, params, prompts = _setup()
    eng = _engine(cfg, params, max_seqs=3)
    mon = PressureMonitor(eng, WatermarkCfg(queue_yellow=0, queue_red=99),
                          disk_free_fn=lambda: 1 << 40)
    b = _batcher(eng, mon)
    b.submit(Request(0, prompts[0], max_new=6, priority=1))
    b.step()
    b.submit(Request(1, prompts[1], max_new=3, priority=1))
    done = b.run()
    assert b._suspensions == 0         # same class: FIFO order holds
    assert all(r.error is None for r in done)
    _assert_engine_clean(eng)
    eng.store.close()


def test_red_pressure_sheds_structured():
    """Forced red at the first sample sheds every queued request with a
    structured RejectedOverload; accounting stays exact."""
    from repro.serving.overload import PressureMonitor, WatermarkCfg
    from repro.serving.scheduler import Request
    cfg, params, prompts = _setup()
    plan = FaultPlan(schedule={"pressure": {0: "io_error"}})
    eng = _engine(cfg, params, max_seqs=3, plan=plan)
    mon = PressureMonitor(eng, WatermarkCfg(queue_yellow=0),
                          fault_plan=plan, disk_free_fn=lambda: 1 << 40)
    b = _batcher(eng, mon)
    for i, p in enumerate(prompts):
        b.submit(Request(i, p, max_new=3, priority=i))
    b.run()
    # shedding is lowest-class-newest-first down to the yellow watermark
    assert sorted(r.rid for r in b.rejected) == [0, 1, 2]
    for r in b.rejected:
        assert isinstance(r.rejected_overload, RejectedOverload)
        assert r.rejected_overload.rid == r.rid
        assert "forced" in r.rejected_overload.reasons
        assert r.t_done is not None and "overload" in r.error
    st = b.stats()
    assert st["requests_shed"] == 3.0
    assert st["requests_unaccounted"] == 0.0
    assert st["pressure_rounds_red"] >= 1.0
    _assert_engine_clean(eng)
    eng.store.close()


def test_resource_yellow_pauses_admission_and_drains():
    """Sustained resource (non-queue) yellow: admission pauses and the
    batch drains one victim per round but never below one active —
    then green resumes everything and the queue drains."""
    from repro.serving.overload import GREEN, PressureMonitor, WatermarkCfg
    from repro.serving.scheduler import Request

    class _ScriptedMonitor(PressureMonitor):
        def __init__(self, eng, n_yellow):
            super().__init__(eng, WatermarkCfg(),
                             disk_free_fn=lambda: 1 << 40)
            self.n_yellow = n_yellow

        def sample(self, queue_depth=0):
            # sample 1 green (both requests admit), then n_yellow rounds
            # of resource pressure, then green again
            self.samples += 1
            if 2 <= self.samples <= 1 + self.n_yellow:
                return "yellow", {"disk"}
            return GREEN, set()

    cfg, params, prompts = _setup()
    eng = _engine(cfg, params, max_seqs=4)
    mon = _ScriptedMonitor(eng, n_yellow=2)
    b = _batcher(eng, mon, max_active=2)
    for i, p in enumerate(prompts[:2]):
        b.submit(Request(i, p, max_new=6))
    b.step()
    assert len(b.active) == 2
    b.submit(Request(2, prompts[2], max_new=3))
    b.step()                           # yellow(disk): pause + 1 victim
    assert b._admission_paused
    assert len(b._suspended) == 1 and len(b.active) == 1
    assert all(r.rid == 2 for r in b.queue)   # nothing admitted
    done = b.run()                     # green: resume + admit + finish
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(r.error is None for r in done)
    assert b.stats()["requests_unaccounted"] == 0.0
    _assert_engine_clean(eng)
    eng.store.close()


def test_deadline_clock_pauses_while_suspended():
    """I7 no-starvation: a suspended request's deadline clock stops —
    wall time spent preempted does not consume its latency budget."""
    from repro.serving.scheduler import Request
    cfg, params, prompts = _setup()
    eng = _engine(cfg, params, max_seqs=2)
    b = _batcher(eng, None)
    b.submit(Request(99, prompts[1], max_new=2))   # jit warmup: the first
    b.run()                                        # round compiles (~s)
    req = Request(0, prompts[0], max_new=4)
    b.submit(req)
    b.step()
    assert 0 in b.active
    b._suspend(0)
    # budget = time already burned + 0.2s; the 0.3s nap would blow it if
    # the clock kept running while suspended
    req.deadline_s = (time.perf_counter() - req.t_submit) + 0.2
    time.sleep(0.3)
    assert b.active == {}
    assert req.paused_s >= 0.3
    assert not req.expired             # paused clock saved it
    b._resume(0)
    by = {r.rid: r for r in b.run()}   # finished includes the warmup
    assert by[0].error is None and len(by[0].out) == 4
    assert by[0].suspended_s >= 0.3
    _assert_engine_clean(eng)
    eng.store.close()


def test_harness_accounting_and_percentiles():
    """LoadHarness over a bursty trace: exact terminal accounting and
    the p99 TTFT / queue-wait observability rows exist."""
    from repro.serving.overload import LoadHarness, PressureMonitor, \
        WatermarkCfg
    cfg, params, _ = _setup()
    eng = _engine(cfg, params, max_seqs=4)
    mon = PressureMonitor(eng, WatermarkCfg(queue_yellow=6, queue_red=99),
                          disk_free_fn=lambda: 1 << 40)
    b = _batcher(eng, mon, max_active=2)
    arrivals = gen_trace(TraceCfg(n_requests=8, min_prompt=24,
                                  max_prompt=96, max_new=2,
                                  deadline_s=120.0), seed=3)
    res = LoadHarness(b, arrivals, time_scale=0.0, seed=1,
                      vocab=cfg.vocab_size).run()
    assert res["requests_submitted"] == 8.0
    assert res["requests_unaccounted"] == 0.0
    assert res["goodput"] == res["requests_completed"] / 8.0
    for key in ("p99_ttft_s", "p50_queue_wait_s", "p99_queue_wait_s",
                "pressure_level", "suspensions", "harness_rounds"):
        assert key in res, key
    _assert_engine_clean(eng)
    eng.store.close()


def test_simulator_trace_goodput_matches_queueing_logic():
    """The analytic goodput function is a plain FCFS replay: generous
    deadlines -> goodput 1, impossible deadlines -> goodput 0, and an
    infinite-rate burst backs up the queue (sojourn grows with index)."""
    from repro.serving.simulator import HWCfg, ServeCfg, \
        simulate_trace_goodput
    cfg, _, _ = _setup()
    arr = [Arrival(t=0.0, prompt_len=64, max_new=4, deadline_s=None)
           for _ in range(4)]
    hw, scfg = HWCfg(), ServeCfg(output=4)
    r = simulate_trace_goodput(cfg, scfg, hw, arr)
    assert r["goodput"] == 1.0 and r["requests"] == 4.0
    tight = [dataclasses.replace(a, deadline_s=1e-12) for a in arr]
    assert simulate_trace_goodput(cfg, scfg, hw, tight)["goodput"] == 0.0
    # two servers halve the backlog a simultaneous burst builds
    m1 = simulate_trace_goodput(cfg, scfg, hw, arr)["makespan_s"]
    m2 = simulate_trace_goodput(cfg, scfg, hw, arr,
                                servers=2)["makespan_s"]
    assert m2 < m1


# ---------------------------------------------------------------------------
# chaos: preemption under seeded disk faults + sanitizer
# ---------------------------------------------------------------------------

@pytest.mark.chaos
@settings(max_examples=4, deadline=None)
@given(hst.integers(min_value=0, max_value=31))
def test_chaos_preemption_with_disk_faults(seed):
    """Seeded disk/worker faults + forced pressure transitions against
    the preempting scheduler (sanitizer on): every request terminates in
    exactly one of {completed, shed, failed}, and no slot, pool slot,
    refcount, or swap-ledger entry leaks."""
    from repro.serving.overload import PressureMonitor, WatermarkCfg
    from repro.serving.scheduler import Request
    cfg, params, prompts = _setup()
    plan = FaultPlan.from_seed(seed, rate=0.04, horizon=300,
                               latency_s=1e-3)
    was_active = sanitizer.active()
    eng = _engine(cfg, params, max_seqs=3, plan=plan)
    mon = PressureMonitor(eng, WatermarkCfg(queue_yellow=1, queue_red=99),
                          fault_plan=plan, disk_free_fn=lambda: 1 << 40)
    b = _batcher(eng, mon, max_active=2)
    for i, p in enumerate(prompts):
        b.submit(Request(i, p, max_new=3, priority=i % 2))
    b.run()
    try:
        reqs = list(b.finished) + list(b.rejected)
        assert {r.rid for r in reqs} == {0, 1, 2}
        for r in reqs:
            assert r.t_done is not None
        st = b.stats()
        assert st["requests_unaccounted"] == 0.0, st
        assert not b._suspended
        _assert_engine_clean(eng)
    finally:
        eng.store.close()
    assert sanitizer.active() == was_active
