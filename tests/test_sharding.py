"""Distribution correctness: multi-device (host-platform) runs match
single-device numerics; runs in a subprocess so the device count doesn't
leak into other tests."""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json, dataclasses
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, get_shape
from repro.configs.base import ShapeCfg
from repro.launch import steps as stp
from repro.launch.mesh import make_host_mesh, set_mesh
from repro.models import lm
from repro.optim import adamw

cfg = get_config("qwen3-1.7b", smoke=True)
mesh = make_host_mesh(2, 4)
tcfg = stp.TrainCfg(lr=1e-3, warmup_steps=2, total_steps=10)
params = lm.init(cfg, jax.random.PRNGKey(0))
state = {"params": params, "opt": adamw.init_opt_state(params, tcfg.adam)}
rng = np.random.RandomState(0)
B, S = 8, 64
batch = {"tokens": jnp.asarray(rng.randint(1, cfg.vocab_size, (B, S)), jnp.int32)}
batch["targets"] = batch["tokens"]

# single-device reference
step1 = jax.jit(stp.make_train_step(cfg, tcfg))
s1, m1 = step1(jax.tree.map(jnp.copy, state), batch)

# distributed
with set_mesh(mesh):
    shape = ShapeCfg("t", S, B, "train")
    jitted, ss, bspec = stp.make_jitted_train_step(cfg, mesh, tcfg, shape)
    # deep-copy before device_put: the jitted step donates its state arg and
    # device_put may alias the source buffers on the host platform
    sh_state = jax.device_put(jax.tree.map(jnp.copy, state), jax.tree.map(
        lambda p: NamedSharding(mesh, p), ss,
        is_leaf=lambda x: isinstance(x, P)))
    sh_batch = jax.device_put(batch, jax.tree.map(
        lambda p: NamedSharding(mesh, p), bspec,
        is_leaf=lambda x: isinstance(x, P)))
    s2, m2 = jitted(sh_state, sh_batch)

out = {"loss1": float(m1["loss"]), "loss2": float(m2["loss"])}
d = 0.0
for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
    d = max(d, float(jnp.max(jnp.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)))))
out["max_param_diff"] = d

# decode path: seq-sharded attention on the mesh vs local.  Dense mode:
# sparse selection is per-shard under sequence sharding (a documented
# approximation), so exactness is asserted on the dense lse-combine path.
shape_d = ShapeCfg("d", 128, 8, "decode")
cfg_d = dataclasses.replace(
    cfg, leoam=dataclasses.replace(cfg.leoam, min_seq_for_sparse=10**9))
cache = lm.abstract_cache(cfg_d, 8, 128)
cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache)
prompt = jnp.asarray(rng.randint(1, cfg.vocab_size, (8, 96)), jnp.int32)
_, cache_local = lm.prefill(params, cfg_d, {"tokens": prompt}, max_len=128)
logits_local, _ = lm.decode_step(params, cfg_d, cache_local,
                                 {"token": prompt[:, -1]}, jnp.int32(96))
with set_mesh(mesh):
    jd = stp.make_jitted_decode(cfg_d, mesh, shape_d)
    csh = jax.tree.map(lambda p: NamedSharding(mesh, p),
                       stp.cache_specs(cfg_d, mesh, shape_d),
                       is_leaf=lambda x: isinstance(x, P))
    cache_sh = jax.device_put(jax.tree.map(jnp.copy, cache_local), csh)
    psh = stp.param_shardings(cfg_d, mesh)
    params_sh = jax.device_put(params, psh)
    tok_sh = jax.device_put(prompt[:, -1], NamedSharding(mesh, P("data")))
    logits_sh, _ = jd(params_sh, cache_sh, {"token": tok_sh}, jnp.int32(96))
out["decode_diff"] = float(jnp.max(jnp.abs(
    np.asarray(logits_local, np.float32) - np.asarray(logits_sh, np.float32))))
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_multidevice_matches_single(tmp_path):
    p = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(__file__)),
                       timeout=900)
    assert p.returncode == 0, p.stderr[-3000:]
    line = [l for l in p.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert abs(out["loss1"] - out["loss2"]) < 2e-2, out
    assert out["max_param_diff"] < 2e-2, out
    assert out["decode_diff"] < 2e-2, out
