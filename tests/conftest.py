import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; multi-device tests spawn subprocesses.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # offline fallback: vendored fixed-example shim (see _hypothesis_compat)
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_compat
    sys.modules["hypothesis"] = _hypothesis_compat
    sys.modules["hypothesis.strategies"] = _hypothesis_compat.strategies

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
