"""Minimal offline stand-in for the ``hypothesis`` package.

The container has no network access, so ``pip install hypothesis`` is not an
option.  This module provides just enough of the hypothesis API surface used
by this repo's property tests — ``given``, ``settings`` and the ``integers``
/ ``floats`` / ``sampled_from`` strategies — drawing a fixed number of
deterministic, seeded examples instead of performing randomized search and
shrinking.  It is installed into ``sys.modules`` by ``conftest.py`` ONLY
when the real package is absent, so environments that do have hypothesis
keep its full power (shrinking, edge-case probing, failure databases).

Determinism: examples are derived from ``crc32(test qualname)`` so a given
test always sees the same example sequence, independent of collection order
or the process seed.
"""

from __future__ import annotations

import zlib
from typing import Any, Callable, List, Sequence

import numpy as np

__version__ = "0.0-repro-compat"

_DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    """A strategy is just a seeded draw function plus edge examples.

    ``edges`` are deterministic boundary draws emitted first (hypothesis
    reliably probes bounds; property tests here lean on that for clamp
    logic), then the remaining examples are uniform draws.
    """

    def __init__(self, draw: Callable[[np.random.RandomState], Any],
                 edges: Sequence[Any] = ()):
        self._draw = draw
        self._edges = list(edges)

    def example_at(self, idx: int, rng: np.random.RandomState) -> Any:
        if idx < len(self._edges):
            return self._edges[idx]
        return self._draw(rng)


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (``st.`` in tests)."""

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 2 ** 31 - 1
                 ) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: int(rng.randint(min_value, max_value + 1)),
            edges=[min_value, max_value])

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0
               ) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            edges=[min_value, max_value])

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
        elements = list(elements)
        return SearchStrategy(
            lambda rng: elements[rng.randint(len(elements))],
            edges=elements)

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: bool(rng.randint(2)),
                              edges=[False, True])

    @staticmethod
    def just(value: Any) -> SearchStrategy:
        return SearchStrategy(lambda rng: value, edges=[value])


def given(*strats: SearchStrategy) -> Callable:
    """Run the test once per deterministic example (positional draws only,
    which is all this repo uses)."""

    def deco(fn: Callable) -> Callable:
        def wrapper():
            n = getattr(wrapper, "_hc_max_examples", _DEFAULT_MAX_EXAMPLES)
            base = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            for i in range(n):
                rng = np.random.RandomState((base + i) % (2 ** 32))
                args = [s.example_at(i, rng) for s in strats]
                try:
                    fn(*args)
                except Exception as e:
                    raise AssertionError(
                        f"Falsifying example (compat draw {i}): "
                        f"{fn.__name__}({', '.join(map(repr, args))})") from e

        # NOTE: no functools.wraps — pytest follows __wrapped__ for signature
        # introspection and would then demand fixtures for the drawn params.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._hc_inner = fn
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored) -> Callable:
    """Applied above ``given`` in this repo, so it receives the wrapper."""

    def deco(fn: Callable) -> Callable:
        fn._hc_max_examples = max_examples
        return fn

    return deco


# `from hypothesis import strategies as st` resolves the class; expose the
# usual `hypothesis.strategies` submodule alias via conftest's sys.modules
# registration.
st = strategies
