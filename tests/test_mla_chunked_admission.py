"""MLA chunked admission (PR 5): DeepSeek-class absorbed-MLA models ride
the full bucketed/chunked admission pipeline — latent single-plane tier
store, chunk-by-chunk prefill under running decode rounds, write-behind
partial ingest — token-identical to whole-prompt ``add_sequence``
(property-tested under randomized interleavings and at bucket edges), plus
the adaptive per-round prefill budget derived from measured EWMAs."""

import dataclasses
import math

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import compression
from repro.serving.offload import DEVICE, DISK, HOST, TieredKVStore
from repro.serving.scheduler import ContinuousBatcher, Request, SchedulerCfg

_SETUP = {}


def _setup():
    """Module-lazy MLA smoke model (the hypothesis shim can't take
    fixtures).  deepseek-v2-lite smoke: MLA kv_lora 32 + rope 8 (latent
    width 40), MoE body layers — the admission path's hardest case."""
    if not _SETUP:
        from repro.configs import get_config
        from repro.models import lm
        cfg = get_config("deepseek-v2-lite-16b", smoke=True)
        cfg = dataclasses.replace(
            cfg, leoam=dataclasses.replace(cfg.leoam, chunk_size=16,
                                           importance_rate=0.4,
                                           early_rate=0.6,
                                           min_seq_for_sparse=32))
        _SETUP["cfg"] = cfg
        _SETUP["params"] = lm.init(cfg, jax.random.PRNGKey(1))
    return _SETUP["cfg"], _SETUP["params"]


def _ecfg(**kw):
    from repro.serving.engine import EngineCfg
    return EngineCfg(max_len=128, selection="tree", **kw)


def _engine(max_seqs=1, **kw):
    from repro.serving.engine import BatchedLeoAMEngine
    cfg, params = _setup()
    return BatchedLeoAMEngine(cfg, params, _ecfg(**kw), max_seqs=max_seqs)


def _gen(eng, prompt, n_new=3):
    sid, tok = eng.add_sequence(prompt)
    out = [tok]
    toks = {sid: tok}
    for _ in range(n_new):
        toks = eng.decode_round(toks)
        out.append(toks[sid])
    eng.release(sid)
    return out


# ---------------------------------------------------------------------------
# Latent store layout
# ---------------------------------------------------------------------------


def test_latent_store_single_plane_accounting(rng):
    """The absorbed-MLA store keeps ONE latent plane: chunk/row bytes cover
    exactly the latent payload (no phantom V), abstracts are the min/max
    box over the latent rows, and the packed sidecar bytes obey the
    single-plane codec identity."""
    D = 40
    st_ = TieredKVStore(1, 4, 16, 1, D, n_seqs=1, transit_codec="int4",
                        latent=True, use_pool=True, disk_sidecar=True)
    assert st_.planes == 1
    assert st_.chunk_bytes == 16 * D * 2          # one fp16 latent plane
    assert st_.row_bytes == D * 2
    assert st_.abstract_bytes == 2 * D * 2        # min + max, not K + V
    lat = rng.randn(48, 1, D).astype(np.float16)
    st_.ingest(0, lat, None, {0: DEVICE, 1: HOST, 2: DISK})
    km, kn = st_.read_abstracts(0, [2])
    np.testing.assert_allclose(km[0], lat[32:48].max(0), atol=1e-3)
    np.testing.assert_allclose(kn[0], lat[32:48].min(0), atol=1e-3)
    assert st_.pools[0].kv.shape == (st_.pools[0].n_slots + 1, 1, 16, 1, D)
    # packed sidecar identity for the single plane
    st_.demote(0, [2], to=DISK)
    _, _, fst = st_.fetch_chunks_pooled(0, {0: [2]})
    packed = st_.chunk_bytes * compression.codec_ratio("int4", group=16)
    assert fst.disk_bytes == pytest.approx(packed)
    st_.close()


def test_latent_partial_ingest_matches_whole(rng):
    """Chunk-aligned partial ingest of LATENT rows (start=...) lands the
    same replicas, abstracts, tiers and billed bytes as one whole-sequence
    ingest — byte-for-byte in the disk replica."""
    D = 40
    lat = rng.randn(64, 1, D).astype(np.float16)
    place = {0: DEVICE, 1: HOST, 2: DISK, 3: DISK}
    whole = TieredKVStore(1, 4, 16, 1, D, n_seqs=1, transit_codec="int4",
                          latent=True)
    whole.ingest(0, lat, None, place)
    part = TieredKVStore(1, 4, 16, 1, D, n_seqs=1, transit_codec="int4",
                         latent=True)
    for start in (0, 16, 32):
        n = 16 if start < 32 else 32
        part.ingest(0, lat[start:start + n], None, place, start=start)
    np.testing.assert_array_equal(np.asarray(whole._disk),
                                  np.asarray(part._disk))
    np.testing.assert_array_equal(whole._abs_km, part._abs_km)
    np.testing.assert_array_equal(whole._abs_kn, part._abs_kn)
    assert list(whole.tier[0, 0]) == list(part.tier[0, 0])
    assert whole.log.total() == part.log.total()
    whole.close()
    part.close()


def test_latent_sidecar_partial_ingest_matches_whole(rng):
    """Partial vs whole ingest parity extends to the packed int4 sidecar
    (payload + scales) and its billing."""
    D = 40
    lat = rng.randn(64, 1, D).astype(np.float16)
    stores = []
    for starts in ((0,), (0, 32)):
        s = TieredKVStore(1, 4, 16, 1, D, n_seqs=1, transit_codec="int4",
                          latent=True, disk_sidecar=True)
        for start in starts:
            n = 64 if len(starts) == 1 else 32
            s.ingest(0, lat[start:start + n], None,
                     {c: DISK for c in range(4)}, start=start)
        stores.append(s)
    whole, part = stores
    np.testing.assert_array_equal(np.asarray(whole._disk_q),
                                  np.asarray(part._disk_q))
    np.testing.assert_array_equal(np.asarray(whole._disk_scale),
                                  np.asarray(part._disk_scale))
    assert whole.log.total() == part.log.total()
    whole.close()
    part.close()


# ---------------------------------------------------------------------------
# Engine: MLA end-to-end + bucket edges
# ---------------------------------------------------------------------------


_ENGINES = {}


def _bucket_pair():
    if not _ENGINES:
        _ENGINES["exact"] = _engine(bucket_prefill=False)
        _ENGINES["bucket"] = _engine(bucket_prefill=True)
    return _ENGINES["exact"], _ENGINES["bucket"]


@pytest.mark.parametrize("L", [31, 32, 33, 63, 64, 65])
def test_mla_bucketed_prefill_token_identical_at_bucket_edges(L):
    """Property (bucket edges L, L±1): the MLA cache-zeroing path honors
    the traced true length — bucketed MLA admission decodes the exact
    token stream of exact-length admission."""
    cfg, _ = _setup()
    prompt = np.random.RandomState(100 + L).randint(2, cfg.vocab_size, L)
    exact, bucket = _bucket_pair()
    assert _gen(bucket, prompt) == _gen(exact, prompt)


def test_mla_mixed_lengths_compile_log_programs():
    """O(log L) compiled prefill programs hold for MLA traffic too: >= 12
    distinct prompt lengths stay within ceil(log2(max_len)) + 2 programs,
    first tokens matching the exact-length path."""
    cfg, _ = _setup()
    exact, bucket = _bucket_pair()
    rng = np.random.RandomState(11)
    lengths = list(range(17, 113, 8))
    assert len(set(lengths)) >= 12
    for L in lengths:
        p = rng.randint(2, cfg.vocab_size, L)
        sid_b, tok_b = bucket.add_sequence(p)
        bucket.release(sid_b)
        sid_e, tok_e = exact.add_sequence(p)
        exact.release(sid_e)
        assert tok_b == tok_e, L
    limit = math.ceil(math.log2(bucket.ecfg.max_len)) + 2
    assert bucket.prefill_programs <= limit, (bucket.prefill_programs, limit)
    assert exact.prefill_programs >= len(lengths)


@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_mla_chunked_admission_interleaved_matches_serial(seed):
    """Property: MLA chunked admission stepped at RANDOM interleavings
    with a running sequence's decode rounds produces token streams
    identical to whole-prompt admission at the same round schedule."""
    cfg, _ = _setup()
    rng = np.random.RandomState(seed)
    pa = rng.randint(2, cfg.vocab_size, 41)
    pb = rng.randint(2, cfg.vocab_size, 57)
    pre_rounds = int(rng.randint(0, 3))
    interleave = [bool(b) for b in rng.randint(2, size=8)]

    def run(chunked: bool):
        eng = _engine(max_seqs=2, prefill_chunk_tokens=32)
        sa_, ta = eng.add_sequence(pa)
        outs = {sa_: [ta]}
        toks = {sa_: ta}
        for _ in range(pre_rounds):
            toks = eng.decode_round(toks)
            outs[sa_].append(toks[sa_])
        if chunked:
            adm = eng.begin_admission(pb)
            for do_round in interleave:
                adm.step()
                if adm.done:
                    break
                if do_round:
                    toks = eng.decode_round(toks)
                    outs[sa_].append(toks[sa_])
            sb, tb = adm.drain()
        else:
            sb, tb = eng.add_sequence(pb)
        outs[sb] = [tb]
        toks[sb] = tb
        for _ in range(3):
            toks = eng.decode_round(toks)
            for s, t in toks.items():
                outs[s].append(t)
        eng.store.close()
        return outs[sa_], outs[sb]

    a_chunk, b_chunk = run(True)
    a_ser, b_ser = run(False)
    n = min(len(a_chunk), len(a_ser))
    assert a_chunk[:n] == a_ser[:n]
    assert b_chunk == b_ser


@settings(max_examples=2, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_mla_scheduler_chunked_admission_parity(seed):
    """Acceptance: an MLA model runs ContinuousBatcher(chunked_admission=
    True) end-to-end with token streams identical to whole-prompt
    admission, for random arrival orders and budgets."""
    cfg, params = _setup()
    from repro.serving.engine import BatchedLeoAMEngine
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(2, cfg.vocab_size, n) for n in (48, 57, 64, 50)]
    order = list(rng.permutation(4))
    budget = int(rng.choice([16, 32, 64]))

    def drive(chunked: bool):
        eng = BatchedLeoAMEngine(cfg, params,
                                 _ecfg(prefill_chunk_tokens=16),
                                 max_seqs=3)
        b = ContinuousBatcher(
            cfg=SchedulerCfg(max_active=2, chunk=16,
                             chunked_admission=chunked,
                             prefill_round_tokens=budget),
            engine=eng)
        for i in order:
            b.submit(Request(i, prompts[i], max_new=4))
        out = {r.rid: r.out for r in b.run()}
        eng.store.close()
        return out

    assert drive(True) == drive(False), (order, budget)


def test_mla_partial_engine_ingest_matches_whole_ingest(rng):
    """Chunked MLA admission lands byte-identical replicas AND abstracts
    in the tier store vs whole-prompt admission of the same prompt."""
    cfg, params = _setup()
    from repro.serving.engine import BatchedLeoAMEngine
    prompt = rng.randint(2, cfg.vocab_size, 57)
    whole = BatchedLeoAMEngine(cfg, params, _ecfg(), max_seqs=1)
    whole.add_sequence(prompt)
    whole.store.ingest_fence(0)
    chunked = BatchedLeoAMEngine(cfg, params,
                                 _ecfg(prefill_chunk_tokens=16), max_seqs=1)
    chunked.begin_admission(prompt).drain()
    chunked.store.ingest_fence(0)
    np.testing.assert_array_equal(np.asarray(whole.store._disk),
                                  np.asarray(chunked.store._disk))
    np.testing.assert_array_equal(whole.store._abs_km, chunked.store._abs_km)
    np.testing.assert_array_equal(whole.store._abs_kn, chunked.store._abs_kn)
    assert (list(whole.store.tier[0].reshape(-1))
            == list(chunked.store.tier[0].reshape(-1)))
    whole.store.close()
    chunked.store.close()


def test_mla_oversized_prompt_and_capacity_raise():
    """Admission-path guards raise actionable ValueErrors (not asserts):
    oversized prompts before the slot pop, capacity exhaustion, and
    unaligned chunk sizes."""
    cfg, params = _setup()
    eng = _engine(max_seqs=1)
    with pytest.raises(ValueError, match="max_len"):
        eng.add_sequence(np.arange(4000) % cfg.vocab_size)
    assert eng.free_slots == 1            # no slot leaked
    with pytest.raises(ValueError, match="multiple of the store chunk"):
        eng.begin_admission(np.arange(32), chunk_tokens=24)
    sid, _ = eng.add_sequence(np.arange(2, 50))
    with pytest.raises(ValueError, match="capacity"):
        eng.add_sequence(np.arange(2, 50))
    eng.release(sid)
    eng.store.close()


# ---------------------------------------------------------------------------
# MoE no-drop inference dispatch (what makes chunked == whole possible)
# ---------------------------------------------------------------------------


def test_moe_no_drop_rows_independent_of_batch_shape(rng):
    """Inference MoE dispatch (no_drop): a token's output is independent
    of the surrounding batch shape — the same rows fed at T=8 and T=32
    produce bitwise-identical outputs, while the training dispatch may
    capacity-drop differently."""
    import jax.numpy as jnp
    from repro.models import lm as lm_mod
    cfg, params = _setup()
    blk = params["body"][0]
    moe_blk = {k: jax.tree.map(lambda a: a[0], v) for k, v in blk.items()}
    x = jnp.asarray(rng.randn(1, 32, cfg.d_model).astype(np.float32))
    y_whole, _ = lm_mod._apply_mlp(moe_blk, cfg, "moe", x, None,
                                   no_drop=True)
    y_chunk0, _ = lm_mod._apply_mlp(moe_blk, cfg, "moe", x[:, :8], None,
                                    no_drop=True)
    np.testing.assert_array_equal(np.asarray(y_whole[:, :8]),
                                  np.asarray(y_chunk0))


# ---------------------------------------------------------------------------
# Adaptive prefill budget
# ---------------------------------------------------------------------------


def test_adaptive_prefill_budget_derivation():
    """The derived budget honors the target stall bound: with measured
    idle-round and chunk-step EWMAs, budget = k * chunk_tokens where k is
    the largest count with idle + k*chunk <= idle*(1+frac); clamped to one
    chunk so admission always progresses."""
    b = ContinuousBatcher(make_engine=lambda: None,
                          cfg=SchedulerCfg(adaptive_prefill_budget=True,
                                           target_stall_frac=0.5,
                                           prefill_round_tokens=64))
    # no measurements yet: static fallback
    assert b._prefill_budget() == 64
    b._idle_ewma, b._round_ewma = 0.2, 0.25
    b._chunk_ewma, b._chunk_tokens = 0.02, 16
    assert b._prefill_budget() == 5 * 16          # 0.5*0.2/0.02 = 5 chunks
    assert b.stats()["prefill_round_tokens"] == 80.0
    # chunk steps dearer than the whole tolerated stall: still one chunk
    b._chunk_ewma = 0.5
    assert b._prefill_budget() == 16
    # bound check: derived k satisfies the analytic model's gap bound
    from repro.core.pipeline import chunked_admission_model
    m = chunked_admission_model(0.02, 5, 0.2, 5)
    assert m["max_round_gap_chunked_s"] <= 0.2 * 1.5 + 1e-9


def test_adaptive_prefill_budget_end_to_end():
    """Live run: adaptive chunked admission completes, matches the static
    token streams, and stats() exports the derived budget + chunk EWMA."""
    cfg, params = _setup()
    from repro.serving.engine import BatchedLeoAMEngine
    rng = np.random.RandomState(3)
    prompts = [rng.randint(2, cfg.vocab_size, n) for n in (48, 57, 40)]

    def drive(adaptive: bool):
        eng = BatchedLeoAMEngine(cfg, params,
                                 _ecfg(prefill_chunk_tokens=16), max_seqs=3)
        b = ContinuousBatcher(
            cfg=SchedulerCfg(max_active=2, chunk=16, chunked_admission=True,
                             prefill_round_tokens=16,
                             adaptive_prefill_budget=adaptive),
            engine=eng)
        for i, p in enumerate(prompts):
            b.submit(Request(i, p, max_new=4))
        out = {r.rid: r.out for r in b.run()}
        stt = b.stats()
        eng.store.close()
        return out, stt

    out_a, stt = drive(True)
    out_s, _ = drive(False)
    assert out_a == out_s            # budget moves latency, never values
    assert "prefill_round_tokens" in stt
    assert "chunk_step_ewma_s" in stt
    assert stt["prefill_round_tokens"] >= 16
