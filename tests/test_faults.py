"""Fault injection + integrity layer: store-level containment.

Covers docs/INVARIANTS.md I6 at the `TieredKVStore` boundary:

- `FaultPlan` determinism (same seed -> byte-identical schedule) and
  per-site kind pools;
- CRC rejection of corrupted replicas (-> `ChunkLostError`) and
  sidecars (-> lossless fp16 fallback, seq flagged degraded);
- `restore_chunk` recovery round-trip;
- bounded retry: one transient error is value-identical after retry,
  persistent errors exhaust into the degrade paths;
- crash consistency: a reopened store rejects torn (never-checksummed)
  chunks instead of serving garbage;
- exception-safe `ingest_fence` (regression: used to leave later
  futures in flight when the first one raised) and the pooled-fetch
  partial-failure scrub (regression: used to leak slots + dangling
  residency when the stack/codec/scatter raised after allocation).
"""

from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.serving.faults import (FAULT_KINDS, FAULT_SITES, ChunkLostError,
                                  DiskIOExhausted, FaultPlan, IngestError,
                                  TransientDiskError, WorkerFault,
                                  _SITE_KINDS)
from repro.serving.offload import DISK, HOST, TieredKVStore

L, NC, CH, HKV, HD = 2, 4, 8, 2, 4     # layers, chunks, chunk, Hkv, hd


def _mk(root=None, reopen=False, faults=None, **kw):
    kw.setdefault("io_backoff_s", 0.0)
    return TieredKVStore(L, NC, CH, HKV, HD, n_seqs=2, disk_sidecar=True,
                         transit_codec="int8", root=root, reopen=reopen,
                         faults=faults, **kw)


def _kv(seed=0):
    rng = np.random.RandomState(seed)
    return (rng.randn(NC * CH, HKV, HD).astype(np.float16),
            rng.randn(NC * CH, HKV, HD).astype(np.float16))


def _ingest_all(st, k, v, seq=0, **kw):
    for li in range(L):
        st.ingest(li, k, v, {c: DISK for c in range(NC)}, seq=seq, **kw)


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------

def test_fault_plan_from_seed_deterministic():
    a = FaultPlan.from_seed(7, rate=0.2)
    b = FaultPlan.from_seed(7, rate=0.2)
    assert a.schedule == b.schedule
    # a handful of seeds must not all collapse onto one schedule
    assert len({str(FaultPlan.from_seed(s, rate=0.2).schedule)
                for s in range(8)}) > 1


def test_fault_plan_site_kind_pools():
    # seeded schedules draw from the per-site pools: no "exception" at
    # decode-thread read sites, no "bitflip" at write/worker sites
    for seed in range(20):
        plan = FaultPlan.from_seed(seed, rate=0.5, horizon=50)
        for site, hits in plan.schedule.items():
            for kind in hits.values():
                assert kind in _SITE_KINDS[site]


def test_fault_plan_check_consumes_indices():
    plan = FaultPlan(schedule={"disk_read": {1: "io_error"}})
    assert plan.check("disk_read") is None
    assert plan.check("disk_read", key="k") == "io_error"
    assert plan.check("disk_read") is None
    assert plan.calls()["disk_read"] == 3
    [ev] = plan.fired_events()
    assert (ev.site, ev.index, ev.kind, ev.key) == ("disk_read", 1,
                                                    "io_error", "k")


def test_fault_plan_rejects_unknown_names():
    with pytest.raises(ValueError):
        FaultPlan(schedule={"nope": {0: "io_error"}})
    with pytest.raises(ValueError):
        FaultPlan(schedule={"disk_read": {0: "nope"}})
    assert set(_SITE_KINDS) == set(FAULT_SITES)
    assert all(k in FAULT_KINDS for ks in _SITE_KINDS.values() for k in ks)


# ---------------------------------------------------------------------------
# checksum rejection + recovery
# ---------------------------------------------------------------------------

def test_clean_fetch_counts_nothing():
    st = _mk()
    k, v = _kv()
    _ingest_all(st, k, v)
    ks, _ = st.fetch_chunks(0, [0, 1], seq=0)
    assert ks.shape == (2, CH, HKV, HD)
    fs = st.fault_stats()
    assert fs["io_retries"] == fs["checksum_failures"] == 0
    assert fs["chunks_recomputed"] == fs["disk_lost"] == 0
    st.close()


def test_replica_corruption_raises_chunk_lost():
    st = _mk()
    k, v = _kv()
    _ingest_all(st, k, v)
    st._disk[0, 1, 2, 0].reshape(-1)[3] += np.float16(1.0)
    st._sidecar_valid[0, 1, 2] = False      # force the replica path
    with pytest.raises(ChunkLostError) as ei:
        st.fetch_chunks(1, [2], seq=0)
    assert ei.value.layer == 1 and ei.value.keys == [(0, 0, 2)]
    assert st.disk_lost_keys() == {(0, 1, 2)}
    assert st.fault_stats()["checksum_failures"] == 1
    # re-detection of an already-lost chunk must not double count
    with pytest.raises(ChunkLostError):
        st.fetch_chunks(1, [2], seq=0)
    assert st.fault_stats()["checksum_failures"] == 1
    st.close()


def test_restore_chunk_roundtrip():
    st = _mk()
    k, v = _kv()
    _ingest_all(st, k, v)
    st._disk[0, 1, 2, 0].reshape(-1)[3] += np.float16(1.0)
    st._sidecar_valid[0, 1, 2] = False
    with pytest.raises(ChunkLostError):
        st.fetch_chunks(1, [2], seq=0)
    kc, vc = k[2 * CH:3 * CH], v[2 * CH:3 * CH]
    st.restore_chunk(1, 0, 2, kc, vc)
    ks, vs = st.fetch_chunks(1, [2], seq=0)
    assert np.array_equal(ks[0], kc) and np.array_equal(vs[0], vc)
    fs = st.fault_stats()
    assert fs["chunks_recomputed"] == 1 and fs["disk_lost"] == 0
    # recovery traffic is billed under its own kind
    assert st.log.total(src=HOST, kind="kv_recompute") == st.chunk_bytes
    st.close()


def test_sidecar_bitflip_falls_back_lossless():
    plan = FaultPlan(schedule={"sidecar_read": {0: "bitflip"}})
    st = _mk(faults=plan)
    k, v = _kv()
    _ingest_all(st, k, v, seq=1)
    ks, _ = st.fetch_chunks(0, [0], seq=1)
    # the fallback serves the fp16 replica: lossless, not the codec
    assert np.array_equal(ks[0], k[:CH])
    assert 1 in st.degraded_seqs
    assert st.fault_stats()["checksum_failures"] == 1
    assert st.log.total(src=DISK, kind="kv_fallback") > 0
    [ev] = plan.fired_events()
    assert ev.site == "sidecar_read" and ev.key is not None
    st.close()


# ---------------------------------------------------------------------------
# bounded retry
# ---------------------------------------------------------------------------

def test_transient_error_retries_value_identical():
    ref = _mk()
    k, v = _kv()
    _ingest_all(ref, k, v)
    ref._sidecar_valid[:] = False
    want, _ = ref.fetch_chunks(0, [1], seq=0)
    ref.close()

    plan = FaultPlan(schedule={"disk_read": {0: "io_error"}})
    st = _mk(faults=plan)
    _ingest_all(st, k, v)
    st._sidecar_valid[:] = False
    got, _ = st.fetch_chunks(0, [1], seq=0)
    assert np.array_equal(got, want)
    fs = st.fault_stats()
    assert fs["io_retries"] == 1 and fs["checksum_failures"] == 0
    st.close()


def test_persistent_errors_exhaust_to_chunk_lost():
    plan = FaultPlan(schedule={"disk_read": {i: "io_error"
                                             for i in range(10)}})
    st = _mk(faults=plan, io_retries=3)
    k, v = _kv()
    _ingest_all(st, k, v)
    st._sidecar_valid[:] = False
    with pytest.raises(ChunkLostError):
        st.fetch_chunks(0, [1], seq=0)
    assert st.fault_stats()["io_retries"] == 4     # io_retries + 1 attempts
    st.close()


def test_retry_wrapper_raises_exhausted():
    st = _mk(io_retries=2)
    calls = []

    def always_fails():
        calls.append(1)
        raise TransientDiskError("blip")

    with pytest.raises(DiskIOExhausted):
        st._with_retries(always_fails)
    assert len(calls) == 3
    st.close()


# ---------------------------------------------------------------------------
# crash consistency
# ---------------------------------------------------------------------------

def test_reopen_rejects_torn_chunk():
    st = _mk()
    k, v = _kv()
    _ingest_all(st, k, v)
    root = st._root
    # simulate a kill between the hot placement and the cold CRC landing:
    # the replica bytes may be anything, the CRC state never left "none"
    st._crc_state[0, 0, 3] = 0
    st._crc.flush()
    st._disk.flush()

    st2 = _mk(root=root, reopen=True)
    st2._sidecar_valid[:] = False
    ks, _ = st2.fetch_chunks(0, [0, 1, 2], seq=0)   # intact chunks serve
    assert np.array_equal(ks[0], k[:CH])
    with pytest.raises(ChunkLostError):
        st2.fetch_chunks(0, [3], seq=0)
    assert (0, 0, 3) in st2.disk_lost_keys()
    st2.close()


def test_clear_seq_resets_fault_state():
    st = _mk()
    k, v = _kv()
    _ingest_all(st, k, v)
    st._disk[0, 0, 1, 0].reshape(-1)[0] += np.float16(1.0)
    st._sidecar_valid[0, 0, 1] = False
    with pytest.raises(ChunkLostError):
        st.fetch_chunks(0, [1], seq=0)
    st.degraded_seqs.add(0)
    st.clear_seq(0)
    fs = st.fault_stats()
    assert fs["disk_lost"] == 0 and fs["degraded_seqs"] == 0
    # the row restarts with no stale CRC claims about reused storage
    assert int(st._crc_state[0].max()) == 0
    st.close()


# ---------------------------------------------------------------------------
# exception-safe fence (regression) + worker faults
# ---------------------------------------------------------------------------

def test_ingest_fence_drains_all_futures_then_raises():
    # REGRESSION: the fence used to re-raise the first future's error
    # immediately, leaving the seq's remaining write-behind futures in
    # flight while the caller reclaimed the row.  It must await ALL of
    # them, then surface one typed IngestError.
    plan = FaultPlan(schedule={"disk_write": {i: "io_error"
                                              for i in range(64)}})
    st = _mk(faults=plan, io_retries=1)
    k, v = _kv()
    with ThreadPoolExecutor(2) as ex:
        _ingest_all(st, k, v, executor=ex)
        with pytest.raises(IngestError) as ei:
            st.ingest_fence(0)
        assert ei.value.seq == 0
        assert isinstance(ei.value.cause, DiskIOExhausted)
        assert not st._ingest_futs.get(0)    # drained, not abandoned
        st.ingest_fence(0)                   # second fence: clean no-op
    st.close()


def test_worker_fault_surfaces_at_fence():
    plan = FaultPlan(schedule={"worker": {0: "exception"}})
    st = _mk(faults=plan)
    k, v = _kv()
    with ThreadPoolExecutor(1) as ex:
        _ingest_all(st, k, v, executor=ex)
        with pytest.raises(IngestError) as ei:
            st.ingest_fence_all()
        assert isinstance(ei.value.cause, WorkerFault)
    st.close()


# ---------------------------------------------------------------------------
# pooled-fetch partial-failure scrub (regression)
# ---------------------------------------------------------------------------

def test_pooled_fetch_scrubs_partial_failure():
    # REGRESSION: an exception between slot allocation and the slab
    # scatter used to leak the freshly-allocated slots (residency kept
    # pointing at rows the scatter never wrote, the free list never got
    # them back).  The scrub must evict the half-uploaded slots to HOST
    # and leave the pool conservation invariant intact.
    st = _mk(use_pool=True, pool_slots=NC)
    k, v = _kv()
    _ingest_all(st, k, v)
    st.ingest_fence_all()
    pool = st.pools[0]
    real = st._plane_stack
    boom = {"armed": True}

    def exploding(kc, vc):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("jit dispatch failed mid-upload")
        return real(kc, vc)

    st._plane_stack = exploding
    with pytest.raises(RuntimeError):
        st.fetch_chunks_pooled(0, {0: [0, 1]})
    # conservation: every slot is either free or scatter-backed resident
    assert len(pool.free) + len(pool.slot_of) == pool.n_slots
    assert not pool.slot_of                  # nothing half-uploaded stayed
    assert all(st.tier[0, 0, c] == HOST for c in (0, 1))
    # the retry serves the correct bytes from the intact host/disk copies
    # (sidecar path: int8 round-trip, so compare against the host copy)
    st._plane_stack = real
    slots, nsel, _ = st.fetch_chunks_pooled(0, {0: [0, 1]})
    got = np.asarray(pool.kv[slots[0, 0], 0])
    assert np.array_equal(got, st._host_k[(0, 0, 0)].astype(st.dtype))
    assert np.allclose(got.astype(np.float32), k[:CH].astype(np.float32),
                       atol=0.05)
    st.close()
